"""Benchmarks — one per paper table/figure, CPU-scale analogues.

  fig1   per-mode time variation of a mode-specific format vs BLCO
  fig8   all-mode MTTKRP speedup: BLCO vs COO / F-COO / CSF (geomean)
  fig9   per-mode speedup vs the strongest baseline
  table3 memory volume (device bytes) per format + achieved throughput
  fig10  out-of-memory streaming: overall vs in-memory throughput
  fig11  format construction cost: BLCO vs baselines (+ ALTO stages)
  fig12  BLCO construction-stage breakdown
  embed  the technique in the LM path: segment vs scatter embed-grad step
  bench5 memory-hierarchy MTTKRP: in-memory vs host-streamed vs
         disk-streamed store (BENCH_5.json)
  bench6 observability: traced disk-streamed CP-ALS with span-vs-stats
         consistency + tracing overhead (BENCH_6.json, TRACE_6.json)

Output: ``name,us_per_call,derived`` CSV rows (plus commentary lines
prefixed with '#'). The paper's absolute GPU numbers are not reproducible
on 1 CPU core; the *relative* claims (BLCO >= baselines on all-mode MTTKRP,
mode-balance, OOM parity) are what these measure — see EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import numpy as np

from repro import core

RANK = 32
SUITE = ["uber-like", "chicago-like", "vast-like", "darpa-like",
         "nell2-like"]

# block budget for the dispatch bench: forces multi-launch builds (tens to
# ~150 launches) on the fig8 suite — the hypersparse many-block regime the
# paper's launch batching targets, where per-launch dispatch + host padding
# overhead dominates the per-launch loop
DISPATCH_BLOCK = 1 << 9


def _time(fn, *, warmup=2, iters=5) -> float:
    r = None
    for _ in range(warmup):
        r = fn()
    if hasattr(r, "block_until_ready"):
        r.block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def _factors(t, seed=0):
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    return [jnp.asarray(rng.standard_normal((d, RANK)).astype(np.float32))
            for d in t.dims]


def _formats(t):
    """Device-resident ExecutionPlans (paper's in-memory regime: the tensor
    stays in device memory across CP-ALS iterations; only factors change).
    Every format — BLCO and baselines — goes through the one engine API."""
    from repro.engine import plan_for
    b = core.build_blco(t)
    budget = 1 << 40                      # in-memory benchmarking: no limit
    return {
        "blco": plan_for(b, budget, rank=RANK, backend="in_memory"),
        "coo": plan_for(b, budget, rank=RANK, backend="coo", tensor=t),
        "fcoo": plan_for(b, budget, rank=RANK, backend="fcoo", tensor=t),
        "csf": plan_for(b, budget, rank=RANK, backend="csf", tensor=t),
    }


def _mttkrp_time(fmt_name, fmt, factors, mode, resolution="auto") -> float:
    if fmt_name == "blco":
        return _time(lambda: fmt.mttkrp(factors, mode, resolution=resolution))
    return _time(lambda: fmt.mttkrp(factors, mode))


def bench_fig8_fig9_fig1(rows):
    geo: dict[str, list] = {"coo": [], "fcoo": [], "csf": []}
    geo_faithful: dict[str, list] = {"coo": [], "fcoo": [], "csf": []}
    for name in SUITE:
        t = core.paper_like(name, seed=0)
        fmts = _formats(t)
        factors = _factors(t)
        per_mode: dict[str, list] = {k: [] for k in fmts}
        faithful: list[float] = []
        for mode in range(t.order):
            for k in fmts:
                res = "direct" if k == "blco" else "auto"
                per_mode[k].append(_mttkrp_time(k, fmts[k], factors, mode,
                                                resolution=res))
            # paper-faithful conflict-resolution path (segment machinery);
            # on CPU the direct scatter wins — the segment win is TPU/GPU-
            # specific (serialized conflicting updates), see EXPERIMENTS.md
            faithful.append(_mttkrp_time("blco", fmts["blco"], factors, mode,
                                         resolution="auto"))
        all_mode = {k: sum(v) for k, v in per_mode.items()}
        t_faithful = sum(faithful)
        for k in ("coo", "fcoo", "csf"):
            sp = all_mode[k] / all_mode["blco"]
            geo[k].append(sp)
            geo_faithful[k].append(all_mode[k] / t_faithful)
            rows.append((f"fig8.{name}.speedup_vs_{k}",
                         all_mode["blco"] * 1e6, f"{sp:.3f}x"))
        rows.append((f"fig8.{name}.faithful_segment_path",
                     t_faithful * 1e6,
                     f"{all_mode['blco']/t_faithful:.3f}x of direct"))
        # fig9: per-mode speedup vs best baseline
        for mode in range(t.order):
            best = min(per_mode[k][mode] for k in ("coo", "fcoo", "csf"))
            rows.append((f"fig9.{name}.mode{mode+1}",
                         per_mode["blco"][mode] * 1e6,
                         f"{best / per_mode['blco'][mode]:.3f}x"))
        # fig1: per-mode imbalance (max/min across modes), CSF vs BLCO
        for k in ("csf", "blco"):
            imb = max(per_mode[k]) / min(per_mode[k])
            rows.append((f"fig1.{name}.mode_imbalance_{k}", 0.0,
                         f"{imb:.2f}x"))
    for k, v in geo.items():
        g = float(np.exp(np.mean(np.log(v))))
        rows.append((f"fig8.geomean_speedup_vs_{k}", 0.0, f"{g:.3f}x"))
    for k, v in geo_faithful.items():
        g = float(np.exp(np.mean(np.log(v))))
        rows.append((f"fig8.geomean_faithful_vs_{k}", 0.0, f"{g:.3f}x"))


def bench_table3(rows):
    for name in SUITE[:3]:
        t = core.paper_like(name, seed=0)
        fmts = _formats(t)
        factors = _factors(t)
        vol = {k: f.device_bytes() for k, f in fmts.items()}
        for k, b in vol.items():
            tm = sum(_mttkrp_time(k, fmts[k], factors, m)
                     for m in range(t.order))
            tp = b * t.order / tm / 1e9
            rows.append((f"table3.{name}.{k}", tm * 1e6,
                         f"vol={b/1e6:.2f}MB tp={tp:.2f}GB/s"))


def bench_fig10(rows):
    from repro.engine import plan_for
    t = core.paper_like("amazon-like", seed=0)
    b = core.build_blco(t, max_nnz_per_block=1 << 14)
    factors = _factors(t)
    dev = plan_for(b, 1 << 40, rank=RANK, backend="in_memory")
    in_mem = _time(lambda: dev.mttkrp(factors, 0))
    stream = plan_for(b, 1 << 40, rank=RANK, backend="streamed", queues=4)
    t0 = time.perf_counter()
    stream.mttkrp(factors, 0)
    overall = time.perf_counter() - t0
    nnz_bytes = core.format_bytes(b)
    s = stream.stats()
    rows.append(("fig10.amazon-like.in_memory", in_mem * 1e6,
                 f"{nnz_bytes/in_mem/1e9:.2f}GB/s"))
    rows.append(("fig10.amazon-like.oom_overall", overall * 1e6,
                 f"{nnz_bytes/overall/1e9:.2f}GB/s "
                 f"({in_mem/overall*100:.0f}% of in-mem)"))
    rows.append(("fig10.amazon-like.h2d_bytes", 0.0,
                 f"{s.h2d_bytes/1e6:.1f}MB"))
    rows.append(("fig10.amazon-like.put_vs_device", s.put_time_s * 1e6,
                 f"device={s.device_time_s*1e6:.0f}us "
                 f"dispatch={s.dispatch_time_s*1e6:.0f}us"))
    dev.close()
    stream.close()


def bench_fig11_fig12(rows):
    for name in SUITE[:3]:
        t = core.paper_like(name, seed=0)
        tb = _time(lambda: core.build_blco(t), warmup=1, iters=3)
        tc = _time(lambda: core.COOFormat.build(t), warmup=1, iters=3)
        tf = _time(lambda: core.FCOOFormat.build(t), warmup=1, iters=3)
        ts = _time(lambda: core.CSFFormat.build(t), warmup=1, iters=3)
        rows.append((f"fig11.{name}.blco", tb * 1e6, "1.00x"))
        for k, v in (("coo", tc), ("fcoo", tf), ("csf", ts)):
            rows.append((f"fig11.{name}.{k}", v * 1e6, f"{v/tb:.2f}x vs blco"))
        b = core.build_blco(t)
        total = sum(b.construction_stats.values())
        for stage, sec in b.construction_stats.items():
            rows.append((f"fig12.{name}.{stage}", sec * 1e6,
                         f"{sec/total*100:.1f}%"))
        # paper claim: blocking+re-encoding < 25% of construction
        extra = (b.construction_stats["reencode"]
                 + b.construction_stats["blocking"]
                 + b.construction_stats["block_keys"]
                 + b.construction_stats["batching"])
        rows.append((f"fig12.{name}.blco_extra_over_alto", extra * 1e6,
                     f"{extra/total*100:.1f}% (<25% claim)"))


def bench_embed_grad(rows):
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch import steps
    from repro.optim import adamw
    from repro.models import build_model
    rng = np.random.default_rng(0)
    for method in ("segment", "scatter"):
        cfg = dataclasses.replace(get_config("minicpm_2b").reduced(),
                                  embed_grad=method)
        model = build_model(cfg)
        opt_cfg = adamw.AdamWConfig(total_steps=100)
        step = jax.jit(steps.make_train_step(cfg, opt_cfg))
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        batch = {"tokens": jnp.asarray(
                     (rng.zipf(1.2, (8, 256)) % cfg.vocab_size).astype(np.int32)),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (8, 256)))}

        def run():
            nonlocal state
            state, m = step(state, batch)
            return m["loss"]
        rows.append((f"embed.train_step.{method}", _time(run) * 1e6, ""))


def bench_service(rows):
    """Multi-tenant service throughput: jobs/sec at 1 vs 4 concurrent tenants.

    Same total work (4 jobs, 2 distinct tensors, fixed iteration count) run
    (a) sequentially through 4 single-job service instances and (b) through
    one shared service — the shared run reuses cached BLCO builds and pooled
    reservations, so its jobs/sec measures the serving layer's win.
    """
    from repro.service import (BuildParams, DecompositionService,
                               SubmitDecomposition)
    build = BuildParams(max_nnz_per_block=1 << 12)
    tensors = [core.paper_like("uber-like", seed=0),
               core.paper_like("chicago-like", seed=0)]
    reqs = [SubmitDecomposition(tensor=tensors[i % 2], rank=16, iters=4,
                                tol=0.0, seed=i, build=build)
            for i in range(4)]

    def run_sequential():
        for req in reqs:
            svc = DecompositionService(device_budget_bytes=8 << 20, queues=4)
            svc.submit(req)
            svc.run()

    def run_shared():
        svc = DecompositionService(device_budget_bytes=8 << 20, queues=4)
        for req in reqs:
            svc.submit(req)
        svc.run()
        return svc

    # untimed warm-up so neither variant pays launch_mttkrp compilation
    # (the jit cache is process-wide; without this the first-timed variant
    # absorbs all compile time and the ratio is meaningless)
    warm = DecompositionService(device_budget_bytes=8 << 20, queues=4)
    for t in tensors:
        warm.submit(SubmitDecomposition(tensor=t, rank=16, iters=1, tol=0.0,
                                        seed=0, build=build))
    warm.run()

    t0 = time.perf_counter()
    run_sequential()
    seq_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc = run_shared()
    shared_s = time.perf_counter() - t0
    m = svc.service_metrics()
    rows.append(("service.1_tenant_sequential", seq_s / len(reqs) * 1e6,
                 f"{len(reqs)/seq_s:.3f}jobs/s"))
    rows.append(("service.4_tenants_shared", shared_s / len(reqs) * 1e6,
                 f"{len(reqs)/shared_s:.3f}jobs/s "
                 f"({seq_s/shared_s:.2f}x, {m['blco_cache_hits']} cache hits, "
                 f"peak_res={m['peak_admitted_reservation_bytes']/1e6:.2f}MB)"))


def bench_multitenant(rows, *, fast: bool = False,
                      json_path: str | None = "BENCH_4.json") -> dict:
    """Weighted multi-tenant serving through the async runtime (ISSUE 4).

    Drives N concurrent tenants with mixed fair-share weights through
    ``ServiceRuntime`` (worker thread + stride scheduler), ending together
    by giving each tenant an iteration cap proportional to its weight, and
    records per-tenant iterations/sec + achieved vs expected share into
    ``BENCH_4.json``.  A sacrificial tenant is cancelled mid-run to record
    the measured pooled-byte release of ``cancel()``.
    """
    from repro.service import (BuildParams, CancelJob, ServiceRuntime,
                               SubmitDecomposition)
    build = BuildParams(max_nnz_per_block=1 << 12)
    t_small = core.paper_like("uber-like", seed=0)
    t_big = core.paper_like("chicago-like", seed=0)
    base_iters = 2 if fast else 6
    rank = 8 if fast else 16
    # mixed weights; the heavy tenant does proportionally more sweeps
    tenants = [("heavy", 2.0, t_small), ("light-1", 1.0, t_small),
               ("light-2", 1.0, t_big)]
    total_w = sum(w for _, w, _ in tenants)

    # untimed warm-up so compile time does not skew the shared run
    warm = ServiceRuntime(device_budget_bytes=64 << 20, queues=4)
    with warm:
        for i, t in enumerate((t_small, t_big)):
            warm.submit(SubmitDecomposition(tensor=t, rank=rank, iters=1,
                                            tol=0.0, seed=i, build=build))
        warm.drain(timeout=600)

    # enqueue every weighted tenant BEFORE the worker starts: submitting
    # into a live worker lets the first tenant burn through its capped
    # sweeps while the rest are still being registered, which skews the
    # measured share window (all of it spent on one tenant)
    rt = ServiceRuntime(device_budget_bytes=64 << 20, queues=4)
    job_tenant = {}
    for i, (name, w, t) in enumerate(tenants):
        job_tenant[rt.submit(SubmitDecomposition(
            tensor=t, rank=rank, iters=int(base_iters * w), tol=0.0,
            seed=i, build=build, tenant=name, weight=w))] = name
    with rt:
        t0 = time.perf_counter()
        victim = rt.submit(SubmitDecomposition(
            tensor=t_big, rank=rank, iters=10_000, tol=0.0, seed=9,
            build=build, tenant="victim", weight=0.5))
        vfeed = rt.subscribe(victim)
        vfeed.get(timeout=600)               # victim really ran a sweep
        freed = rt.cancel(CancelJob(job_id=victim)).freed_bytes
        rt.unsubscribe(vfeed)
        rt.drain(timeout=600)
        wall = time.perf_counter() - t0
        m = rt.service_metrics()
        trace = list(rt.scheduler.trace)

    # share is measured over the FIRST HALF of the weighted tenants'
    # iteration trace — a window where no tenant has hit its cap yet, so
    # an unfair scheduler (e.g. FIFO serialization) would visibly skew it;
    # over the whole run the caps themselves would mask any unfairness
    tenant_trace = [job_tenant[j] for j in trace if j in job_tenant]
    window = tenant_trace[:len(tenant_trace) // 2] or tenant_trace
    per_tenant: dict[str, dict] = {}
    max_dev = 0.0
    for name, w, t in tenants:
        n = m["tenant_iterations"].get(name, 0)
        expected = w / total_w
        share = window.count(name) / len(window)
        dev = abs(share - expected) / expected
        max_dev = max(max_dev, dev)
        per_tenant[name] = {
            "weight": w, "nnz": t.nnz, "iterations": n,
            "iters_per_sec": n / wall if wall > 0 else 0.0,
            "share": share, "expected_share": expected,
        }
        rows.append((f"service4.{name}", wall / max(1, n) * 1e6,
                     f"w={w} {n / wall:.2f}it/s share={share:.3f} "
                     f"(want {expected:.3f})"))
    rows.append(("service4.max_share_deviation", 0.0, f"{max_dev:.3f}"))
    rows.append(("service4.cancel_freed_bytes", 0.0, f"{freed/1e6:.2f}MB"))
    payload = {
        "bench": "weighted_multi_tenant_service",
        "fast_mode": fast,
        "rank": rank,
        "backend": _jax_backend(),
        "note": ("N concurrent tenants with mixed stride-scheduling "
                 "weights through the async ServiceRuntime; iteration caps "
                 "proportional to weights so tenants finish together.  The "
                 "achieved share is measured over the first half of the "
                 "iteration trace (no tenant capped yet), so scheduler "
                 "unfairness cannot hide behind the caps.  A sacrificial "
                 "tenant is cancelled mid-run; freed bytes are the "
                 "measured admission-budget release."),
        "tenants": per_tenant,
        "wall_s": wall,
        "iterations_per_sec_total": m["iterations_total"] / wall
        if wall > 0 else 0.0,
        "max_share_deviation_vs_weights": max_dev,
        "victim_iterations_before_cancel":
            m["tenant_iterations"].get("victim", 0),
        "cancelled_jobs": m["jobs_cancelled"],
        "cancel_freed_bytes": freed,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return payload


def bench_dispatch(rows, *, fast: bool = False,
                   json_path: str | None = "BENCH_3.json") -> dict:
    """Single-dispatch launch-cache paths vs the PR-2 per-launch loop.

    Per fig8-suite tensor (built with a small block budget so the BLCO has
    MANY launches — the regime the paper's "reduce kernel launching
    overhead" claim is about), measures us_per_call of:

      per_launch_loop   PR-2 hot path: one numpy padding pass + one XLA
                        dispatch per launch per call (``mttkrp_per_launch``)
      cached_scan_xla   launch cache + single jitted lax.scan dispatch
                        (``InMemoryPlan(kernel="xla")``)
      fused_pallas      launch cache + ONE fused pallas_call pipeline
                        (``InMemoryPlan(kernel="pallas")``; interpret mode
                        on CPU — the Pallas timings here measure the
                        interpreter, not TPU performance)
      phases_pallas     PR-2 three-dispatch Pallas pipeline (cache-driven)

    Emits the machine-readable ``BENCH_3.json`` next to the CSV rows.
    """
    from repro.engine import plan_for
    from repro.kernels import pallas_mttkrp_phases

    suite = SUITE[:2] if fast else SUITE
    iters = 2 if fast else 5
    warmup = 1 if fast else 2
    p_iters = 1 if fast else 3
    suites: dict[str, dict] = {}
    speedups = []
    for name in suite:
        t = core.paper_like(name, seed=0)
        b = core.build_blco(t, max_nnz_per_block=DISPATCH_BLOCK)
        factors = _factors(t)
        mode = 0

        c0 = core.dispatch_count()
        core.mttkrp_per_launch(b, factors, mode)
        loop_dispatches = core.dispatch_count() - c0
        t_loop = _time(lambda: core.mttkrp_per_launch(b, factors, mode),
                       warmup=warmup, iters=iters)

        plan_x = plan_for(b, 1 << 40, rank=RANK, backend="in_memory",
                          kernel="xla")
        c0 = core.dispatch_count()
        plan_x.mttkrp(factors, mode)
        scan_dispatches = core.dispatch_count() - c0
        t_scan = _time(lambda: plan_x.mttkrp(factors, mode),
                       warmup=warmup, iters=iters)

        plan_p = plan_for(b, 1 << 40, rank=RANK, backend="in_memory",
                          kernel="pallas")
        t_fused = _time(lambda: plan_p.mttkrp(factors, mode),
                        warmup=1, iters=p_iters)
        t_phases = _time(lambda: pallas_mttkrp_phases(b, factors, mode),
                         warmup=1, iters=p_iters)

        sp = t_loop / t_scan
        speedups.append(sp)
        suites[name] = {
            "nnz": t.nnz,
            "launches": len(b.launches),
            "per_launch_loop_us": t_loop * 1e6,
            "cached_scan_xla_us": t_scan * 1e6,
            "fused_pallas_us": t_fused * 1e6,
            "phases_pallas_us": t_phases * 1e6,
            "dispatches_per_call_loop": loop_dispatches,
            "dispatches_per_call_cached": scan_dispatches,
            "speedup_cached_scan_vs_loop": sp,
        }
        rows.append((f"bench3.{name}.per_launch_loop", t_loop * 1e6,
                     f"{loop_dispatches} dispatches/call"))
        rows.append((f"bench3.{name}.cached_scan_xla", t_scan * 1e6,
                     f"{scan_dispatches} dispatch/call {sp:.2f}x vs loop"))
        rows.append((f"bench3.{name}.fused_pallas", t_fused * 1e6,
                     "1 dispatch/call (interpret)"))
        rows.append((f"bench3.{name}.phases_pallas", t_phases * 1e6,
                     "3-phase (interpret)"))
        plan_x.close()
        plan_p.close()

    geo = float(np.exp(np.mean(np.log(speedups))))
    rows.append(("bench3.geomean_cached_scan_vs_loop", 0.0, f"{geo:.3f}x"))
    payload = {
        "bench": "fused_single_dispatch_blco_mttkrp",
        "fast_mode": fast,
        "rank": RANK,
        "block_budget_nnz": DISPATCH_BLOCK,
        "backend": _jax_backend(),
        "note": ("Pallas paths run in interpret mode on CPU; their times "
                 "measure the interpreter.  The headline comparison is "
                 "cached_scan_xla (one dispatch, zero per-call host work) "
                 "vs per_launch_loop (the PR-2 engine hot path)."),
        "suites": suites,
        "geomean_speedup_cached_scan_vs_per_launch_loop": geo,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return payload


def bench_oom(rows, *, fast: bool = False,
              json_path: str | None = "BENCH_5.json",
              store_dir: str | None = None) -> dict:
    """Memory-hierarchy MTTKRP (ISSUE 5): in-memory vs host-streamed vs
    DISK-streamed, all bit-identical, through one engine API.

    Builds a many-launch BLCO, spills it to the persistent store
    (measuring write cost + file size), and times a full MTTKRP per tier:

      in_memory      device-resident launch cache, zero per-call H2D
      host_streamed  host-resident tensor, lazily padded chunks through
                     fixed reservations (the paper's OOM regime)
      disk_streamed  mmap'd store chunks straight to the device; host
                     window bounded by queues x reservation

    Records the bounded-window ratio (host window / all-launches padded
    bytes) — the quantity the lazy-padding fix and the store exist for —
    into ``BENCH_5.json``.
    """
    import shutil
    import tempfile
    from repro.engine import plan_for
    from repro.store import DiskStreamedPlan, open_blco, save_blco

    name = "uber-like" if fast else "amazon-like"
    block = 1 << 11 if fast else 1 << 12    # many launches: real streaming
    iters = 2 if fast else 5
    warmup = 1 if fast else 2
    queues = 4
    t = core.paper_like(name, seed=0)
    b = core.build_blco(t, max_nnz_per_block=block)
    factors = _factors(t)
    mode = 0
    own_dir = tempfile.mkdtemp() if store_dir is None else None
    sdir = store_dir or own_dir
    os.makedirs(sdir, exist_ok=True)
    path = f"{sdir}/bench_oom.blco"

    mem = host = disk = None
    try:
        t0 = time.perf_counter()
        file_bytes = save_blco(b, path)
        save_s = time.perf_counter() - t0

        mem = plan_for(b, 1 << 40, rank=RANK, backend="in_memory")
        host = plan_for(b, 1 << 40, rank=RANK, backend="streamed",
                        queues=queues)
        disk = DiskStreamedPlan(open_blco(path), queues=queues)

        t_mem = _time(lambda: mem.mttkrp(factors, mode),
                      warmup=warmup, iters=iters)
        t_host = _time(lambda: host.mttkrp(factors, mode),
                       warmup=warmup, iters=iters)
        t_disk = _time(lambda: disk.mttkrp(factors, mode),
                       warmup=warmup, iters=iters)

        # bit-identical across all three tiers (cheap insurance here)
        m0 = np.asarray(mem.mttkrp(factors, mode))
        if not (np.array_equal(m0, np.asarray(host.mttkrp(factors, mode)))
                and np.array_equal(m0, np.asarray(disk.mttkrp(factors, mode)))):
            raise AssertionError("memory-tier MTTKRP results diverged")

        nnz_bytes = core.format_bytes(b)
        window = disk.host_window_bytes()
        all_padded = disk.spec.bytes_per_launch * len(b.launches)
        ds = disk.stats()
    finally:
        for plan in (mem, host, disk):
            if plan is not None:
                plan.close()
        if own_dir is not None:
            shutil.rmtree(own_dir, ignore_errors=True)
    variants = {
        "in_memory": t_mem, "host_streamed": t_host, "disk_streamed": t_disk,
    }
    for k, sec in variants.items():
        rows.append((f"bench5.{name}.{k}", sec * 1e6,
                     f"{nnz_bytes/sec/1e9:.2f}GB/s "
                     f"({t_mem/sec*100:.0f}% of in-mem)"))
    rows.append((f"bench5.{name}.store_write", save_s * 1e6,
                 f"{file_bytes/1e6:.1f}MB file"))
    rows.append((f"bench5.{name}.host_window", 0.0,
                 f"{window/1e6:.2f}MB vs {all_padded/1e6:.2f}MB all-launch "
                 f"({window/all_padded:.3f}x)"))
    payload = {
        "bench": "memory_hierarchy_mttkrp",
        "fast_mode": fast,
        "rank": RANK,
        "tensor": name,
        "nnz": t.nnz,
        "launches": len(b.launches),
        "queues": queues,
        "block_budget_nnz": block,
        "backend": _jax_backend(),
        "note": ("One MTTKRP per memory tier (device-resident launch "
                 "cache / host-streamed lazy chunks / disk-streamed mmap "
                 "store), bit-identical outputs.  host_window_bytes is "
                 "the bounded padded-chunk window the streaming loop "
                 "holds (queues x reservation); ratio_vs_all_launches "
                 "is what the lazy-padding fix saves over the old eager "
                 "prepare_chunks.  On this CPU container the disk tier "
                 "reads from page cache; on a real deployment the mmap "
                 "page-ins overlap the H2D queue."),
        "store_file_bytes": file_bytes,
        "store_write_s": save_s,
        "format_bytes": nnz_bytes,
        "host_window_bytes": window,
        "all_launches_padded_bytes": all_padded,
        "host_window_ratio_vs_all_launches": window / all_padded,
        "us_per_call": {k: v * 1e6 for k, v in variants.items()},
        "gb_per_s": {k: nnz_bytes / v / 1e9 for k, v in variants.items()},
        "fraction_of_in_memory": {k: t_mem / v for k, v in variants.items()},
        "disk_stats": ds.snapshot(),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return payload


def bench_obs(rows, *, fast: bool = False,
              json_path: str | None = "BENCH_6.json",
              trace_path: str | None = "TRACE_6.json") -> dict:
    """Observability cost + correctness (ISSUE 6).

    Two measurements:

    * **Traced disk-streamed CP-ALS**: runs a full disk-streamed CP-ALS
      sweep with span tracing ON, writes the Chrome trace JSON
      (``trace_path``; load it at https://ui.perfetto.dev), and
      cross-checks the per-track span duration sums against the plan's
      ``EngineStats`` totals — they must agree, because the hot loop
      records trace events from the *same* timestamps that feed the
      stats.
    * **Tracing overhead**: in-memory MTTKRP us_per_call with tracing
      disabled vs enabled.  The disabled path is the default everywhere
      else in the benchmark suite; its cost is one module-flag check per
      instrumentation site.
    """
    import shutil
    import tempfile
    from repro import obs
    from repro.core.cp_als import cp_als
    from repro.engine import plan_for

    name = "uber-like" if fast else "chicago-like"
    block = 1 << 11 if fast else 1 << 12
    sweeps = 2
    rank = 8 if fast else RANK
    t = core.paper_like(name, seed=0)
    b = core.build_blco(t, max_nnz_per_block=block)
    norm_x = float(np.linalg.norm(np.asarray(t.values, np.float64)))
    factors = _factors(t)
    own_dir = tempfile.mkdtemp()
    was_enabled = obs.is_enabled()
    try:
        path = f"{own_dir}/bench_obs.blco"
        # untimed warm-up sweep (tracing off): compile + page the store in
        warm = plan_for(b, 1 << 40, rank=rank, backend="disk_streamed",
                        store_path=path)
        cp_als(warm, t.dims, rank, iters=1, norm_x=norm_x, tol=0.0, seed=0)
        warm.close()

        obs.enable()
        obs.clear()
        plan = plan_for(b, 1 << 40, rank=rank, backend="disk_streamed",
                        store_path=path)
        t0 = time.perf_counter()
        cp_als(plan, t.dims, rank, iters=sweeps, norm_x=norm_x, tol=0.0,
               seed=0)
        traced_wall_s = time.perf_counter() - t0
        st = plan.stats()
        plan.close()
        obs.disable()

        totals = obs.track_totals()
        n_spans = len(obs.trace.spans())
        if trace_path:
            obs.write_chrome_trace(trace_path)
        obs.clear()

        # per-track span sums vs the EngineStats the same timestamps fed
        pairs = {
            "store": (totals.get("store", 0.0), st.disk_time_s),
            "h2d": (totals.get("h2d", 0.0), st.put_time_s),
            "dispatch": (totals.get("dispatch", 0.0), st.dispatch_time_s),
            "device": (totals.get("device", 0.0), st.device_time_s),
        }
        consistency = {
            track: abs(span_s - stat_s) / stat_s if stat_s > 0 else 0.0
            for track, (span_s, stat_s) in pairs.items()}
        max_rel_err = max(consistency.values())

        # tracing overhead on the in-memory hot path (flag check only when
        # disabled; span + ring-buffer append when enabled)
        mem = plan_for(b, 1 << 40, rank=rank, backend="in_memory")
        t_off = _time(lambda: mem.mttkrp(factors, 0))
        obs.enable()
        obs.clear()
        t_on = _time(lambda: mem.mttkrp(factors, 0))
        obs.disable()
        obs.clear()
        mem.close()
        overhead = t_on / t_off - 1.0
    finally:
        if was_enabled:
            obs.enable()
        shutil.rmtree(own_dir, ignore_errors=True)

    rows.append((f"bench6.{name}.traced_disk_als", traced_wall_s * 1e6,
                 f"{n_spans} spans, max track err {max_rel_err*100:.2f}%"))
    for track, (span_s, stat_s) in pairs.items():
        rows.append((f"bench6.{name}.track_{track}", span_s * 1e6,
                     f"stats={stat_s*1e6:.0f}us "
                     f"err={consistency[track]*100:.2f}%"))
    rows.append((f"bench6.{name}.tracing_overhead_in_memory", t_on * 1e6,
                 f"off={t_off*1e6:.0f}us ({overhead*100:+.2f}%)"))
    payload = {
        "bench": "observability_tracing",
        "fast_mode": fast,
        "rank": rank,
        "tensor": name,
        "nnz": t.nnz,
        "launches": len(b.launches),
        "sweeps": sweeps,
        "backend": _jax_backend(),
        "note": ("Traced disk-streamed CP-ALS: per-track span duration "
                 "sums vs EngineStats totals (identical timestamps, so "
                 "rel err ~0 by construction), plus in-memory MTTKRP "
                 "us_per_call with tracing enabled vs disabled.  The "
                 "enabled-overhead measurement is noisy at CPU-container "
                 "timescales; the acceptance bar (<2%) applies to the "
                 "DISABLED path vs an untraced build."),
        "spans_recorded": n_spans,
        "traced_wall_s": traced_wall_s,
        "track_span_s": {k: v[0] for k, v in pairs.items()},
        "stats_totals_s": {k: v[1] for k, v in pairs.items()},
        "track_rel_err": consistency,
        "max_track_rel_err": max_rel_err,
        "hist_counts": {
            "dispatch_s": st.hist.dispatch_s.count,
            "put_chunk_s": st.hist.put_chunk_s.count,
            "disk_read_s": st.hist.disk_read_s.count,
            "launch_nnz": st.hist.launch_nnz.count,
        },
        "in_memory_us_tracing_off": t_off * 1e6,
        "in_memory_us_tracing_on": t_on * 1e6,
        "tracing_enabled_overhead_frac": overhead,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return payload


def _peak_bandwidths(fast: bool) -> dict:
    """Measured achievable ceilings per tier edge on THIS host.

    Microbenchmarks, not datasheet numbers: a fenced ``device_put`` of a
    large contiguous buffer (host_device), a fenced elementwise kernel
    over a device-resident buffer counting read+write traffic
    (device_hbm), and a scratch-file read (disk_host — on this CPU
    container that is page-cache speed, the same medium the mmap'd store
    chunks actually read from, so fractions stay apples-to-apples).
    """
    import shutil
    import tempfile

    import jax

    mb = 16 if fast else 64
    nbytes = mb << 20
    host_buf = np.ones(nbytes // 4, np.float32)

    def put():
        jax.device_put(host_buf).block_until_ready()

    t_h2d = _time(put, warmup=1, iters=3)

    dev = jax.device_put(host_buf)
    dev.block_until_ready()
    g = jax.jit(lambda a: a * 2.0)
    g(dev).block_until_ready()
    t_hbm = _time(lambda: g(dev).block_until_ready(), warmup=1, iters=3)

    own_dir = tempfile.mkdtemp()
    try:
        path = f"{own_dir}/scratch.bin"
        host_buf.tofile(path)

        def rd():
            np.fromfile(path, np.uint8)

        t_disk = _time(rd, warmup=1, iters=3)
    finally:
        shutil.rmtree(own_dir, ignore_errors=True)

    return {
        "disk_host": nbytes / t_disk / 1e9,
        "host_device": nbytes / t_h2d / 1e9,
        "device_hbm": 2 * nbytes / t_hbm / 1e9,   # read + write per element
    }


def _peak_flops() -> float:
    """Measured device flop ceiling: a fenced jitted matmul."""
    import jax
    import jax.numpy as jnp
    n = 512
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()
    t = _time(lambda: f(x).block_until_ready(), warmup=1, iters=3)
    return 2.0 * n ** 3 / t


def bench_roofline(rows, *, fast: bool = False,
                   json_path: str | None = "BENCH_7.json",
                   store_dir: str | None = None) -> dict:
    """Bandwidth ledger + roofline attribution (ISSUE 10).

    Re-measures the BENCH_5 workload (same tensor, block budget, queue
    depth) with the bandwidth ledger enabled, then:

    * **conservation** — per (regime, edge), ledger bytes/seconds must
      equal the plans' ``EngineStats`` counters with 0 relative error
      (the instrumentation records the identical locals; see
      ``repro.obs.ledger``);
    * **roofline** — measures this host's achievable ceiling per edge
      with microbenchmarks, then reports achieved GB/s and achieved
      fraction per edge per regime, naming each regime's saturated edge —
      turning BENCH_5's 0.80x/0.65x streaming gaps into a statement
      about *which* tier edge is the bottleneck;
    * **overhead** — in-memory MTTKRP us_per_call with tracing+ledger
      both enabled vs both disabled (the +2% acceptance bar).
    """
    import shutil
    import tempfile
    from repro import obs
    from repro.obs import ledger
    from repro.engine import plan_for
    from repro.store import DiskStreamedPlan, open_blco, save_blco

    name = "uber-like" if fast else "amazon-like"
    block = 1 << 11 if fast else 1 << 12
    iters = 2 if fast else 5
    warmup = 1 if fast else 2
    queues = 4
    t = core.paper_like(name, seed=0)
    b = core.build_blco(t, max_nnz_per_block=block)
    factors = _factors(t)
    mode = 0
    own_dir = tempfile.mkdtemp() if store_dir is None else None
    sdir = store_dir or own_dir
    os.makedirs(sdir, exist_ok=True)
    path = f"{sdir}/bench_roofline.blco"

    peaks = _peak_bandwidths(fast)
    peak_flops = _peak_flops()

    was_tracing = obs.is_enabled()
    was_ledger = ledger.is_enabled()
    mem = host = disk = None
    try:
        save_blco(b, path)

        # the ledger is on from plan construction (the in-memory upload is
        # part of its regime's host_device account) through every call the
        # timing loops make — stats and ledger see the same activity
        ledger.enable()
        ledger.clear()
        mem = plan_for(b, 1 << 40, rank=RANK, backend="in_memory")
        host = plan_for(b, 1 << 40, rank=RANK, backend="streamed",
                        queues=queues)
        disk = DiskStreamedPlan(open_blco(path), queues=queues)

        t_mem = _time(lambda: mem.mttkrp(factors, mode),
                      warmup=warmup, iters=iters)
        t_host = _time(lambda: host.mttkrp(factors, mode),
                       warmup=warmup, iters=iters)
        t_disk = _time(lambda: disk.mttkrp(factors, mode),
                       warmup=warmup, iters=iters)

        conservation = ledger.verify_conservation([
            ("in_memory", mem.stats()),
            ("streamed", host.stats()),
            ("disk_streamed", disk.stats()),
        ])
        report = obs.roofline_report(peaks=peaks, peak_flops=peak_flops)
        ledger.disable()

        # tracing + ledger enabled overhead on the in-memory hot path
        t_plain = _time(lambda: mem.mttkrp(factors, mode),
                        warmup=warmup, iters=iters)
        obs.enable()
        obs.clear()
        ledger.enable()
        t_obs = _time(lambda: mem.mttkrp(factors, mode),
                      warmup=warmup, iters=iters)
        obs.disable()
        obs.clear()
        ledger.disable()
        ledger.clear()
        overhead = t_obs / t_plain - 1.0
    finally:
        for plan in (mem, host, disk):
            if plan is not None:
                plan.close()
        if was_tracing:
            obs.enable()
        if was_ledger:
            ledger.enable()
        if own_dir is not None:
            shutil.rmtree(own_dir, ignore_errors=True)

    variants = {"in_memory": t_mem, "streamed": t_host,
                "disk_streamed": t_disk}
    achieved_fraction: dict[str, float] = {}
    saturated_edge: dict[str, str] = {}
    for regime, rep in report["regimes"].items():
        saturated_edge[regime] = rep["saturated_edge"]
        for edge, er in rep["edges"].items():
            frac = er.get("achieved_fraction")
            if frac is not None and er.get("seconds", 0.0) > 0.0:
                achieved_fraction[f"{regime}.{edge}"] = frac
                rows.append((f"bench7.{name}.{regime}.{edge}",
                             er["seconds"] * 1e6,
                             f"{er['gb_per_s']:.2f}GB/s "
                             f"({frac*100:.0f}% of {er['peak_gb_per_s']:.1f}"
                             f"GB/s peak)"))
    for regime, rep in report["regimes"].items():
        rows.append((f"bench7.{name}.{regime}.bound", 0.0,
                     f"{rep['bound']} (AI={rep['arithmetic_intensity']:.2f}"
                     f" flops/B, saturated: {saturated_edge[regime]})"))
    rows.append((f"bench7.{name}.conservation", 0.0,
                 f"max_edge_rel_err={conservation['max_rel_err']:.1e} "
                 f"({len(conservation['checks'])} checks)"))
    rows.append((f"bench7.{name}.obs_overhead_in_memory", t_obs * 1e6,
                 f"plain={t_plain*1e6:.0f}us ({overhead*100:+.2f}%)"))

    payload = {
        "bench": "bandwidth_roofline",
        "fast_mode": fast,
        "rank": RANK,
        "tensor": name,
        "nnz": t.nnz,
        "launches": len(b.launches),
        "queues": queues,
        "block_budget_nnz": block,
        "backend": _jax_backend(),
        "note": ("BENCH_5 workload re-measured under the bandwidth "
                 "ledger.  peaks are microbenchmarked achievable "
                 "ceilings on THIS host (disk_host is page-cache speed "
                 "on the CPU container — the same medium the mmap'd "
                 "store reads, so achieved fractions are "
                 "apples-to-apples; fractions can exceed 1.0 when "
                 "the workload's reads are cache-warmer than the "
                 "cold scratch-file microbenchmark).  "
                 "device_hbm bytes are "
                 "model-attributed per kernel (see "
                 "repro.obs.ledger.hbm_model_bytes); its seconds are "
                 "the fenced device spans.  max_edge_rel_err compares "
                 "ledger accounts against EngineStats counters and is "
                 "exactly 0.0 by construction.  saturated_edge names "
                 "the edge running closest to its ceiling per regime — "
                 "the direct input to the ROADMAP pipelining/compression "
                 "item."),
        "peak_gb_per_s": peaks,
        "peak_flops": peak_flops,
        "roofline": report,
        "achieved_fraction": achieved_fraction,
        "saturated_edge": saturated_edge,
        "bound": {r: rep["bound"] for r, rep in report["regimes"].items()},
        "max_edge_rel_err": conservation["max_rel_err"],
        "conservation_checks": len(conservation["checks"]),
        "us_per_call": {k: v * 1e6 for k, v in variants.items()},
        "in_memory_us_obs_off": t_plain * 1e6,
        "in_memory_us_obs_on": t_obs * 1e6,
        "obs_enabled_overhead_frac": overhead,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return payload


def _jax_backend() -> str:
    import jax
    return jax.default_backend()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke mode: dispatch bench only, reduced "
                         "suite/iterations")
    ap.add_argument("--json", default="BENCH_3.json", metavar="PATH",
                    help="where to write the machine-readable dispatch "
                         "bench (default: BENCH_3.json; '' disables)")
    ap.add_argument("--mt-json", default="BENCH_4.json", metavar="PATH",
                    help="where to write the weighted multi-tenant service "
                         "bench (default: BENCH_4.json; '' disables)")
    ap.add_argument("--oom-json", default="BENCH_5.json", metavar="PATH",
                    help="where to write the memory-hierarchy (disk vs "
                         "host vs in-memory) bench (default: BENCH_5.json; "
                         "'' disables)")
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="persistent store directory for bench_oom "
                         "(default: a temp dir, removed afterwards)")
    ap.add_argument("--obs-json", default="BENCH_6.json", metavar="PATH",
                    help="where to write the observability bench "
                         "(default: BENCH_6.json; '' disables)")
    ap.add_argument("--trace-json", default="TRACE_6.json", metavar="PATH",
                    help="where to write the Chrome trace JSON of the "
                         "traced disk-streamed CP-ALS (default: "
                         "TRACE_6.json; '' disables)")
    ap.add_argument("--roofline-json", default="BENCH_7.json", metavar="PATH",
                    help="where to write the bandwidth-ledger / roofline "
                         "bench (default: BENCH_7.json; '' disables)")
    args = ap.parse_args(argv)

    rows: list[tuple[str, float, str]] = []
    print("# BLCO paper benchmarks (CPU-scale analogues; see EXPERIMENTS.md)")
    if not args.fast:
        bench_fig8_fig9_fig1(rows)
        bench_table3(rows)
        bench_fig10(rows)
        bench_fig11_fig12(rows)
        bench_embed_grad(rows)
        bench_service(rows)
    bench_dispatch(rows, fast=args.fast, json_path=args.json or None)
    bench_multitenant(rows, fast=args.fast, json_path=args.mt_json or None)
    bench_oom(rows, fast=args.fast, json_path=args.oom_json or None,
              store_dir=args.store_dir)
    bench_obs(rows, fast=args.fast, json_path=args.obs_json or None,
              trace_path=args.trace_json or None)
    bench_roofline(rows, fast=args.fast,
                   json_path=args.roofline_json or None,
                   store_dir=args.store_dir)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
