"""Distributed CP-ALS over a device mesh (beyond-paper scale-out).

nnz shard over the `data` axis (one psum per mode), rank shards over the
`model` axis (zero-communication in MTTKRP). Runs on 8 fake XLA CPU devices
here; the identical code targets the 16x16 pod mesh.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/distributed_cpals.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro import core                                # noqa: E402
from repro.core.distributed import make_distributed_mttkrp   # noqa: E402
from repro.launch.mesh import make_test_mesh          # noqa: E402

mesh = make_test_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

t = core.random_tensor((300, 200, 150), 300_000, seed=0, dist="powerlaw")
b = core.build_blco(t)
print(f"tensor dims={t.dims} nnz={t.nnz:,}; BLCO blocks={len(b.blocks)}")

dist_mttkrp = make_distributed_mttkrp(b, mesh)

rank = 16
factor_sh = NamedSharding(mesh, P(None, "model"))
init = [jax.device_put(f, factor_sh)
        for f in core.init_factors(t.dims, rank, seed=1)]

res = core.cp_als(dist_mttkrp, t.dims, rank,
                  norm_x=float(np.linalg.norm(t.values)), iters=10,
                  factors=init)
print("fits:", [f"{f:.4f}" for f in res.fits])
print("factor sharding:", res.factors[0].sharding)
