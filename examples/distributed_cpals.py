"""Distributed CP-ALS over a device mesh (beyond-paper scale-out).

nnz shard over the `data` axis (one psum per mode), rank shards over the
`model` axis (zero-communication in MTTKRP). Runs on 8 fake XLA CPU devices
here; the identical code targets the 16x16 pod mesh.

With a mesh installed in ``repro.dist.context``, the engine's regime
decision routes MTTKRP execution through the sharded backend automatically:
``plan_for`` returns a ``ShardedPlan`` and CP-ALS runs on it unchanged.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/distributed_cpals.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro import core                                # noqa: E402
from repro.dist.context import set_mesh               # noqa: E402
from repro.engine import plan_for                     # noqa: E402
from repro.launch.mesh import make_test_mesh          # noqa: E402

mesh = make_test_mesh((4, 2), ("data", "model"))
set_mesh(mesh)                 # active mesh -> plan_for routes to sharded
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

t = core.random_tensor((300, 200, 150), 300_000, seed=0, dist="powerlaw")
b = core.build_blco(t)
print(f"tensor dims={t.dims} nnz={t.nnz:,}; BLCO blocks={len(b.blocks)}")

plan = plan_for(b, 1 << 30, rank=16)
assert plan.backend == "sharded", plan.backend
print(f"engine chose backend={plan.backend!r} "
      f"({plan.device_bytes()/1e6:.1f} MB sharded over the mesh)")

rank = 16
factor_sh = NamedSharding(mesh, P(None, "model"))
init = [jax.device_put(f, factor_sh)
        for f in core.init_factors(t.dims, rank, seed=1)]

res = core.cp_als(plan, t.dims, rank,
                  norm_x=float(np.linalg.norm(t.values)), iters=10,
                  factors=init)
print("fits:", [f"{f:.4f}" for f in res.fits])
print("factor sharding:", res.factors[0].sharding)
print("engine stats:", plan.stats().snapshot())
plan.close()
set_mesh(None)
