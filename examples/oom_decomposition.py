"""Out-of-memory decomposition: the paper's headline capability.

The tensor lives in HOST memory; only fixed-size launch reservations ever
occupy the device. The executor overlaps H2D transfers of pending blocks
with compute on active blocks (paper §4.2 / §6.4.2), and CP-ALS runs
unmodified on top.

    PYTHONPATH=src python examples/oom_decomposition.py
"""
import numpy as np

from repro import core

# "amazon-like" scale-down: 170k nnz, 3 long modes (paper Table 2 analogue)
t = core.paper_like("amazon-like", seed=0)
print(f"tensor dims={t.dims} nnz={t.nnz:,}")

# deliberately tiny per-launch reservation -> many streamed launches,
# emulating a tensor far larger than device memory
b = core.build_blco(t, max_nnz_per_block=1 << 13)
ex = core.OOMExecutor(b, queues=4)
print(f"{len(b.launches)} launches of <= {ex.reservation:,} nnz "
      f"(device reservation {ex.reservation * 16 / 1e6:.1f} MB)")

res = core.cp_als(lambda f, m: ex.mttkrp(f, m), t.dims, rank=16,
                  norm_x=float(np.linalg.norm(t.values)), iters=8, seed=1)
print("fits:", [f"{f:.4f}" for f in res.fits])

s = ex.stats
print(f"streaming stats: {s.launches} launches, "
      f"{s.h2d_bytes/1e6:.1f} MB H2D, "
      f"put {s.put_time_s:.2f}s / compute {s.compute_time_s:.2f}s / "
      f"total {s.total_time_s:.2f}s")
print("in-memory-throughput vs overall-throughput gap = host-device "
      "interconnect cost (paper Fig. 10)")
