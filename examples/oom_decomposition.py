"""Out-of-memory decomposition: the paper's headline capability.

The tensor lives in HOST memory; only fixed-size launch reservations ever
occupy the device.  ``plan_for`` makes the regime decision: under a budget
smaller than the tensor's device footprint it returns a ``StreamedPlan``,
which overlaps H2D transfers of pending blocks with compute on active
blocks (paper §4.2 / §6.4.2) — and CP-ALS runs unmodified on top of the
plan, exactly as it would on an in-memory one.

    PYTHONPATH=src python examples/oom_decomposition.py
"""
import numpy as np

from repro import core
from repro.engine import factor_bytes, in_memory_bytes, plan_for

# "amazon-like" scale-down: 170k nnz, 3 long modes (paper Table 2 analogue)
t = core.paper_like("amazon-like", seed=0)
print(f"tensor dims={t.dims} nnz={t.nnz:,}")

# deliberately tiny per-launch reservation -> many streamed launches,
# emulating a tensor far larger than device memory
b = core.build_blco(t, max_nnz_per_block=1 << 13)
# budget covers the factor working set but only HALF the tensor -> stream
budget = factor_bytes(b.dims, 16, np.float32) + in_memory_bytes(b) // 2
plan = plan_for(b, budget, rank=16, queues=4)
assert plan.backend == "streamed", plan.backend
print(f"budget {budget/1e6:.1f} MB cannot hold the "
      f"{in_memory_bytes(b)/1e6:.1f} MB tensor + factors "
      f"-> backend={plan.backend!r}: {len(b.launches)} launches of "
      f"<= {plan.spec.nnz:,} nnz, {plan.device_bytes()/1e6:.1f} MB in flight")

res = core.cp_als(plan, t.dims, rank=16,
                  norm_x=float(np.linalg.norm(t.values)), iters=8, seed=1)
print("fits:", [f"{f:.4f}" for f in res.fits])

s = plan.stats()
print(f"engine stats: {s.launches} launches, "
      f"{s.h2d_bytes/1e6:.1f} MB H2D, "
      f"put {s.put_time_s:.2f}s / dispatch {s.dispatch_time_s:.2f}s / "
      f"device {s.device_time_s:.2f}s / total {s.total_time_s:.2f}s")
print("in-memory-throughput vs overall-throughput gap = host-device "
      "interconnect cost (paper Fig. 10)")
plan.close()
