"""Quickstart: decompose a sparse tensor with BLCO-based CP-ALS.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import core

# a 4-order sparse tensor with skewed fiber density (paper's hard regime)
t = core.random_tensor((500, 120, 80, 40), 200_000, seed=0, dist="powerlaw")
print(f"tensor dims={t.dims} nnz={t.nnz:,} density={t.density:.2e}")

# build the BLCO format: one copy, mode-agnostic
b = core.build_blco(t)
print(f"BLCO: {len(b.blocks)} block(s), {len(b.launches)} launch(es), "
      f"{b.spec.total_bits} index bits, "
      f"{core.format_bytes(b)/1e6:.1f} MB device-resident")
print(f"construction: { {k: f'{v*1e3:.1f}ms' for k, v in b.construction_stats.items()} }")

# rank-16 CP decomposition via CP-ALS (Algorithm 1 of the paper)
res = core.cp_als(lambda f, m: core.mttkrp(b, f, m), t.dims, rank=16,
                  norm_x=float(np.linalg.norm(t.values)), iters=15, seed=1)
for i, fit in enumerate(res.fits, 1):
    print(f"iter {i:2d}  fit {fit:.4f}")
print(f"converged={res.converged} after {res.iterations} iterations")
print("lambda:", np.round(res.lam[:8], 3), "...")
