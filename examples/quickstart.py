"""Quickstart: decompose a sparse tensor with BLCO-based CP-ALS.

The engine API is the one front door: ``plan_for`` picks the execution
regime (device-resident vs streamed) for your device budget, and the plan
goes straight into ``cp_als``.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import core
from repro.engine import plan_for

# a 4-order sparse tensor with skewed fiber density (paper's hard regime)
t = core.random_tensor((500, 120, 80, 40), 200_000, seed=0, dist="powerlaw")
print(f"tensor dims={t.dims} nnz={t.nnz:,} density={t.density:.2e}")

# build the BLCO format: one copy, mode-agnostic
b = core.build_blco(t)
print(f"BLCO: {len(b.blocks)} block(s), {len(b.launches)} launch(es), "
      f"{b.spec.total_bits} index bits, "
      f"{core.format_bytes(b)/1e6:.1f} MB device-resident")
print(f"construction: { {k: f'{v*1e3:.1f}ms' for k, v in b.construction_stats.items()} }")

# plan execution under a 1 GiB device budget -> in-memory regime here.
# kernel="xla" (default) scans the device-resident launch cache in ONE
# jitted dispatch per call; kernel="pallas" runs the fused single-kernel
# pipeline instead (same plan API, interpret mode on CPU).
plan = plan_for(b, 1 << 30, rank=16, kernel="xla")
print(f"engine chose backend={plan.backend!r} kernel={plan.kernel!r} "
      f"({plan.device_bytes()/1e6:.1f} MB resident)")
c0 = core.dispatch_count()
plan.mttkrp(core.init_factors(t.dims, 16, seed=1), 0)
print(f"one MTTKRP call = {core.dispatch_count() - c0} device dispatch "
      f"across {len(b.launches)} launch(es)")

# rank-16 CP decomposition via CP-ALS (Algorithm 1 of the paper)
res = core.cp_als(plan, t.dims, rank=16,
                  norm_x=float(np.linalg.norm(t.values)), iters=15, seed=1)
for i, fit in enumerate(res.fits, 1):
    print(f"iter {i:2d}  fit {fit:.4f}")
print(f"converged={res.converged} after {res.iterations} iterations")
print("lambda:", np.round(res.lam[:8], 3), "...")
print(f"engine stats: {plan.stats().snapshot()}")
plan.close()
