"""Batched serving example: prefill + streaming greedy decode.

Uses the same decode step the 32k/500k dry-run shapes compile, at CPU scale.

    PYTHONPATH=src python examples/serve_lm.py [--arch minicpm-2b]
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model, param_count
from repro.serving import Server, ServeConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="minicpm-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=12)
ap.add_argument("--new-tokens", type=int, default=24)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
print(f"{cfg.name} ({cfg.family}) reduced: "
      f"{param_count(params)/1e6:.1f}M params")

srv = Server(cfg, ServeConfig(max_len=args.prompt_len + args.new_tokens),
             params)
rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size,
                       (args.batch, args.prompt_len)).astype(np.int32)

t0 = time.perf_counter()
out = srv.generate(prompts, args.new_tokens)
dt = time.perf_counter() - t0
total_steps = args.prompt_len + args.new_tokens
print(f"generated {args.batch}x{args.new_tokens} tokens "
      f"in {dt:.2f}s ({args.batch * total_steps / dt:.0f} steps/s batched)")
for i, row in enumerate(out):
    print(f"  request {i}: {row.tolist()}")
