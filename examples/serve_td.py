"""Multi-tenant tensor-decomposition service demo — mixed execution regimes.

Four CP-ALS jobs from three tenants on two distinct tensors share one
device through the service layer, under ONE measured byte budget:

* the engine gives the small repeated tensor the **device-resident fast
  path** (one pooled DeviceBLCO copy, zero per-iteration H2D) while the
  larger tensor **streams** through pooled fixed reservations;
* the repeated tensor is a BLCO construction-cache hit (one shared copy)
  AND a residency-pool hit (its second tenant is admitted for 0 bytes);
* admission charges exactly ``plan.device_bytes()`` — measured, not a
  padded worst case;
* results are bit-identical to a solo run through the same engine regime.

    PYTHONPATH=src python examples/serve_td.py
"""
import numpy as np

from repro import core
from repro.engine import factor_bytes, in_memory_bytes, plan_for
from repro.service import BuildParams, DecompositionService, SubmitDecomposition

build = BuildParams(max_nnz_per_block=1 << 12)   # small blocks -> real streaming
t_uber = core.paper_like("uber-like", seed=0)
t_chicago = core.paper_like("chicago-like", seed=0)
t_uber_again = core.paper_like("uber-like", seed=0)   # same content, new object

# size the budget so uber fits device-resident but chicago must stream:
# uber's resident copy + the factor working set + one pooled reservation
# set for chicago, with headroom well below chicago's residency cost
from repro.core.streaming import reservation_for

b_uber = core.build_blco(t_uber, max_nnz_per_block=1 << 12)
b_chicago = core.build_blco(t_chicago, max_nnz_per_block=1 << 12)
chicago_stream = reservation_for(b_chicago).bytes_in_flight(4)
headroom = chicago_stream + (128 << 10)
assert headroom < in_memory_bytes(b_chicago)   # chicago can never go resident
assert headroom >= factor_bytes(t_uber.dims, 16, np.float32)  # uber can
budget = in_memory_bytes(b_uber) + headroom

svc = DecompositionService(device_budget_bytes=budget, queues=4)
jobs = {
    "tenantA/uber":     svc.submit(SubmitDecomposition(
        tensor=t_uber, rank=16, iters=6, seed=1, build=build)),
    "tenantB/chicago":  svc.submit(SubmitDecomposition(
        tensor=t_chicago, rank=16, iters=6, seed=2, build=build)),
    "tenantC/uber":     svc.submit(SubmitDecomposition(
        tensor=t_uber_again, rank=16, iters=6, seed=1, build=build)),
    "tenantB/chicago8": svc.submit(SubmitDecomposition(
        tensor=t_chicago, rank=8, iters=6, seed=3, build=build)),
}
print(f"submitted {len(jobs)} jobs on 2 distinct tensors "
      f"(budget {budget/1e6:.1f} MB, {svc.engine.queues} queues)")

results = svc.run()
m = svc.service_metrics()

for name, jid in jobs.items():
    st = svc.status(jid)
    r = results[jid]
    print(f"  {name:18s} job={jid} {st.state} backend={st.backend:9s} "
          f"iters={st.iteration} fit={st.fit:.4f} cache_hit={st.cache_hit} "
          f"h2d={r.metrics['h2d_bytes']/1e6:.1f}MB "
          f"launches={r.metrics['launches']}")

backends = {name: svc.status(jid).backend for name, jid in jobs.items()}
assert backends["tenantA/uber"] == "in_memory"       # fast path
assert backends["tenantC/uber"] == "in_memory"       # pooled residency
assert backends["tenantB/chicago"] == "streamed"     # too big -> streams
assert backends["tenantB/chicago8"] == "streamed"

print(f"service: {m['blco_cache_hits']} cache hit(s) / "
      f"{m['blco_cache_misses']} build(s); "
      f"measured admission peak {m['peak_admitted_reservation_bytes']/1e6:.2f}MB "
      f"<= budget; {m['iterations_total']} iterations "
      f"({m['iterations_per_sec']:.2f}/s); "
      f"{m['h2d_bytes_total']/1e6:.1f}MB H2D total")
assert m["peak_admitted_reservation_bytes"] <= budget
assert m["blco_cache_hits"] == 2       # repeated uber content + reused chicago
assert m["blco_cache_misses"] == 2     # one build per distinct tensor

# the multi-tenant result is exactly the solo result through the same regime
jid = jobs["tenantA/uber"]
solo_plan = plan_for(b_uber, budget, rank=16, backend="in_memory")
solo = core.cp_als(solo_plan, t_uber.dims, 16,
                   norm_x=float(np.linalg.norm(t_uber.values)),
                   iters=6, seed=1)
for a, b_ in zip(results[jid].result.factors, solo.factors):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-5, atol=1e-6)
solo_plan.close()
print("multi-tenant factors == solo engine factors (same seeds): OK")
