"""Multi-tenant tensor-decomposition service demo — mixed execution regimes.

Four CP-ALS jobs from three tenants on two distinct tensors share one
device through the service layer, under ONE measured byte budget:

* the engine gives the small repeated tensor the **device-resident fast
  path** (one pooled DeviceBLCO copy, zero per-iteration H2D) while the
  larger tensor **streams** through pooled fixed reservations;
* the repeated tensor is a BLCO construction-cache hit (one shared copy)
  AND a residency-pool hit (its second tenant only pays its per-job factor
  working set);
* admission charges exactly ``plan.device_bytes()`` — the pooled tensor
  state once, plus each job's private factor working set;
* results are bit-identical to a solo run through the same engine regime.

A second act drives the **async runtime**: the same workload submitted to
``ServiceRuntime`` with per-tenant weights (tenant A at weight 2 gets twice
the sweeps), a streamed status feed, and a mid-run cancellation that
measurably frees pooled bytes.

A third act exercises the **persistent store**: the service is killed
mid-decomposition, restarted from its snapshot + spill store, and the job
resumes from its checkpointed ``CPState`` — disk-streaming the tensor
straight off the store, with no BLCO rebuild and a numerically identical
trajectory.

    PYTHONPATH=src python examples/serve_td.py
"""
import os
import shutil
import tempfile

import numpy as np

from repro import core
from repro.engine import factor_bytes, in_memory_bytes, plan_for
from repro.service import (BuildParams, CancelJob, DecompositionService,
                           ServiceRuntime, SubmitDecomposition)

build = BuildParams(max_nnz_per_block=1 << 12)   # small blocks -> real streaming
t_uber = core.paper_like("uber-like", seed=0)
t_chicago = core.paper_like("chicago-like", seed=0)
t_uber_again = core.paper_like("uber-like", seed=0)   # same content, new object

# size the budget so uber fits device-resident but chicago must stream:
# uber's resident copy + a working set per job + one pooled reservation
# set for chicago, with headroom well below chicago's residency cost
b_uber = core.build_blco(t_uber, max_nnz_per_block=1 << 12)
b_chicago = core.build_blco(t_chicago, max_nnz_per_block=1 << 12)
from repro.core.streaming import reservation_for

chicago_stream = reservation_for(b_chicago).bytes_in_flight(4)
fb_uber = factor_bytes(t_uber.dims, 8, np.float32)
fb_ch16 = factor_bytes(t_chicago.dims, 16, np.float32)
fb_ch8 = factor_bytes(t_chicago.dims, 8, np.float32)
budget = in_memory_bytes(b_uber) + 2 * fb_uber \
    + chicago_stream + fb_ch16 + fb_ch8 + (32 << 10)
# chicago can never go resident: when its first job is admitted (tenantA's
# uber copy + working set already held), the remaining budget is below
# chicago's residency cost + its working set
assert budget - in_memory_bytes(b_uber) - fb_uber \
    < in_memory_bytes(b_chicago) + fb_ch16

svc = DecompositionService(device_budget_bytes=budget, queues=4)
jobs = {
    "tenantA/uber":     svc.submit(SubmitDecomposition(
        tensor=t_uber, rank=8, iters=6, seed=1, build=build,
        tenant="tenantA")),
    "tenantB/chicago":  svc.submit(SubmitDecomposition(
        tensor=t_chicago, rank=16, iters=6, seed=2, build=build,
        tenant="tenantB")),
    "tenantC/uber":     svc.submit(SubmitDecomposition(
        tensor=t_uber_again, rank=8, iters=6, seed=1, build=build,
        tenant="tenantC")),
    "tenantB/chicago8": svc.submit(SubmitDecomposition(
        tensor=t_chicago, rank=8, iters=6, seed=3, build=build,
        tenant="tenantB")),
}
print(f"submitted {len(jobs)} jobs on 2 distinct tensors "
      f"(budget {budget/1e6:.1f} MB, {svc.engine.queues} queues)")

results = svc.run()
m = svc.service_metrics()

for name, jid in jobs.items():
    st = svc.status(jid)
    r = results[jid]
    print(f"  {name:18s} job={jid} {st.state} backend={st.backend:9s} "
          f"iters={st.iteration} fit={st.fit:.4f} cache_hit={st.cache_hit} "
          f"h2d={r.metrics['h2d_bytes']/1e6:.1f}MB "
          f"launches={r.metrics['launches']}")

backends = {name: svc.status(jid).backend for name, jid in jobs.items()}
assert backends["tenantA/uber"] == "in_memory"       # fast path
assert backends["tenantC/uber"] == "in_memory"       # pooled residency
assert backends["tenantB/chicago"] == "streamed"     # too big -> streams
assert backends["tenantB/chicago8"] == "streamed"

print(f"service: {m['blco_cache_hits']} cache hit(s) / "
      f"{m['blco_cache_misses']} build(s); "
      f"measured admission peak {m['peak_admitted_reservation_bytes']/1e6:.2f}MB "
      f"<= budget; {m['iterations_total']} iterations "
      f"({m['iterations_per_sec']:.2f}/s); "
      f"{m['h2d_bytes_total']/1e6:.1f}MB H2D total")
assert m["peak_admitted_reservation_bytes"] <= budget
assert m["blco_cache_hits"] == 2       # repeated uber content + reused chicago
assert m["blco_cache_misses"] == 2     # one build per distinct tensor

# the multi-tenant result is exactly the solo result through the same regime
jid = jobs["tenantA/uber"]
solo_plan = plan_for(b_uber, budget, rank=8, backend="in_memory")
solo = core.cp_als(solo_plan, t_uber.dims, 8,
                   norm_x=float(np.linalg.norm(t_uber.values)),
                   iters=6, seed=1)
for a, b_ in zip(results[jid].result.factors, solo.factors):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-5, atol=1e-6)
solo_plan.close()
print("multi-tenant factors == solo engine factors (same seeds): OK")

# ---------------------------------------------------------------------------
# Act 2: the async runtime — weighted fair share, streaming status, cancel.
# ---------------------------------------------------------------------------
print("\n== async runtime (weighted shares + streaming + cancellation) ==")
# three uber tenants (3 working sets) + one streaming chicago tenant
budget2 = in_memory_bytes(b_uber) + 3 * fb_uber \
    + chicago_stream + fb_ch16 + (32 << 10)
assert chicago_stream + fb_ch16 + (32 << 10) \
    < in_memory_bytes(b_chicago) + fb_ch16
with ServiceRuntime(device_budget_bytes=budget2, queues=4) as rt:
    feed = rt.subscribe()                       # all-jobs status stream
    ja = rt.submit(SubmitDecomposition(tensor=t_uber, rank=8, iters=8,
                                       tol=0.0, seed=1, build=build,
                                       tenant="tenantA", weight=2.0))
    jb = rt.submit(SubmitDecomposition(tensor=t_uber, rank=8, iters=4,
                                       tol=0.0, seed=2, build=build,
                                       tenant="tenantB", weight=1.0))
    jc = rt.submit(SubmitDecomposition(tensor=t_uber, rank=8, iters=4,
                                       tol=0.0, seed=3, build=build,
                                       tenant="tenantC", weight=1.0))
    victim = rt.submit(SubmitDecomposition(tensor=t_chicago, rank=16,
                                           iters=10_000, tol=0.0, seed=4,
                                           build=build, tenant="tenantD"))
    first = feed.get(timeout=120)
    print(f"  first streamed event: job={first.job_id} kind={first.kind} "
          f"tenant={first.tenant}")
    assert rt.status(victim).state == "running"   # admitted as streamed
    held = rt.service.engine.pooled_bytes()
    res = rt.cancel(CancelJob(job_id=victim))
    print(f"  cancelled tenantD mid-run: freed {res.freed_bytes/1e6:.2f}MB "
          f"(pooled {held/1e6:.2f}MB -> "
          f"{rt.service.engine.pooled_bytes()/1e6:.2f}MB)")
    assert res.cancelled and res.freed_bytes > 0
    rt.drain(timeout=600)
    rt.unsubscribe(feed)
    mt = rt.service_metrics()
print(f"  tenant iterations: {mt['tenant_iterations']} "
      f"(weights A=2, B=C=1); cancellations={mt['jobs_cancelled']}")
assert mt["tenant_iterations"]["tenantA"] == 8
assert mt["tenant_iterations"]["tenantB"] == 4
assert mt["tenant_iterations"]["tenantC"] == 4
assert mt["jobs_cancelled"] == 1
print("async runtime: weighted shares + measured cancellation: OK")

# ---------------------------------------------------------------------------
# Act 3: kill the service mid-decomposition, restart from the persisted
# store, and watch the job resume from its checkpointed CPState.
# ---------------------------------------------------------------------------
print("\n== persistent store (kill -> restart -> resume) ==")
workdir = tempfile.mkdtemp()
store_dir = os.path.join(workdir, "store")
snap_dir = os.path.join(workdir, "snapshot")
ITERS = 10

# the uninterrupted trajectory we must exactly reproduce across the restart
ref = DecompositionService(device_budget_bytes=budget, store_dir=store_dir)
ref_job = ref.submit(SubmitDecomposition(tensor=t_uber, rank=8, iters=ITERS,
                                         tol=0.0, seed=7, build=build,
                                         tenant="tenantA"))
ref.run()
ref_fits = ref.result(ref_job).result.fits

rt = ServiceRuntime(device_budget_bytes=budget, store_dir=store_dir).start()
job = rt.submit(SubmitDecomposition(tensor=t_uber, rank=8, iters=ITERS,
                                    tol=0.0, seed=7, build=build,
                                    tenant="tenantA"))
feed = rt.subscribe(job)
while True:                                     # let it make real progress
    ev = feed.get(timeout=120)
    if ev.kind == "iteration" and ev.iteration >= 3:
        break
rt.unsubscribe(feed)
rt.stop()            # "kill": the worker halts after its in-flight sweep
manifest = rt.snapshot(snap_dir)                # checkpoint at a sweep edge
assert manifest["jobs"], "job finished before the snapshot window"
ckpt_iter = manifest["jobs"][0]["iteration"]
del rt
print(f"  killed mid-run at iteration {ckpt_iter}/{ITERS} "
      f"(snapshot: {len(manifest['jobs'])} job, "
      f"{len(manifest['tensors'])} tensor in store)")

rt2 = ServiceRuntime.restore(snap_dir, device_budget_bytes=budget,
                             store_dir=store_dir)
st = rt2.status(job)                            # original job id survives
assert st.state == "running" and st.iteration == ckpt_iter
assert rt2.service.registry.misses == 0         # adopted off disk, no rebuild
with rt2:
    final = rt2.wait(job, timeout=600)
    fits = rt2.result(job).result.fits
    m3 = rt2.service_metrics()
print(f"  restored under job id {job}: resumed at iter {ckpt_iter}, "
      f"finished at iter {final.iteration} backend={final.backend}")
assert final.state == "done" and final.iteration == ITERS
assert final.backend == "disk_streamed"         # streams straight off the store
assert fits == ref_fits                         # trajectory exactly preserved
assert m3["jobs_restored"] == 1 and m3["blco_cache_misses"] == 0
print(f"  resumed fit trajectory == uninterrupted run ({len(fits)} sweeps, "
      f"exact); disk-streamed {m3['h2d_bytes_total']/1e6:.1f}MB from the store")
shutil.rmtree(workdir)
print("persistent store: kill -> restart -> exact resume: OK")
