"""Multi-tenant tensor-decomposition service demo.

Four CP-ALS jobs from three tenants on two distinct tensors share one
device through the service layer:

* the repeated tensor is a BLCO construction-cache hit (one shared copy);
* admission control keeps the sum of pooled reservation bytes under a
  device budget (the paper's §4.2 memory constraint, multi-tenant);
* the scheduler round-robins CP-ALS iterations so every tenant advances
  each cycle;
* results are bit-identical to a solo sequential run on the same seeds.

    PYTHONPATH=src python examples/serve_td.py
"""
import numpy as np

from repro import core
from repro.service import BuildParams, DecompositionService, SubmitDecomposition

build = BuildParams(max_nnz_per_block=1 << 12)   # small blocks -> real streaming
t_uber = core.paper_like("uber-like", seed=0)
t_chicago = core.paper_like("chicago-like", seed=0)
t_uber_again = core.paper_like("uber-like", seed=0)   # same content, new object

svc = DecompositionService(device_budget_bytes=8 << 20, queues=4)
jobs = {
    "tenantA/uber":     svc.submit(SubmitDecomposition(
        tensor=t_uber, rank=16, iters=6, seed=1, build=build)),
    "tenantB/chicago":  svc.submit(SubmitDecomposition(
        tensor=t_chicago, rank=16, iters=6, seed=2, build=build)),
    "tenantC/uber":     svc.submit(SubmitDecomposition(
        tensor=t_uber_again, rank=16, iters=6, seed=1, build=build)),
    "tenantB/chicago8": svc.submit(SubmitDecomposition(
        tensor=t_chicago, rank=8, iters=6, seed=3, build=build)),
}
print(f"submitted {len(jobs)} jobs on 2 distinct tensors "
      f"(budget {svc.scheduler.device_budget_bytes >> 20} MiB, "
      f"{svc.executor.queues} queues)")

results = svc.run()
m = svc.service_metrics()

for name, jid in jobs.items():
    st = svc.status(jid)
    r = results[jid]
    print(f"  {name:18s} job={jid} {st.state} iters={st.iteration} "
          f"fit={st.fit:.4f} cache_hit={st.cache_hit} "
          f"h2d={r.metrics['h2d_bytes']/1e6:.1f}MB "
          f"launches={r.metrics['launches']}")

print(f"service: {m['blco_cache_hits']} cache hit(s) / "
      f"{m['blco_cache_misses']} build(s); "
      f"pooled-reservation peak {m['peak_admitted_reservation_bytes']/1e6:.2f}MB "
      f"<= budget; {m['iterations_total']} iterations "
      f"({m['iterations_per_sec']:.2f}/s); "
      f"{m['h2d_bytes_total']/1e6:.1f}MB H2D total")
assert m["peak_admitted_reservation_bytes"] <= svc.scheduler.device_budget_bytes
assert m["blco_cache_hits"] == 2       # repeated uber content + reused chicago
assert m["blco_cache_misses"] == 2     # one build per distinct tensor

# the multi-tenant result is exactly the solo result on the same seeds
jid = jobs["tenantA/uber"]
b = core.build_blco(t_uber, max_nnz_per_block=1 << 12)
ex = core.OOMExecutor(b, queues=4)
solo = core.cp_als(lambda f, mm: ex.mttkrp(f, mm), t_uber.dims, 16,
                   norm_x=float(np.linalg.norm(t_uber.values)),
                   iters=6, seed=1)
for a, b_ in zip(results[jid].result.factors, solo.factors):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-5, atol=1e-6)
print("multi-tenant factors == solo sequential factors (same seeds): OK")
