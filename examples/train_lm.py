"""End-to-end LM training driver with the paper's technique in the
embedding-gradient path (segment conflict resolution vs naive scatter).

Trains a reduced-config LM for a few hundred steps on CPU with the full
production substrate: sharded-capable train step, WSD/cosine schedule,
fault-tolerant trainer (checkpoint + resume), deterministic data pipeline.

    PYTHONPATH=src python examples/train_lm.py [--arch minicpm-2b] [--steps 300]
"""
import argparse
import dataclasses
import shutil

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_mod
from repro.models import build_model, param_count
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="minicpm-2b")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--embed-grad", choices=("segment", "scatter"),
                default="segment")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = dataclasses.replace(get_config(args.arch).reduced(),
                          embed_grad=args.embed_grad)
model = build_model(cfg)
opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup=20, total_steps=args.steps,
                            schedule=cfg.schedule)
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, global_batch=8,
                              seq_len=128, input_mode=cfg.input_mode,
                              frontend_dim=cfg.frontend_dim or cfg.d_model,
                              encdec=cfg.is_encdec))
shutil.rmtree(args.ckpt_dir, ignore_errors=True)

trainer = Trainer(
    TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                  ckpt_every=100, log_every=20),
    model, opt_cfg, steps_mod.make_train_step(cfg, opt_cfg), data)

import jax
params_m = param_count(trainer.state["params"]) / 1e6
print(f"{cfg.name} ({cfg.family}): {params_m:.1f}M params, "
      f"embed_grad={cfg.embed_grad}, schedule={cfg.schedule}")
out = trainer.run()
for h in out["history"]:
    print(f"step {h['step']:>5}  loss {h['loss']:.4f}  lr {h['lr']:.2e}  "
          f"{h['step_time_s']*1e3:.0f} ms")
print(f"done at step {out['final_step']}; "
      f"checkpoints in {args.ckpt_dir}; "
      f"stragglers flagged: {len(out['stragglers'])}")
