#!/usr/bin/env python
"""Perf-regression gate: diff fresh BENCH_*.json against the committed ones.

Compares a fresh benchmark run (e.g. ``benchmarks/run.py --fast`` into a
scratch directory) against the benchmark JSON files committed at the repo
root, and fails when the geometric-mean slowdown across comparable timing
metrics exceeds the threshold (default 20%).

Only **config-comparable** metrics are diffed: a metric pair is compared
iff the two payloads agree on every configuration key they both carry
(``rank``, ``tensor``, ``block_budget_nnz``, ``queues``, ``sweeps``,
``fast_mode``) — the committed files are full-mode runs, so a ``--fast``
CI run skips the benches whose fast config differs (different tensor or
rank) and says so, rather than comparing apples to oranges.  BENCH_3 is
additionally diffed per-suite, so the fig8-suite overlap between fast and
full modes still gates even though the suite lists differ.

Exit status: 0 = within threshold (or nothing comparable), 1 = regression
over threshold, 2 = usage/IO error.  ``--report json`` prints a
machine-readable verdict for CI artifacts.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

DEFAULT_BENCHES = ("BENCH_3.json", "BENCH_4.json", "BENCH_5.json",
                   "BENCH_6.json", "BENCH_7.json")

# payload keys that must agree for two runs to be timing-comparable
CONFIG_KEYS = ("bench", "rank", "tensor", "block_budget_nnz", "queues",
               "sweeps", "fast_mode")


def _config_mismatch(old: dict, new: dict) -> list:
    """Config keys present in both payloads with differing values."""
    return [k for k in CONFIG_KEYS
            if k in old and k in new and old[k] != new[k]]


def _suite_metrics(old: dict, new: dict):
    """BENCH_3 per-suite timings over the suites both runs measured."""
    out = {}
    shared = set(old.get("suites", {})) & set(new.get("suites", {}))
    for name in sorted(shared):
        o, n = old["suites"][name], new["suites"][name]
        for key in ("per_launch_loop_us", "cached_scan_xla_us"):
            if key in o and key in n:
                out[f"{name}.{key}"] = (o[key], n[key], "lower")
    return out


def _flat_metrics(old: dict, new: dict):
    """Timing metrics shared by the generic payload shapes."""
    out = {}
    for key, direction in (("iterations_per_sec_total", "higher"),
                           ("in_memory_us_tracing_off", "lower"),
                           ("traced_wall_s", "lower"),
                           ("store_write_s", "lower")):
        if key in old and key in new:
            out[key] = (old[key], new[key], direction)
    for key in ("us_per_call",):                      # BENCH_5/7 tier timings
        if isinstance(old.get(key), dict) and isinstance(new.get(key), dict):
            for tier in sorted(set(old[key]) & set(new[key])):
                out[f"{key}.{tier}"] = (old[key][tier], new[key][tier],
                                        "lower")
    # BENCH_7 bandwidth fractions: a drop in achieved fraction of the
    # measured peak on any edge is a bandwidth regression ("higher" is
    # better, so ratio = old/new)
    key = "achieved_fraction"
    if isinstance(old.get(key), dict) and isinstance(new.get(key), dict):
        for edge in sorted(set(old[key]) & set(new[key])):
            out[f"{key}.{edge}"] = (old[key][edge], new[key][edge], "higher")
    return out


def compare_pair(old: dict, new: dict) -> dict:
    """Diff one committed/fresh payload pair; returns a verdict record."""
    mismatch = _config_mismatch(old, new)
    metrics = dict(_suite_metrics(old, new))
    if not mismatch:
        metrics.update(_flat_metrics(old, new))
    ratios = {}
    for name, (o, n, direction) in metrics.items():
        # a null/non-numeric metric (crashed sub-bench, hand-edited file)
        # is skipped, never a crash in the gate itself
        if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   and v > 0 for v in (o, n)):
            continue
        # ratio > 1 always means "fresh run is worse"
        ratios[name] = (n / o) if direction == "lower" else (o / n)
    record = {
        "bench": new.get("bench", "?"),
        "config_mismatch": mismatch,
        "compared_metrics": len(ratios),
        "ratios": ratios,
    }
    if ratios:
        record["geomean_ratio"] = math.exp(
            sum(math.log(r) for r in ratios.values()) / len(ratios))
        worst = max(ratios, key=ratios.get)
        record["worst_metric"] = worst
        record["worst_ratio"] = ratios[worst]
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--fresh-dir", required=True, metavar="DIR",
                    help="directory holding the freshly generated "
                         "BENCH_*.json files")
    ap.add_argument("--committed-dir", default=".", metavar="DIR",
                    help="directory holding the committed baselines "
                         "(default: repo root)")
    ap.add_argument("--benches", nargs="*", default=list(DEFAULT_BENCHES),
                    metavar="FILE", help="benchmark JSON filenames to diff")
    ap.add_argument("--threshold", type=float, default=0.20, metavar="FRAC",
                    help="maximum tolerated geomean slowdown "
                         "(default: 0.20 = 20%%)")
    ap.add_argument("--report", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    records, all_ratios, skipped = [], {}, []
    for fname in args.benches:
        old_path = os.path.join(args.committed_dir, fname)
        new_path = os.path.join(args.fresh_dir, fname)
        if not os.path.exists(old_path):
            skipped.append((fname, "no committed baseline"))
            continue
        if not os.path.exists(new_path):
            skipped.append((fname, "missing from fresh run"))
            continue
        try:
            with open(old_path) as f:
                old = json.load(f)
            with open(new_path) as f:
                new = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            print(f"bench_compare: cannot read {fname}: {exc}",
                  file=sys.stderr)
            return 2
        rec = compare_pair(old, new)
        rec["file"] = fname
        records.append(rec)
        if rec["config_mismatch"]:
            skipped.append((fname, "config mismatch: "
                            + ",".join(rec["config_mismatch"])))
        for name, r in rec["ratios"].items():
            all_ratios[f"{fname}:{name}"] = r

    verdict = {
        "threshold": args.threshold,
        "compared_metrics": len(all_ratios),
        "skipped": [{"file": f, "reason": r} for f, r in skipped],
        "per_bench": records,
    }
    if all_ratios:
        geo = math.exp(sum(math.log(r) for r in all_ratios.values())
                       / len(all_ratios))
        worst = max(all_ratios, key=all_ratios.get)
        verdict.update(geomean_ratio=geo, worst_metric=worst,
                       worst_ratio=all_ratios[worst])
        verdict["regressed"] = geo > 1.0 + args.threshold
    else:
        verdict.update(geomean_ratio=None, regressed=False)

    if args.report == "json":
        json.dump(verdict, sys.stdout, indent=2, sort_keys=True)
        print()
    else:
        for fname, reason in skipped:
            print(f"bench_compare: SKIP {fname} ({reason})")
        for rec in records:
            if rec["ratios"]:
                print(f"bench_compare: {rec['file']} "
                      f"geomean {rec['geomean_ratio']:.3f}x "
                      f"worst {rec['worst_metric']} "
                      f"{rec['worst_ratio']:.3f}x "
                      f"({rec['compared_metrics']} metrics)")
        if verdict["geomean_ratio"] is None:
            if not records:
                print("bench_compare: SKIP — no baseline/fresh file pairs "
                      "to compare; nothing to gate")
            else:
                print("bench_compare: nothing comparable "
                      "(config-mismatched fast run vs full baselines is "
                      "expected when suites do not overlap)")
        else:
            state = "REGRESSED" if verdict["regressed"] else "OK"
            print(f"bench_compare: {state} — overall geomean "
                  f"{verdict['geomean_ratio']:.3f}x over "
                  f"{len(all_ratios)} metrics "
                  f"(threshold {1 + args.threshold:.2f}x)")
    return 1 if verdict["regressed"] else 0


if __name__ == "__main__":
    sys.exit(main())
