#!/usr/bin/env python
"""Env-driven chaos soak: the CI chaos lane's entry point.

Runs a fixed mixed-tenant workload through the async service runtime
with whatever ``REPRO_FAULTS`` plan the environment installs (see
``repro.faults``), records a full span trace, and asserts the PR-8
robustness invariants:

  * the worker thread survives (watchdog restarts are fine, death isn't),
  * every job ends DONE — bit-identical to a fault-free reference run of
    the same workload — or FAILED with an explanatory ``error_payload``,
  * the admission ledger drains to zero (audited continuously when
    ``REPRO_SANITIZE=1``, asserted at the end regardless).

Exit status is non-zero on any violation; the Chrome trace is written to
``--trace-out`` either way so CI can attach it to failures.

    REPRO_FAULTS="1234:store.read@n=2:transient;runtime.quantum@n=3:crash" \
        REPRO_SANITIZE=1 python scripts/chaos_soak.py --trace-out chaos.json
"""
import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.tensor import SparseTensor
from repro.faults import inject
from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace
from repro.obs.slo import TelemetryExporter
from repro.service import ServiceRuntime, SubmitDecomposition, GetTrace

WORKLOAD = ((0, 1, "acme", 1.0), (1, 2, "umbrella", 2.0),
            (0, 3, "umbrella", 1.0))
RANK, ITERS = 8, 6


def _tensor(seed, nnz=500, dim=12):
    rng = np.random.default_rng(seed)
    return SparseTensor(
        indices=rng.integers(0, dim, size=(nnz, 3)).astype(np.int64),
        values=rng.standard_normal(nnz).astype(np.float32),
        dims=(dim, dim, dim))


def _run(store_dir, *, faults, export_jsonl=None, export_prom=None):
    """One workload pass; returns (outcome, metrics, trace, ok, exporter)."""
    ctx = inject.active(None) if not faults else _noop()
    exp_counters = None
    with ctx:
        with ServiceRuntime(device_budget_bytes=256 << 20,
                            store_dir=store_dir,
                            host_budget_bytes=1) as rt:
            exporter = None
            if export_jsonl is not None:
                # runs in its own daemon thread: worker crashes and
                # watchdog restarts must not interrupt the export cadence
                exporter = TelemetryExporter(rt, interval_s=0.2,
                                             jsonl_path=export_jsonl,
                                             prom_path=export_prom)
                exporter.start()
            try:
                ids = [rt.submit(SubmitDecomposition(
                    tensor=_tensor(ts), rank=RANK, iters=ITERS, tol=0.0,
                    seed=ss, tenant=tenant, weight=weight))
                    for ts, ss, tenant, weight in WORKLOAD]
                ok = rt.drain(timeout=600)
                out = {}
                for n, jid in enumerate(ids):
                    st = rt.status(jid)
                    if st.state == "done":
                        res = rt.result(jid).result
                        out[n] = ("done", [float(f) for f in res.fits], None)
                    else:
                        out[n] = (st.state, None, st.error_payload)
                metrics = rt.service_metrics()
                trace = rt.trace(GetTrace(drain=True))
                dead = rt._error is not None
            finally:
                if exporter is not None:
                    alive_at_stop = exporter.running
                    exporter.stop()
                    exp_counters = dict(exporter.counters(),
                                        alive_at_stop=alive_at_stop)
    return out, metrics, trace, ok and not dead, exp_counters


class _noop:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace-out", default="chaos_trace.json")
    ap.add_argument("--telemetry-out", default=None, metavar="JSONL",
                    help="run the TelemetryExporter against the faulted "
                         "runtime and write its JSONL feed here "
                         "(default: a temp file, kept only on request)")
    args = ap.parse_args()

    plan = inject.FAULTS.plan
    print(f"chaos soak: fault plan = {plan!r}")
    obs_trace.enable()

    with tempfile.TemporaryDirectory() as ref_dir:
        ref, ref_metrics, _, ref_ok, _ = _run(ref_dir, faults=False)
    if not ref_ok or any(v[0] != "done" for v in ref.values()):
        print("FATAL: fault-free reference run failed", file=sys.stderr)
        return 2

    jsonl_path = args.telemetry_out or os.path.join(
        tempfile.gettempdir(), f"chaos_telemetry_{os.getpid()}.jsonl")
    obs_ledger.clear()
    obs_ledger.enable()
    try:
        with tempfile.TemporaryDirectory() as store_dir:
            out, metrics, trace, alive, exp = _run(
                store_dir, faults=True, export_jsonl=jsonl_path,
                export_prom=jsonl_path + ".prom")
        ledger_snap = obs_ledger.snapshot()
    finally:
        obs_ledger.disable()

    with open(args.trace_out, "w") as f:
        json.dump(trace, f)
    print(f"trace: {len(trace.get('traceEvents', []))} events "
          f"-> {args.trace_out}")

    violations = []
    if not alive:
        violations.append("worker died (or drain timed out)")
    for n, (state, fits, payload) in sorted(out.items()):
        if state == "done":
            tag = "bit-identical" if fits == ref[n][1] else "DIVERGED"
            print(f"  job {n}: done, {tag}")
            if tag == "DIVERGED":
                violations.append(f"job {n} completed but diverged "
                                  f"from the fault-free reference")
        elif state == "failed" and payload:
            print(f"  job {n}: failed ({payload.get('type')}: "
                  f"{payload.get('message')})")
        else:
            violations.append(f"job {n} ended {state!r} without an "
                              f"explanatory payload")
    for key in ("retries_total", "giveups_total", "demotions_total",
                "watchdog_restarts", "store_rebuilds", "jobs_failed"):
        print(f"  {key} = {metrics[key]}")
    if metrics["admitted_reservation_bytes"] != 0:
        violations.append(
            f"ledger leak: admitted_reservation_bytes = "
            f"{metrics['admitted_reservation_bytes']}")

    # fault balance: under retries the transfer is re-attempted but both
    # the EngineStats counter and the bandwidth ledger record once, after
    # success; a giveup raises before either records.  Every job reaches a
    # terminal state here, so the retired-job byte totals must equal the
    # ledger's edge accounts exactly (integer byte counts — order-free).
    edges = ledger_snap.get("edges", {})
    for edge, stats_key in (("host_device", "h2d_bytes_total"),
                            ("disk_host", "disk_bytes_total")):
        lv = int(edges.get(edge, {}).get("bytes", 0))
        sv = int(metrics[stats_key])
        print(f"  ledger[{edge}].bytes = {lv}  ({stats_key} = {sv})")
        if lv != sv:
            violations.append(
                f"bandwidth ledger imbalance on {edge}: ledger {lv} B "
                f"!= {stats_key} {sv} B (double-count or drop under "
                f"faults)")

    # the exporter runs on its own thread: worker crashes + watchdog
    # restarts must not stop the telemetry cadence
    if exp is None:
        violations.append("telemetry exporter never ran")
    else:
        print(f"  telemetry: {exp['exports']} exports, "
              f"{exp['failures']} failures across "
              f"{metrics['watchdog_restarts']} worker restart(s) "
              f"-> {jsonl_path}")
        if exp["exports"] < 1:
            violations.append("telemetry exporter produced no exports")
        if exp["failures"]:
            violations.append(
                f"telemetry exporter recorded {exp['failures']} "
                f"failed export(s)")
        if not exp["alive_at_stop"]:
            violations.append("telemetry exporter thread died before "
                              "shutdown (did not survive the soak)")
    if not args.telemetry_out:
        for p in (jsonl_path, jsonl_path + ".prom"):
            try:
                os.unlink(p)
            except OSError:
                pass

    if violations:
        print("CHAOS SOAK FAILED:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print("chaos soak clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
