#!/usr/bin/env bash
# Repo check: tier-1 tests + a multi-tenant service smoke run.
#
#   scripts/check.sh            # full tier-1 suite + service smoke
#   scripts/check.sh --fast     # service/streaming/cp-als tests + smoke only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repro-lint (blocking) =="
python scripts/lint.py

echo "== trace-tier verifiers (blocking) =="
python scripts/lint.py --tier=trace

if [[ "${1:-}" == "--fast" ]]; then
    python -m pytest -x -q tests/test_service.py tests/test_streaming.py \
        tests/test_cp_als.py
else
    python -m pytest -x -q
fi

echo "== service smoke (examples/serve_td.py) =="
python examples/serve_td.py

# Perf-regression gate (non-blocking here; CI runs the blocking variant):
# a fresh fast benchmark run diffed against the committed BENCH_*.json.
# CI sets REPRO_SKIP_BENCH_COMPARE=1 because it runs its own blocking
# compare on the same fast run right after check.sh.
if [[ "${REPRO_SKIP_BENCH_COMPARE:-}" == "1" ]]; then
    echo "== bench compare skipped (REPRO_SKIP_BENCH_COMPARE=1) =="
    echo "ALL CHECKS PASSED"
    exit 0
fi
echo "== bench compare (non-blocking) =="
FRESH_DIR=$(mktemp -d)
if python benchmarks/run.py --fast \
        --json "$FRESH_DIR/BENCH_3.json" \
        --mt-json "$FRESH_DIR/BENCH_4.json" \
        --oom-json "$FRESH_DIR/BENCH_5.json" \
        --obs-json "$FRESH_DIR/BENCH_6.json" \
        --trace-json "$FRESH_DIR/TRACE_6.json" \
        --roofline-json "$FRESH_DIR/BENCH_7.json" > "$FRESH_DIR/bench.log" 2>&1
then
    python scripts/bench_compare.py --fresh-dir "$FRESH_DIR" \
        || echo "bench_compare: regression reported (non-blocking in check.sh)"
else
    echo "bench_compare: fast benchmark run failed (non-blocking); see $FRESH_DIR/bench.log"
fi

echo "ALL CHECKS PASSED"
