#!/usr/bin/env bash
# Repo check: tier-1 tests + a multi-tenant service smoke run.
#
#   scripts/check.sh            # full tier-1 suite + service smoke
#   scripts/check.sh --fast     # service/streaming/cp-als tests + smoke only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    python -m pytest -x -q tests/test_service.py tests/test_streaming.py \
        tests/test_cp_als.py
else
    python -m pytest -x -q
fi

echo "== service smoke (examples/serve_td.py) =="
python examples/serve_td.py

echo "ALL CHECKS PASSED"
