#!/usr/bin/env python
"""repro-lint: run the repo's static-analysis tiers (see repro.analysis).

Usage:
    python scripts/lint.py [paths...] [--tier ast|trace|all]
                           [--baseline scripts/lint_baseline.json]
                           [--format text|json] [--write-baseline]
                           [--prune-baseline] [--report-out PATH] [--list]

Tiers:
    ast    (default) the AST lint passes over Python source;
    trace  the trace-tier verifiers: jaxpr audits of the registered hot
           paths, cache-key churn, symbolic BLCO encoding proofs and the
           fused kernel's write-conflict prover (imports jax);
    all    both.

Default paths: src/repro (AST tier only — the trace tier audits the
registered hot paths, not a path list).  Exit status 1 when any finding
is not covered by the committed baseline (or an inline ``# repro-lint:
disable=<pass>`` comment), or when the baseline carries STALE entries —
suppressions whose finding no longer exists must be removed, which
``--prune-baseline`` does in place.  ``--write-baseline`` records the
current findings as the new baseline — entries are stamped with a
placeholder reason that MUST be replaced with a real justification
before committing.  ``--report-out`` writes the trace tier's artifact
bundle (conflict report, encoding proofs, verifier metrics) as JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import Baseline, all_passes, lint_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(REPO_ROOT, "src", "repro")])
    ap.add_argument("--tier", choices=("ast", "trace", "all"),
                    default="ast",
                    help="which analysis tier(s) to run (default: ast)")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT, "scripts",
                                         "lint_baseline.json"))
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as the suppression "
                         "baseline (justify every entry before committing)")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="rewrite the baseline dropping stale entries "
                         "(suppressions whose finding no longer exists)")
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="write the trace tier's artifact bundle (conflict "
                         "report + encoding proofs + metrics) as JSON")
    ap.add_argument("--list", action="store_true",
                    help="list the registered passes and exit")
    args = ap.parse_args(argv)

    if args.list:
        for p in all_passes():
            print(f"{p.pass_id:24s} {p.description}")
        if args.tier in ("trace", "all"):
            from repro.analysis.trace import TRACE_PASS_IDS
            for pid in TRACE_PASS_IDS:
                print(f"{pid:24s} (trace tier)")
        return 0

    findings = []
    ran_pass_ids = set()
    if args.tier in ("ast", "all"):
        findings.extend(lint_paths(args.paths, root=REPO_ROOT))
        ran_pass_ids |= {p.pass_id for p in all_passes()}
    bundle = None
    if args.tier in ("trace", "all"):
        from repro.analysis import run_trace_tier
        from repro.analysis.trace import TRACE_PASS_IDS
        trace_findings, bundle, _metrics = run_trace_tier()
        findings.extend(trace_findings)
        ran_pass_ids |= set(TRACE_PASS_IDS)

    if args.report_out and bundle is not None:
        with open(args.report_out, "w", encoding="utf-8") as fh:
            json.dump(bundle, fh, indent=2, sort_keys=True)
            fh.write("\n")

    if args.write_baseline:
        Baseline.from_findings(
            findings,
            reason="TODO: justify or fix (recorded by --write-baseline)",
        ).save(args.baseline)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = Baseline([]) if args.no_baseline else Baseline.load(
        args.baseline)
    unsuppressed = [f for f in findings if not baseline.suppresses(f)]
    # only entries for the tier(s) that actually ran can be judged stale —
    # an AST-tier suppression is not stale just because only the trace
    # tier was invoked
    stale = [e for e in baseline.stale_entries(findings)
             if e["pass"] in ran_pass_ids]

    if args.prune_baseline:
        if stale:
            keep = [e for e in baseline.entries if e not in stale]
            Baseline(keep).save(args.baseline)
            print(f"pruned {len(stale)} stale entr"
                  f"{'y' if len(stale) == 1 else 'ies'} from "
                  f"{args.baseline}")
        else:
            print(f"no stale entries in {args.baseline}")
        stale = []

    if args.format == "json":
        print(json.dumps({
            "findings": [vars(f) for f in unsuppressed],
            "suppressed": len(findings) - len(unsuppressed),
            "stale_baseline_entries": stale,
        }, indent=2))
    else:
        for f in unsuppressed:
            print(f.render())
        for e in stale:
            print(f"error: stale baseline entry "
                  f"{e['pass']}:{e['path']}:{e['symbol']} — the finding it "
                  f"suppressed no longer exists; run --prune-baseline")
        n_sup = len(findings) - len(unsuppressed)
        print(f"repro-lint[{args.tier}]: {len(unsuppressed)} finding(s), "
              f"{n_sup} baseline-suppressed, {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}")
    return 1 if (unsuppressed or stale) else 0


if __name__ == "__main__":
    raise SystemExit(main())
