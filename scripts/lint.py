#!/usr/bin/env python
"""repro-lint: run the repo's AST lint passes (see repro.analysis).

Usage:
    python scripts/lint.py [paths...] [--baseline scripts/lint_baseline.json]
                           [--format text|json] [--write-baseline] [--list]

Default paths: src/repro.  Exit status 1 when any finding is not covered
by the committed baseline (or an inline ``# repro-lint: disable=<pass>``
comment), 0 otherwise.  ``--write-baseline`` records the current findings
as the new baseline — entries are stamped with a placeholder reason that
MUST be replaced with a real justification before committing.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import Baseline, all_passes, lint_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(REPO_ROOT, "src", "repro")])
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT, "scripts",
                                         "lint_baseline.json"))
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as the suppression "
                         "baseline (justify every entry before committing)")
    ap.add_argument("--list", action="store_true",
                    help="list the registered passes and exit")
    args = ap.parse_args(argv)

    if args.list:
        for p in all_passes():
            print(f"{p.pass_id:24s} {p.description}")
        return 0

    findings = lint_paths(args.paths, root=REPO_ROOT)

    if args.write_baseline:
        Baseline.from_findings(
            findings,
            reason="TODO: justify or fix (recorded by --write-baseline)",
        ).save(args.baseline)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = Baseline([]) if args.no_baseline else Baseline.load(
        args.baseline)
    unsuppressed = [f for f in findings if not baseline.suppresses(f)]
    stale = baseline.stale_entries(findings)

    if args.format == "json":
        print(json.dumps({
            "findings": [vars(f) for f in unsuppressed],
            "suppressed": len(findings) - len(unsuppressed),
            "stale_baseline_entries": stale,
        }, indent=2))
    else:
        for f in unsuppressed:
            print(f.render())
        for e in stale:
            print(f"warning: stale baseline entry "
                  f"{e['pass']}:{e['path']}:{e['symbol']} — the finding it "
                  f"suppressed no longer exists; remove it")
        n_sup = len(findings) - len(unsuppressed)
        print(f"repro-lint: {len(unsuppressed)} finding(s), "
              f"{n_sup} baseline-suppressed, {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}")
    return 1 if unsuppressed else 0


if __name__ == "__main__":
    raise SystemExit(main())
