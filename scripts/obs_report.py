#!/usr/bin/env python
"""Render the roofline attribution table from a BENCH_7 payload.

Usage::

    python scripts/obs_report.py [BENCH_7.json] [--json]

Reads the committed (or CI-fresh) ``BENCH_7.json`` and prints a
per-regime, per-edge table: bytes moved, seconds, achieved GB/s, the
measured ceiling, achieved fraction, plus each regime's arithmetic
intensity / bound classification and saturated edge.  ``--json`` emits
the condensed machine-readable report instead (for artifact diffing).

Exit codes: 0 on success, 2 when the payload is missing/unreadable.
"""
from __future__ import annotations

import argparse
import json
import sys

EDGE_ORDER = ("disk_host", "host_device", "device_hbm")


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1000.0 or unit == "TB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1000.0
    return f"{n:.1f}TB"


def condensed(payload: dict) -> dict:
    """The machine-readable core of the report (stable keys)."""
    return {
        "tensor": payload.get("tensor"),
        "fast_mode": payload.get("fast_mode"),
        "peak_gb_per_s": payload.get("peak_gb_per_s", {}),
        "achieved_fraction": payload.get("achieved_fraction", {}),
        "saturated_edge": payload.get("saturated_edge", {}),
        "bound": payload.get("bound", {}),
        "max_edge_rel_err": payload.get("max_edge_rel_err"),
        "obs_enabled_overhead_frac":
            payload.get("obs_enabled_overhead_frac"),
    }


def render(payload: dict) -> str:
    report = payload.get("roofline", {})
    regimes = report.get("regimes", {})
    peaks = payload.get("peak_gb_per_s", {})
    lines = []
    lines.append(f"Roofline attribution — {payload.get('tensor', '?')} "
                 f"(rank {payload.get('rank', '?')}, "
                 f"{payload.get('launches', '?')} launches, "
                 f"backend {payload.get('backend', '?')})")
    lines.append(f"peaks: " + "  ".join(
        f"{e}={peaks.get(e, 0.0):.2f}GB/s" for e in EDGE_ORDER if e in peaks))
    lines.append("")
    hdr = (f"{'regime':<14} {'edge':<12} {'bytes':>10} {'seconds':>10} "
           f"{'GB/s':>8} {'peak':>8} {'frac':>6}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for regime, rep in regimes.items():
        for edge in EDGE_ORDER:
            er = rep.get("edges", {}).get(edge)
            if er is None or er.get("seconds", 0.0) <= 0.0:
                continue
            frac = er.get("achieved_fraction")
            sat = " <- saturated" \
                if payload.get("saturated_edge", {}).get(regime) == edge \
                else ""
            lines.append(
                f"{regime:<14} {edge:<12} {_fmt_bytes(er['bytes']):>10} "
                f"{er['seconds']:>10.4f} {er['gb_per_s']:>8.2f} "
                f"{er.get('peak_gb_per_s', 0.0):>8.2f} "
                f"{(f'{frac*100:.0f}%' if frac is not None else '-'):>6}"
                f"{sat}")
        lines.append(
            f"{regime:<14} {'(classify)':<12} "
            f"AI={rep.get('arithmetic_intensity', 0.0):.3f} flops/B -> "
            f"{rep.get('bound', 'unknown')}")
    lines.append("")
    err = payload.get("max_edge_rel_err")
    lines.append(f"ledger conservation: max_edge_rel_err={err!r} "
                 f"({payload.get('conservation_checks', '?')} checks)")
    ov = payload.get("obs_enabled_overhead_frac")
    if ov is not None:
        lines.append(f"tracing+ledger overhead (in-memory path): "
                     f"{ov*100:+.2f}%")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("payload", nargs="?", default="BENCH_7.json",
                    help="BENCH_7 payload path (default: BENCH_7.json)")
    ap.add_argument("--json", action="store_true",
                    help="emit the condensed machine-readable report")
    args = ap.parse_args(argv)
    try:
        with open(args.payload, encoding="utf-8") as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"obs_report: cannot read {args.payload}: {exc}",
              file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(condensed(payload), indent=2, sort_keys=True))
    else:
        sys.stdout.write(render(payload))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
