"""Repo-specific static analysis + runtime sanitizer.

``repro.analysis`` mechanizes the invariants earlier PRs fixed by hand so
they are checked by tooling instead of reviewer memory:

* :mod:`repro.analysis.linter` — an AST lint framework with passes traced
  to shipped bug classes (silent dtype downcasts, host sync in hot paths,
  unfenced device timing, lock discipline, span hygiene).  Run it via
  ``scripts/lint.py``.
* :mod:`repro.analysis.sanitize` — a runtime sanitizer
  (``REPRO_SANITIZE=1`` or ``plan_for(..., sanitize=True)``) wrapping any
  ExecutionPlan with shape/dtype/finiteness contracts, plus ledger audits
  and lock-ownership assertions inside the service.
* :mod:`repro.analysis.trace` — the trace tier: jaxpr audits of the
  registered hot paths (host callbacks, dtype narrowing, cache-key
  churn), symbolic BLCO encoding proofs, and the fused kernel's
  write-conflict prover.  Run it via ``scripts/lint.py --tier=trace``.
  Imported lazily (``run_trace_tier``) so the AST tier stays jax-free.
"""
from .linter import (Baseline, Finding, LintPass, ParsedModule,  # noqa: F401
                     all_passes, lint_paths, lint_sources)
from .sanitize import (SanitizedPlan, SanitizerError,  # noqa: F401
                       sanitize_enabled, sanitized, wrap_plan)


def run_trace_tier(**kwargs):
    """Lazy entry to :func:`repro.analysis.trace.run_trace_tier` (imports
    jax only when the trace tier actually runs)."""
    from .trace import run_trace_tier as _run
    return _run(**kwargs)


__all__ = [
    "Baseline", "Finding", "LintPass", "ParsedModule", "all_passes",
    "lint_paths", "lint_sources", "run_trace_tier",
    "SanitizedPlan", "SanitizerError", "sanitize_enabled", "sanitized",
    "wrap_plan",
]
