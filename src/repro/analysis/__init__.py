"""Repo-specific static analysis + runtime sanitizer.

``repro.analysis`` mechanizes the invariants earlier PRs fixed by hand so
they are checked by tooling instead of reviewer memory:

* :mod:`repro.analysis.linter` — an AST lint framework with passes traced
  to shipped bug classes (silent dtype downcasts, host sync in hot paths,
  unfenced device timing, lock discipline, span hygiene).  Run it via
  ``scripts/lint.py``.
* :mod:`repro.analysis.sanitize` — a runtime sanitizer
  (``REPRO_SANITIZE=1`` or ``plan_for(..., sanitize=True)``) wrapping any
  ExecutionPlan with shape/dtype/finiteness contracts, plus ledger audits
  and lock-ownership assertions inside the service.
"""
from .linter import (Baseline, Finding, LintPass, ParsedModule,  # noqa: F401
                     all_passes, lint_paths, lint_sources)
from .sanitize import (SanitizedPlan, SanitizerError,  # noqa: F401
                       sanitize_enabled, sanitized, wrap_plan)

__all__ = [
    "Baseline", "Finding", "LintPass", "ParsedModule", "all_passes",
    "lint_paths", "lint_sources",
    "SanitizedPlan", "SanitizerError", "sanitize_enabled", "sanitized",
    "wrap_plan",
]
