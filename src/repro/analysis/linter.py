"""AST lint framework: repo-specific passes over parsed source modules.

Each :class:`LintPass` encodes ONE invariant a shipped PR fixed by hand
(see ``repro.analysis.passes``) and reports :class:`Finding`\\ s.  Findings
are keyed by ``(pass_id, path, enclosing-symbol)`` — not line numbers — so
a committed suppression baseline survives unrelated edits that shift
lines.  Two suppression mechanisms:

* a **baseline** file (JSON): reviewed, justified findings that predate
  the pass or are intentional; every entry must carry a ``reason``;
* an **inline comment** ``# repro-lint: disable=<pass-id>`` on the
  offending line or on the enclosing ``def``/``class`` line.

``lint_paths`` is the everything-wired entry point ``scripts/lint.py``
calls; ``lint_sources`` takes in-memory sources for fixture tests.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re

_DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w,\-]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, stable-keyed for baseline suppression."""
    pass_id: str
    path: str            # repo-relative, forward slashes
    symbol: str          # enclosing qualname ("<module>" at top level)
    line: int
    message: str

    @property
    def key(self) -> str:
        return f"{self.pass_id}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.pass_id}] {self.symbol}: "
                f"{self.message}")


class ParsedModule:
    """A parsed source file plus the symbol/suppression maps passes need."""

    def __init__(self, path: str, source: str):
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # enclosing qualname per AST node, computed once for every pass
        self._qualname: dict[ast.AST, str] = {}
        self._assign_qualnames(self.tree, [])
        # lines carrying "# repro-lint: disable=<pass>" -> set of pass ids
        self.disabled: dict[int, set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _DISABLE_RE.search(text)
            if m:
                self.disabled[i] = set(m.group(1).split(","))

    def _assign_qualnames(self, node: ast.AST, stack: list[str]) -> None:
        name = ".".join(stack) if stack else "<module>"
        self._qualname[node] = name
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                self._assign_qualnames(child, stack + [child.name])
            else:
                self._assign_qualnames(child, stack)

    def qualname(self, node: ast.AST) -> str:
        """Qualname of the scope ``node`` belongs to; def/class nodes map
        to their OWN qualified name, so a finding on a ``def`` line blames
        that function."""
        return self._qualname.get(node, "<module>")

    def functions(self):
        """Every (qualname, def-node) pair, outermost first."""
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield self.qualname(node), node

    def outer_functions(self):
        """Top-level functions and methods, with nested defs folded in.

        Yields only defs whose enclosing scopes are modules or classes —
        a closure nested inside a function is analysed as part of its
        outermost enclosing function (timing/fencing invariants hold for
        the outer call, not each helper in isolation).
        """
        def _walk(node, in_function):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if not in_function:
                        yield self.qualname(child), child
                    yield from _walk(child, True)
                elif isinstance(child, ast.ClassDef):
                    yield from _walk(child, in_function)
                else:
                    yield from _walk(child, in_function)
        yield from _walk(self.tree, False)

    def is_disabled(self, pass_id: str, node: ast.AST,
                    scope: ast.AST | None = None) -> bool:
        """True when the finding line (or its enclosing def line) carries
        an inline ``# repro-lint: disable=`` comment for ``pass_id``."""
        for n in (node, scope):
            if n is None or not hasattr(n, "lineno"):
                continue
            ids = self.disabled.get(n.lineno)
            if ids and (pass_id in ids or "all" in ids):
                return True
        return False

    def finding(self, pass_id: str, node: ast.AST, message: str,
                scope: ast.AST | None = None) -> Finding:
        symbol = self.qualname(scope if scope is not None else node)
        return Finding(pass_id=pass_id, path=self.path, symbol=symbol,
                       line=getattr(node, "lineno", 0), message=message)


class LintPass:
    """Base class: one invariant, one ``run`` over a parsed module."""

    pass_id = "base"
    description = ""
    #: path fragments the pass is scoped to; empty = every file
    scope: tuple = ()

    def applies(self, module: ParsedModule) -> bool:
        if not self.scope:
            return True
        return any(frag in module.path for frag in self.scope)

    def run(self, module: ParsedModule) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def check(self, module: ParsedModule) -> list[Finding]:
        if not self.applies(module):
            return []
        return self.run(module)


class Baseline:
    """Committed suppression list: reviewed findings with justifications.

    Entries match findings by stable key (pass + path + symbol), so line
    drift never invalidates them.  ``reason`` is mandatory — an entry
    without one fails loading, which is what keeps the baseline honest
    ("only justified entries" is enforced, not hoped for).
    """

    def __init__(self, entries: list[dict] | None = None):
        self.entries = list(entries or [])
        for e in self.entries:
            for field in ("pass", "path", "symbol", "reason"):
                if not e.get(field):
                    raise ValueError(
                        f"baseline entry {e!r} is missing {field!r}; every "
                        f"suppression must name its finding and justify it")
        self._keys = {f"{e['pass']}:{e['path']}:{e['symbol']}"
                      for e in self.entries}

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([])
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
        return cls(data.get("suppressions", []))

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump({"suppressions": self.entries}, fh, indent=2,
                      sort_keys=True)
            fh.write("\n")

    def suppresses(self, finding: Finding) -> bool:
        return finding.key in self._keys

    def stale_entries(self, findings: list[Finding]) -> list[dict]:
        """Entries matching nothing any more — fixed code should shed its
        suppressions rather than accumulate dead ones."""
        live = {f.key for f in findings}
        return [e for e in self.entries
                if f"{e['pass']}:{e['path']}:{e['symbol']}" not in live]

    @classmethod
    def from_findings(cls, findings: list[Finding],
                      reason: str = "baselined pre-existing finding"
                      ) -> "Baseline":
        seen, entries = set(), []
        for f in sorted(findings, key=lambda f: f.key):
            if f.key in seen:
                continue
            seen.add(f.key)
            entries.append({"pass": f.pass_id, "path": f.path,
                            "symbol": f.symbol, "reason": reason})
        return cls(entries)


def all_passes() -> list[LintPass]:
    """The registered repo passes (import deferred to avoid cycles)."""
    from .passes import REGISTRY
    return [cls() for cls in REGISTRY]


def _iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)


def lint_sources(sources: dict[str, str],
                 passes: list[LintPass] | None = None) -> list[Finding]:
    """Lint in-memory ``{path: source}`` pairs (the fixture-test path)."""
    passes = passes if passes is not None else all_passes()
    findings: list[Finding] = []
    for path, source in sources.items():
        module = ParsedModule(path, source)
        for p in passes:
            findings.extend(p.check(module))
    findings.sort(key=lambda f: (f.path, f.line, f.pass_id))
    return findings


def lint_paths(paths, *, root: str | None = None,
               passes: list[LintPass] | None = None) -> list[Finding]:
    """Lint files/directories; paths in findings are relative to ``root``."""
    sources = {}
    for fpath in _iter_py_files(paths):
        rel = os.path.relpath(fpath, root) if root else fpath
        with open(fpath, encoding="utf-8") as fh:
            sources[rel] = fh.read()
    return lint_sources(sources, passes=passes)
