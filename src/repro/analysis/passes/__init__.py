"""The registered repo-specific lint passes.

Each pass mechanizes one invariant a shipped PR fixed by hand; see the
individual modules for the bug class each one traces to.
"""
from .dtype_promotion import DtypePromotionPass
from .fault_site_hygiene import FaultSiteHygienePass
from .host_sync import HostSyncPass
from .lock_discipline import LockDisciplinePass
from .span_hygiene import SpanHygienePass
from .unfenced_timing import UnfencedTimingPass

REGISTRY = [
    DtypePromotionPass,
    HostSyncPass,
    UnfencedTimingPass,
    LockDisciplinePass,
    SpanHygienePass,
    FaultSiteHygienePass,
]

__all__ = ["REGISTRY", "DtypePromotionPass", "FaultSiteHygienePass",
           "HostSyncPass", "UnfencedTimingPass", "LockDisciplinePass",
           "SpanHygienePass"]
