"""Small AST helpers shared by the lint passes."""
from __future__ import annotations

import ast


def root_name(node: ast.AST) -> str | None:
    """The base ``Name`` id of an attribute/subscript chain.

    ``factors[0].dtype`` -> ``factors``; ``self._lock`` -> ``self``.
    """
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def call_name(call: ast.Call) -> str:
    """The trailing name of the called expression (``a.b.c()`` -> ``c``)."""
    func = call.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def dotted(node: ast.AST) -> str:
    """Dotted rendering of a Name/Attribute chain (best-effort)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def self_attr(node: ast.AST) -> str | None:
    """``self.X`` -> ``"X"`` (one level only), else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def walk_calls(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub
