"""dtype-promotion pass: no factor-dtype casts/allocations on mttkrp paths.

Bug class (PR 4): MTTKRP paths that cast the tensor values — or allocate
the accumulator — with ``factors[0].dtype`` silently downcast float64
tensor values against float32 factors.  The repo-wide idiom is

    out_dtype = jnp.result_type(vals, factors[0])

so the accumulation runs at the promoted precision.  This pass flags, in
any function whose qualname mentions ``mttkrp`` or ``hadamard``:

* ``x.astype(<factor>.dtype)``;
* array creation (``zeros``/``ones``/``empty``/``full``) whose dtype
  argument is ``<factor>.dtype``;
* ``ShapeDtypeStruct(..., <factor>.dtype)`` kernel out-shapes;

where ``<factor>`` is a factor-matrix spelling (``factors``, ``gathered``,
...).  Expressions routed through ``jnp.result_type`` never match — the
dtype argument is then a Call, not a bare ``.dtype`` attribute.
"""
from __future__ import annotations

import ast

from ..linter import Finding, LintPass, ParsedModule
from .common import call_name, root_name

FACTOR_NAMES = frozenset({
    "factors", "factor", "f_refs", "fs", "gathered", "others", "mats",
})

CREATION_FUNCS = frozenset({"zeros", "ones", "empty", "full"})

PASS_ID = "dtype-promotion"


def _factor_dtype_expr(node: ast.AST) -> str | None:
    """``factors[0].dtype``-shaped expression -> its factor root name."""
    if isinstance(node, ast.Attribute) and node.attr == "dtype":
        root = root_name(node.value)
        if root in FACTOR_NAMES:
            return root
    return None


def _call_args(call: ast.Call):
    yield from call.args
    for kw in call.keywords:
        if kw.arg is not None:      # skip **kwargs
            yield kw.value


class DtypePromotionPass(LintPass):
    pass_id = PASS_ID
    description = ("factor-dtype cast/allocation on an mttkrp path; "
                   "promote with jnp.result_type(vals, factors[...])")
    scope = ()                      # dtype discipline applies everywhere

    def run(self, module: ParsedModule) -> list[Finding]:
        findings: list[Finding] = []
        seen: set[tuple] = set()    # a nested def is walked by its outer
        for qualname, fn in module.functions():
            low = qualname.lower()
            if "mttkrp" not in low and "hadamard" not in low:
                continue
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                name = call_name(call)
                hit = None
                if name == "astype" and call.args:
                    root = _factor_dtype_expr(call.args[0])
                    if root is not None:
                        hit = (f"astype({root}[...].dtype) downcasts the "
                               f"values operand")
                elif name in CREATION_FUNCS or name == "ShapeDtypeStruct":
                    for arg in _call_args(call):
                        root = _factor_dtype_expr(arg)
                        if root is not None:
                            hit = (f"{name}(..., {root}[...].dtype) pins the "
                                   f"output to the factor dtype")
                            break
                if hit is None:
                    continue
                loc = (call.lineno, call.col_offset)
                if loc in seen:
                    continue        # already reported from the enclosing def
                seen.add(loc)
                if module.is_disabled(self.pass_id, call, fn):
                    continue
                findings.append(module.finding(
                    self.pass_id, call,
                    f"{hit}; use jnp.result_type(vals, factors[...]) so "
                    f"f64 values are not silently downcast (PR-4 bug class)",
                    scope=fn))
        return findings
