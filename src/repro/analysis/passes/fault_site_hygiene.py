"""fault-site-hygiene pass: fault probes must name declared sites.

Bug class (PR 8 fault injection): ``faults.fire("...")`` /
``faults.maybe_fail("...")`` look up the site string in
``repro.faults.inject.SITES`` at *fire* time — but only when a plan is
installed.  A typo'd site at a probe point is therefore invisible in
normal operation (the disabled fast path never validates) and turns a
chaos-test scenario into a silent no-op: the fault "injected" at
``store.raed`` never fires and the test vacuously passes.  This pass
checks every string-literal site argument against the declared ``SITES``
table statically, so a misspelled probe fails CI instead of weakening
the chaos suite.

Non-literal site arguments (a variable, an f-string) are skipped — they
are the injection framework's own plumbing, which validates at runtime.
"""
from __future__ import annotations

import ast

from ..linter import Finding, LintPass, ParsedModule
from .common import call_name, root_name

PASS_ID = "fault-site-hygiene"

_PROBES = ("fire", "maybe_fail", "exception_for")


def _declared_sites() -> frozenset:
    from repro.faults.inject import SITES
    return frozenset(SITES)


class FaultSiteHygienePass(LintPass):
    pass_id = PASS_ID
    description = "fault probe names an undeclared injection site"
    scope = ()

    def applies(self, module: ParsedModule) -> bool:
        return not module.path.endswith("faults/inject.py")

    def run(self, module: ParsedModule) -> list[Finding]:
        sites = _declared_sites()
        findings: list[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            if call_name(node) not in _PROBES:
                continue
            # only the injection module's probes: faults.fire(...),
            # inject.maybe_fail(...), or a bare import of those names
            if isinstance(node.func, ast.Attribute):
                root = (root_name(node.func) or "").lower()
                if not ("fault" in root or "inject" in root):
                    continue
            if not node.args:
                continue
            site = node.args[0]
            if not (isinstance(site, ast.Constant)
                    and isinstance(site.value, str)):
                continue            # runtime-validated plumbing
            if site.value in sites:
                continue
            if module.is_disabled(self.pass_id, node):
                continue
            findings.append(module.finding(
                self.pass_id, node,
                f"fault site {site.value!r} is not declared in "
                f"repro.faults.inject.SITES — the probe can never fire"))
        return findings
