"""host-sync pass: no host round-trips inside device-dispatched code.

Bug class (PRs 2-3): the per-launch Python loop + per-call host numpy
padding the launch cache replaced.  Code that executes under ``jax.jit``,
as a ``lax.scan`` body, or as a ``pallas_call`` kernel must not touch host
numpy (``np.*``), force device->host syncs (``.tolist()`` / ``.item()``),
or loop in Python over launch/chunk sequences — each of those serializes
the dispatch pipeline the whole design exists to keep async.

Scope: the hot dispatch layers (``core/launches.py``, ``engine/plans.py``,
``kernels/``).  "Hot" functions are found structurally: decorated with a
``jit`` (directly or through ``functools.partial``), referenced inside a
``pallas_call``, or passed to a ``.scan``.
"""
from __future__ import annotations

import ast

from ..linter import Finding, LintPass, ParsedModule
from .common import call_name, dotted, root_name

PASS_ID = "host-sync-in-hot-path"

HOST_MODULES = frozenset({"np", "numpy"})
SYNC_METHODS = frozenset({"tolist", "item"})
LOOP_HINTS = ("launch", "chunk")


def _decorated_with_jit(fn) -> bool:
    for dec in fn.decorator_list:
        for node in ast.walk(dec):
            if isinstance(node, ast.Attribute) and node.attr == "jit":
                return True
            if isinstance(node, ast.Name) and node.id == "jit":
                return True
    return False


def _hot_function_names(tree: ast.AST) -> set[str]:
    """Names referenced inside pallas_call/scan call sites (kernel bodies
    and scan bodies are hot transitively through those call expressions)."""
    hot: set[str] = set()
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        if call_name(call) in ("pallas_call", "scan"):
            for sub in ast.walk(call):
                if isinstance(sub, ast.Name):
                    hot.add(sub.id)
    return hot


class HostSyncPass(LintPass):
    pass_id = PASS_ID
    description = ("host numpy / sync / Python launch loop inside a "
                   "jitted, scanned, or pallas-dispatched function")
    scope = ("core/launches.py", "engine/plans.py", "kernels/")

    def run(self, module: ParsedModule) -> list[Finding]:
        findings: list[Finding] = []
        seen: set[tuple] = set()    # a nested def is walked by its outer
        hot_names = _hot_function_names(module.tree)
        for qualname, fn in module.functions():
            if not (_decorated_with_jit(fn) or fn.name in hot_names):
                continue
            for node in ast.walk(fn):
                msg = None
                if isinstance(node, ast.Call):
                    name = call_name(node)
                    if isinstance(node.func, ast.Attribute) and \
                            root_name(node.func) in HOST_MODULES:
                        msg = (f"host numpy call {dotted(node.func)}() in a "
                               f"device-dispatched function")
                    elif name in SYNC_METHODS and \
                            isinstance(node.func, ast.Attribute):
                        msg = (f".{name}() forces a device->host sync in a "
                               f"device-dispatched function")
                elif isinstance(node, (ast.For, ast.AsyncFor)):
                    it = dotted(node.iter) if not isinstance(node.iter,
                                                             ast.Call) \
                        else call_name(node.iter)
                    if any(h in (it or "").lower() for h in LOOP_HINTS):
                        msg = (f"Python loop over {it!r} in a "
                               f"device-dispatched function — launches must "
                               f"go through the scan/stacked path")
                if msg is None:
                    continue
                loc = (node.lineno, node.col_offset)
                if loc in seen:
                    continue
                seen.add(loc)
                if module.is_disabled(self.pass_id, node, fn):
                    continue
                findings.append(module.finding(self.pass_id, node, msg,
                                               scope=fn))
        return findings
