"""lock-discipline pass: state guarded somewhere must be guarded everywhere.

Bug class (PRs 4-6): the threaded service (``ServiceRuntime`` worker +
caller threads) synchronizes on ``self._lock``; an attribute written under
the lock in one method but read or written without it elsewhere is a data
race waiting for a scheduler interleaving.  Two structural rules:

* **classes** — for every class that creates a ``threading.Lock``/
  ``RLock`` on ``self`` (conditions built over it count as aliases), any
  attribute *written* inside a ``with self._lock:`` block anywhere becomes
  lock-guarded state; accesses to it outside a guarded block (in any
  method except ``__init__``, which runs before the object is shared) are
  flagged;
* **module singletons** — for a module-level ``STATE = SomeClass()``
  whose class carries a ``.lock``, *writes* to ``STATE.attr`` outside
  ``with STATE.lock:`` are flagged.  Reads stay free: the tracer's hot
  path reads ``TRACING.enabled`` lock-free by design, and a stale read
  of a monotonic flag is benign where a torn write sequence is not.

Known limitation (documented, deliberate): an attribute *never* written
under the lock is invisible to rule one — the pass learns what is shared
state from the code's own locking, it does not infer sharing.
"""
from __future__ import annotations

import ast

from ..linter import Finding, LintPass, ParsedModule
from .common import dotted, self_attr

PASS_ID = "lock-discipline"

_LOCK_CTORS = frozenset({"Lock", "RLock"})
_ALIAS_CTORS = frozenset({"Condition"})


def _ctor_name(node: ast.AST) -> str | None:
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute):
            return f.attr
        if isinstance(f, ast.Name):
            return f.id
    return None


def _methods(cls: ast.ClassDef):
    for child in cls.body:
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield child


def _guarded_nodes(scope: ast.AST, is_lock_expr) -> set[ast.AST]:
    """All AST nodes lexically inside a ``with <lock>:`` block."""
    guarded: set[ast.AST] = set()
    for node in ast.walk(scope):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        if not any(is_lock_expr(item.context_expr) for item in node.items):
            continue
        for sub in ast.walk(node):
            guarded.add(sub)
    return guarded


#: method calls that mutate their receiver in place (container mutation is
#: a write for locking purposes: ``self._feeds.append(...)``)
_MUTATORS = frozenset({"append", "remove", "clear", "pop", "extend", "add",
                       "update", "discard", "insert", "popleft",
                       "appendleft"})


def _attr_writes(scope: ast.AST):
    """Yield (attr-name-node, node) for attribute writes: assignment
    targets plus in-place container mutations."""
    for node in ast.walk(scope):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS and \
                isinstance(node.func.value, ast.Attribute):
            targets = [node.func.value]
        for tgt in targets:
            for leaf in ast.walk(tgt):
                if isinstance(leaf, ast.Attribute):
                    yield leaf, node


class LockDisciplinePass(LintPass):
    pass_id = PASS_ID
    description = ("attribute guarded by self._lock in one method but "
                   "accessed without it elsewhere")
    scope = ()

    # ----------------------------------------------------------- rule one
    def _check_class(self, module: ParsedModule,
                     cls: ast.ClassDef) -> list[Finding]:
        locks: set[str] = set()
        for meth in _methods(cls):
            for leaf, node in _attr_writes(meth):
                name = self_attr(leaf)
                if name is None or not isinstance(node, ast.Assign):
                    continue
                ctor = _ctor_name(node.value)
                if ctor in _LOCK_CTORS:
                    locks.add(name)
        if not locks:
            return []
        # conditions constructed over a lock acquire it on entry: aliases
        for meth in _methods(cls):
            for leaf, node in _attr_writes(meth):
                name = self_attr(leaf)
                if name is None or not isinstance(node, ast.Assign):
                    continue
                if _ctor_name(node.value) in _ALIAS_CTORS and any(
                        self_attr(a) in locks
                        for a in ast.walk(node.value)
                        if isinstance(a, ast.Attribute)):
                    locks.add(name)

        def is_lock_expr(expr):
            return self_attr(expr) in locks

        # pass 1: which attributes does the class itself guard?
        guarded_attrs: set[str] = set()
        for meth in _methods(cls):
            guarded = _guarded_nodes(meth, is_lock_expr)
            for leaf, node in _attr_writes(meth):
                name = self_attr(leaf)
                if name in locks or name is None:
                    continue
                if leaf in guarded:
                    guarded_attrs.add(name)
        if not guarded_attrs:
            return []

        # pass 2: flag unguarded accesses to those attributes
        findings: list[Finding] = []
        for meth in _methods(cls):
            if meth.name == "__init__":
                continue            # runs before the object is shared
            guarded = _guarded_nodes(meth, is_lock_expr)
            for node in ast.walk(meth):
                if not isinstance(node, ast.Attribute):
                    continue
                name = self_attr(node)
                if name not in guarded_attrs or node in guarded:
                    continue
                if module.is_disabled(self.pass_id, node, meth):
                    continue
                findings.append(module.finding(
                    self.pass_id, node,
                    f"self.{name} is written under self lock(s) "
                    f"{sorted(locks)} elsewhere in {cls.name} but accessed "
                    f"here without holding one",
                    scope=meth))
                break               # one finding per method is plenty
        return findings

    # ----------------------------------------------------------- rule two
    def _check_singletons(self, module: ParsedModule) -> list[Finding]:
        # classes whose __init__ hangs a ".lock"/"._lock" off self
        lock_classes: set[str] = set()
        for node in module.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            for meth in _methods(node):
                for leaf, stmt in _attr_writes(meth):
                    if self_attr(leaf) in ("lock", "_lock") and \
                            isinstance(stmt, ast.Assign) and \
                            _ctor_name(stmt.value) in _LOCK_CTORS:
                        lock_classes.add(node.name)
        if not lock_classes:
            return []
        singletons: dict[str, str] = {}
        for node in module.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    _ctor_name(node.value) in lock_classes:
                singletons[node.targets[0].id] = _ctor_name(node.value)
        if not singletons:
            return []

        def is_lock_expr(expr):
            return (isinstance(expr, ast.Attribute)
                    and expr.attr in ("lock", "_lock")
                    and isinstance(expr.value, ast.Name)
                    and expr.value.id in singletons)

        guarded = _guarded_nodes(module.tree, is_lock_expr)
        findings: list[Finding] = []
        for leaf, node in _attr_writes(module.tree):
            if not (isinstance(leaf.value, ast.Name)
                    and leaf.value.id in singletons):
                continue
            if leaf in guarded or node in guarded:
                continue
            if module.is_disabled(self.pass_id, node):
                continue
            findings.append(module.finding(
                self.pass_id, node,
                f"write to {dotted(leaf)} outside 'with "
                f"{leaf.value.id}.lock:' — singleton state must only be "
                f"mutated under its lock (reads may stay lock-free)"))
        return findings

    def run(self, module: ParsedModule) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(self._check_class(module, node))
        findings.extend(self._check_singletons(module))
        return findings
