"""span-hygiene pass: tracer spans are context managers, not values.

Bug class (PR 6 observability): ``obs.trace.span(...)`` returns a context
manager; a span held as a bare value is never entered, never records, and
silently drops its interval (worse: with tracing disabled it is the
shared no-op singleton, so code that "works" in tests records nothing in
production).  Every ``span(...)`` call must be the context expression of
a ``with`` statement::

    with obs_trace.span("plan.mttkrp", "plan", mode=mode) as sp:
        ...

The tracer module itself is exempt (it constructs spans by definition),
as is ``add_event`` (the already-measured-interval API).
"""
from __future__ import annotations

import ast

from ..linter import Finding, LintPass, ParsedModule
from .common import call_name, root_name

PASS_ID = "span-hygiene"


class SpanHygienePass(LintPass):
    pass_id = PASS_ID
    description = "tracer span opened outside a 'with' block"
    scope = ()

    def applies(self, module: ParsedModule) -> bool:
        return not module.path.endswith("obs/trace.py")

    def run(self, module: ParsedModule) -> list[Finding]:
        with_exprs: set[ast.AST] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    with_exprs.add(item.context_expr)
        findings: list[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call) or node in with_exprs:
                continue
            if call_name(node) != "span":
                continue
            # only the tracer's span factory: bare span(...) or a call on
            # a module spelled like the tracer (trace / obs_trace / obs)
            if isinstance(node.func, ast.Attribute):
                root = (root_name(node.func) or "").lower()
                if not ("trace" in root or root == "obs"):
                    continue
            if module.is_disabled(self.pass_id, node):
                continue
            findings.append(module.finding(
                self.pass_id, node,
                "span(...) must be entered via 'with' — an unentered span "
                "never records its interval"))
        return findings
