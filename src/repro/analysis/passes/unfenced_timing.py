"""unfenced-timing pass: dispatch timing must fence the device.

Bug class (PR 3): JAX dispatch is asynchronous, so bracketing a compute
call with ``time.perf_counter()`` measures the *host-side issue cost*,
not device execution.  Every timed dispatch path in the repo therefore
fences with ``block_until_ready()`` before reading the second timestamp
(the ``EngineStats`` dispatch-vs-device split exists for exactly this).

The rule is holistic per outermost function (nested helpers fold into
their enclosing function, because a fence at the end of the outer loop
legitimately covers per-chunk timestamps taken inside closures — see
``core.streaming.stream_mttkrp``): a function that

* reads ``time.perf_counter()`` at least twice, and
* issues at least one device dispatch (an ``mttkrp``-family call or a
  ``device_put``), and
* never calls ``block_until_ready``

is reporting async dispatch time as device time.
"""
from __future__ import annotations

import ast

from ..linter import Finding, LintPass, ParsedModule
from .common import call_name

PASS_ID = "unfenced-timing"


def _is_dispatch(call: ast.Call) -> bool:
    name = call_name(call)
    return "mttkrp" in name.lower() or name == "device_put"


class UnfencedTimingPass(LintPass):
    pass_id = PASS_ID
    description = ("perf_counter pair around a device dispatch with no "
                   "block_until_ready fence")
    scope = ()

    def run(self, module: ParsedModule) -> list[Finding]:
        findings: list[Finding] = []
        for qualname, fn in module.outer_functions():
            timers = 0
            dispatches = []
            fenced = False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name == "perf_counter":
                    timers += 1
                elif name == "block_until_ready":
                    fenced = True
                elif _is_dispatch(node):
                    dispatches.append(node)
            if timers >= 2 and dispatches and not fenced:
                node = dispatches[0]
                if module.is_disabled(self.pass_id, node, fn):
                    continue
                findings.append(module.finding(
                    self.pass_id, node,
                    f"{qualname} times a device dispatch with "
                    f"perf_counter but never fences with "
                    f"block_until_ready() — async dispatch time would be "
                    f"reported as device time (PR-3 bug class)",
                    scope=fn))
        return findings
