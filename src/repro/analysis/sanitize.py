"""Runtime sanitizer: contract checks over any ExecutionPlan + the service.

Enabled via ``REPRO_SANITIZE=1`` (read dynamically, so tests can flip it
per-case) or programmatically (``plan_for(..., sanitize=True)``, or the
``sanitized()`` context manager).  When enabled:

* every plan handed out by ``plan_for`` / the service engine is wrapped in
  a :class:`SanitizedPlan` enforcing the mttkrp boundary contract —
  factor shapes against the tensor dims, output shape ``(dims[mode],
  rank)``, no silent dtype downcast below the promoted input dtype, and a
  NaN/Inf guard on the result;
* the scheduler audits its admission ledger on every admit/retire edge:
  the byte total it charged must equal the engine's live pooled bytes
  plus the active jobs' factor working sets (the PR-4 overcommit bug
  class, now checked on every transition instead of once in a test);
* scheduler mutations assert the runtime lock is held by the calling
  thread whenever a :class:`~repro.service.runtime.ServiceRuntime` owns
  the scheduler (``guard_lock``) — the lock-order assertion the threaded
  race-stress test drives;
* factor updates are checked finite after every ALS sweep.

All checks raise :class:`SanitizerError` (an ``AssertionError`` subclass,
so ``pytest.raises(AssertionError)`` also catches it).  The wrapper only
*reads* plan outputs — a sanitized plan is bit-identical to a plain one.
"""
from __future__ import annotations

import os
import threading

import jax.numpy as jnp
import numpy as np

_ENV_VAR = "REPRO_SANITIZE"
_FALSY = ("", "0", "false", "False", "no")

# tri-state programmatic override: None -> follow the environment
_override: bool | None = None
_override_lock = threading.Lock()


class SanitizerError(AssertionError):
    """A runtime contract the sanitizer enforces was violated."""


def sanitize_enabled() -> bool:
    """True when sanitizer checks should run (override beats environment)."""
    if _override is not None:
        return _override
    return os.environ.get(_ENV_VAR, "") not in _FALSY


def set_sanitize(value: bool | None) -> None:
    """Force the sanitizer on/off; ``None`` returns control to the env."""
    global _override
    with _override_lock:
        _override = value


class sanitized:
    """``with sanitized(): ...`` — scoped sanitizer enable for tests."""

    def __init__(self, value: bool = True):
        self.value = value
        self._prev: bool | None = None

    def __enter__(self) -> "sanitized":
        self._prev = _override
        set_sanitize(self.value)
        return self

    def __exit__(self, *exc) -> bool:
        set_sanitize(self._prev)
        return False


# ------------------------------------------------------------------ plans
def _canonical(dtype):
    """The dtype as JAX will actually materialize it (x64 flag respected)."""
    return jnp.asarray(np.zeros(0, dtype)).dtype


def _plan_value_dtype(plan):
    """Best-effort tensor value dtype of a plan (None when unknowable)."""
    stored = getattr(plan, "stored", None)
    if stored is not None and getattr(stored, "value_dtype", None) is not None:
        return stored.value_dtype
    blco = getattr(plan, "blco", None)
    if blco is not None and getattr(blco, "values", None) is not None:
        return blco.values.dtype
    return None


class SanitizedPlan:
    """Transparent ExecutionPlan wrapper enforcing the mttkrp contract.

    Everything except ``mttkrp`` passes straight through, and ``mttkrp``
    only *inspects* inputs and output — the returned array is the inner
    plan's result object itself, so sanitized and plain execution are
    bit-identical.
    """

    def __init__(self, plan):
        if type(plan) is SanitizedPlan:
            plan = plan._plan       # idempotent: never double-wrap
        object.__setattr__(self, "_plan", plan)

    def __getattr__(self, name):
        return getattr(self._plan, name)

    @property
    def __class__(self):  # noqa: D401 — transparent-proxy identity
        # ``isinstance(plan, DiskStreamedPlan)`` must see through the
        # wrapper (callers branch on the plan's regime); ``type(plan)``
        # still reports SanitizedPlan for tests asserting the wrap itself
        return type(self._plan)

    def __repr__(self) -> str:
        return f"SanitizedPlan({self._plan!r})"

    @property
    def plan(self):
        """The wrapped plan (for tests asserting on the inner object)."""
        return self._plan

    def mttkrp(self, factors, mode: int, *args, **kwargs):
        dims = tuple(self._plan.dims)
        factors = tuple(factors)
        if len(factors) != len(dims):
            raise SanitizerError(
                f"mttkrp contract: {len(factors)} factor matrices for an "
                f"order-{len(dims)} tensor (dims {dims})")
        if not 0 <= int(mode) < len(dims):
            raise SanitizerError(
                f"mttkrp contract: mode {mode} out of range for dims {dims}")
        rank = int(factors[0].shape[1])
        for i, f in enumerate(factors):
            shape = tuple(f.shape)
            if shape != (dims[i], rank):
                raise SanitizerError(
                    f"mttkrp contract: factor {i} has shape {shape}, "
                    f"expected ({dims[i]}, {rank}) for dims {dims}")
        out = self._plan.mttkrp(factors, mode, *args, **kwargs)
        if tuple(out.shape) != (dims[mode], rank):
            raise SanitizerError(
                f"mttkrp contract: output shape {tuple(out.shape)} != "
                f"({dims[mode]}, {rank}) for mode {mode}")
        expected = _canonical(jnp.result_type(*[f.dtype for f in factors]))
        val_dtype = _plan_value_dtype(self._plan)
        if val_dtype is not None:
            expected = _canonical(jnp.promote_types(
                expected, _canonical(val_dtype)))
        if jnp.promote_types(out.dtype, expected) != out.dtype:
            raise SanitizerError(
                f"mttkrp contract: output dtype {out.dtype} is narrower "
                f"than the promoted input dtype {expected} — silent "
                f"downcast (PR-4 bug class)")
        if not bool(jnp.isfinite(out).all()):
            raise SanitizerError(
                f"mttkrp contract: non-finite values in the mode-{mode} "
                f"output")
        return out


def wrap_plan(plan, enable: bool | None = None):
    """Wrap ``plan`` when the sanitizer is (or is forced) on."""
    if plan is None:
        return None
    on = sanitize_enabled() if enable is None else enable
    if not on or type(plan) is SanitizedPlan:
        return plan
    return SanitizedPlan(plan)


# ---------------------------------------------------------------- service
def check_factors(arrays, where: str) -> None:
    """NaN/Inf guard over factor matrices (no-op when disabled)."""
    if not sanitize_enabled():
        return
    for i, arr in enumerate(arrays):
        if not bool(jnp.isfinite(arr).all()):
            raise SanitizerError(f"non-finite factor matrix {i} ({where})")


def audit_scheduler(scheduler, where: str) -> None:
    """Ledger audit: charged bytes == measured resident bytes.

    Pooled accounting: a pool entry is charged by whichever plan created
    it and released by whichever closes last, so between those events the
    entry's bytes live in the ledger but in no single active plan's
    ``device_bytes()``.  The measured quantity is therefore the engine's
    live pool footprint plus every active job's private factor working
    set (``_working`` on pooled plans; unpooled plans fall back to their
    full ``device_bytes()``).
    """
    if not sanitize_enabled():
        return
    held = 0
    for job_id in scheduler.active:
        plan = scheduler.jobs[job_id].plan
        if plan is None:
            continue
        working = getattr(plan, "_working", None)
        held += working if working is not None else plan.device_bytes()
    pooled_fn = getattr(scheduler.engine, "pooled_bytes", None)
    pooled = pooled_fn() if pooled_fn is not None else 0
    ledger = scheduler.metrics.admitted_reservation_bytes
    if held + pooled != ledger:
        raise SanitizerError(
            f"admission ledger out of sync at {where}: ledger holds "
            f"{ledger} B but pools measure {pooled} B + active working "
            f"sets {held} B (PR-4 overcommit bug class)")


def assert_owned(lock, what: str) -> None:
    """Assert the calling thread holds ``lock`` (RLock ownership check)."""
    if lock is None:
        return
    is_owned = getattr(lock, "_is_owned", None)
    if is_owned is not None and not is_owned():
        raise SanitizerError(
            f"{what} requires the runtime lock, but the calling thread "
            f"does not hold it — unsynchronized scheduler access")


def assert_scheduler_guard(scheduler, what: str) -> None:
    """Lock-order assertion for runtime-owned schedulers (no-op when the
    scheduler is driven synchronously without a runtime)."""
    if not sanitize_enabled():
        return
    assert_owned(getattr(scheduler, "guard_lock", None), what)
