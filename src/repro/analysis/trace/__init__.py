"""Trace-tier static analysis: verification over traced jaxprs + encodings.

The AST tier (``repro.analysis.linter`` + ``passes``) checks Python
source; this tier checks what actually executes and what the format
actually encodes, all offline (abstract tracing, pure integer
arithmetic — no GPU/TPU, no large arrays):

* :mod:`.jaxpr_audit`  — host callbacks/transfers and dtype narrowing on
  accumulation edges, over the registered hot paths (:mod:`.hotpaths`);
* :mod:`.cachekeys`    — jit cache-key churn: reservation roundings must
  keep executable counts logarithmic in launch shape and independent of
  tenant count;
* :mod:`.encoding`     — symbolic proofs that the BLCO bit layout is
  lossless, u64-safe, int32-safe, gather-in-bounds and padded-lane-
  no-op for any ``BuildParams``;
* :mod:`.conflicts`    — the fused kernel's write-set proof (single
  writer per row per step / declared conflicts) plus the per-launch
  machine-readable conflict report.

Findings are plain :class:`repro.analysis.Finding` objects, so the AST
tier's baseline and suppression machinery applies unchanged;
``scripts/lint.py --tier=trace`` is the CLI entry.
"""
from __future__ import annotations

import time

from .cachekeys import (PASS_CHURN, audit_reservation_churn,  # noqa: F401
                        audit_tenant_invariance, churn_bound,
                        enumerate_reservations, shipped_roundings)
from .conflicts import (PASS_CONFLICT, audit_conflicts,  # noqa: F401
                        check_scatter_claims, check_write_structure,
                        conflict_report, prove_variant, scatter_facts)
from .encoding import (DEFAULT_CONFIGS, PASS_ENCODING,  # noqa: F401
                       EncodingProof, audit_encodings, prove_encoding,
                       verify_layout)
from .hotpaths import HotPath, registered_hot_paths  # noqa: F401
from .jaxpr_audit import (PASS_CALLBACK, PASS_NARROWING,  # noqa: F401
                          audit_callbacks, audit_hot_path, audit_narrowing)
from .jaxprs import trace_jaxpr, walk_eqns  # noqa: F401
from .metrics import TraceVerifyMetrics  # noqa: F401

TRACE_PASS_IDS = (PASS_CALLBACK, PASS_NARROWING, PASS_CHURN, PASS_ENCODING,
                  PASS_CONFLICT)


def run_trace_tier(*, metrics: TraceVerifyMetrics | None = None):
    """Run every verifier family; returns ``(findings, report, metrics)``.

    ``report`` is the artifact bundle: the write-conflict report (the
    per-launch conflict structure the segmented-reduction invariant test
    and the CI artifact consume) plus the encoding proofs and the
    verifier metrics snapshot.
    """
    m = metrics if metrics is not None else TraceVerifyMetrics()
    findings = []
    t_start = time.perf_counter()

    t0 = time.perf_counter()
    for hp in registered_hot_paths():
        closed = hp.trace()
        m.hot_paths_traced += 1
        m.jaxpr_eqns_walked += sum(1 for _ in walk_eqns(closed))
        findings.extend(audit_callbacks(closed, path=hp.path,
                                        symbol=hp.name))
        findings.extend(audit_narrowing(closed, path=hp.path,
                                        symbol=hp.name))
    m.runtime_jaxpr_audit_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    findings.extend(audit_reservation_churn())
    findings.extend(audit_tenant_invariance())
    m.runtime_cache_churn_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    proofs, enc_findings = audit_encodings()
    m.encodings_verified = len(proofs)
    findings.extend(enc_findings)
    m.runtime_encoding_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    conflict_findings, report = audit_conflicts()
    findings.extend(conflict_findings)
    m.launches_analyzed = len(report["launches"])
    m.runtime_conflicts_s = time.perf_counter() - t0

    m.runtime_total_s = time.perf_counter() - t_start
    m.count_findings(findings)
    bundle = {
        "conflict_report": report,
        "encoding_proofs": [p.snapshot() for p in proofs],
        "metrics": m.snapshot(),
    }
    return findings, bundle, m
