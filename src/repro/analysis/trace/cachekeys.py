"""Cache-key churn audit: jit executable counts must be bounded.

Every distinct reservation size is a distinct traced shape, i.e. a
distinct XLA executable for the stacked scan / fused kernel — so the
function mapping a launch size to its reservation decides how many
compilations a ``plan_for`` regime can generate across a service
workload.  The audit enumerates the *image* of each regime's shipped
rounding over the launch-size range and fails when the count grows
linearly with launch shape (unbounded churn) instead of
logarithmically; it also proves the roundings are sound (cover the
launch, stay LANE-divisible for the Pallas tiler, monotone so a bigger
tensor never maps below a smaller one).

Tenant count can never enter the key: reservations are pure functions
of launch nnz, and ``audit_tenant_invariance`` mechanizes that by
checking an N-tenant workload's key count stays within the same
logarithmic envelope regardless of N.

The audit runs against the functions the regimes actually ship
(``core.launches.default_reservation``, ``core.padding.next_pow2`` via
``reservation_for``) — pass a different table to audit a candidate
rounding, e.g. the known-bad raw-LANE rounding in the fixture tests.
"""
from __future__ import annotations

from repro.analysis.linter import Finding

PASS_CHURN = "trace-cache-churn"

#: keys enumerated densely over [1, MAX_NNZ]; the churn bound below is
#: expressed in octaves of this range, so the verdict is range-independent
MAX_NNZ = 1 << 18

#: admissible distinct-reservation count: ``CLASSES_PER_OCTAVE`` per
#: power-of-two octave (size classes), plus slack for the floor bucket
CLASSES_PER_OCTAVE = 16


def shipped_roundings() -> dict:
    """regime name -> the reservation rounding that regime really uses."""
    from repro.core.launches import default_reservation
    from repro.core.padding import next_pow2

    return {
        # LaunchCache.from_blco default (in-memory regime)
        "in_memory": default_reservation,
        # reservation_for (streamed + disk_streamed regimes)
        "streamed": next_pow2,
        "disk_streamed": next_pow2,
    }


def enumerate_reservations(rounding, max_nnz: int = MAX_NNZ) -> set:
    """The reachable reservation set over launch sizes [1, max_nnz]."""
    return {rounding(n) for n in range(1, max_nnz + 1)}


def churn_bound(max_nnz: int = MAX_NNZ) -> int:
    """Admissible distinct-executable count for the launch-size range."""
    octaves = max(1, max_nnz.bit_length())
    return CLASSES_PER_OCTAVE * octaves


def audit_rounding(regime: str, rounding, *, max_nnz: int = MAX_NNZ,
                   path: str = "src/repro/core/padding.py") -> list[Finding]:
    """Soundness + boundedness of one regime's reservation rounding."""
    findings = []

    def flag(msg):
        findings.append(Finding(pass_id=PASS_CHURN, path=path,
                                symbol=regime, line=0, message=msg))

    prev = 0
    image = set()
    for n in range(1, max_nnz + 1):
        r = rounding(n)
        image.add(r)
        if r < n:
            flag(f"reservation {r} smaller than launch nnz {n}: padded "
                 f"launches would overflow the buffer")
            return findings
        if r < prev:
            flag(f"rounding not monotone at nnz {n}: {r} < {prev} — a "
                 f"bigger launch must never get a smaller reservation")
            return findings
        prev = r
    bound = churn_bound(max_nnz)
    if len(image) > bound:
        flag(f"{len(image)} distinct reservations over launch sizes "
             f"[1, {max_nnz}] (bound: {bound}) — jit cache keys grow "
             f"linearly with launch shape; use size-class or pow2 "
             f"rounding so executable count is O(log max_launch)")
    return findings


def audit_reservation_churn(roundings: dict | None = None, *,
                            max_nnz: int = MAX_NNZ) -> list[Finding]:
    """Audit every regime's shipped rounding (or a candidate table)."""
    findings = []
    for regime, fn in (roundings or shipped_roundings()).items():
        findings.extend(audit_rounding(regime, fn, max_nnz=max_nnz))
    return findings


def audit_tenant_invariance(n_tenants: int = 1000, *,
                            roundings: dict | None = None) -> list[Finding]:
    """Executable count over an N-tenant workload stays O(log), not O(N).

    A deterministic spread of per-tenant max-launch sizes (every tenant a
    different tensor) must collapse onto the bounded reservation classes —
    the property that lets the pooled service executor reuse one compiled
    executable per shape across tenants.
    """
    findings = []
    sizes = [1 + (i * 2654435761) % MAX_NNZ for i in range(n_tenants)]
    for regime, fn in (roundings or shipped_roundings()).items():
        keys = {fn(s) for s in sizes}
        bound = churn_bound(MAX_NNZ)
        if len(keys) > bound:
            findings.append(Finding(
                pass_id=PASS_CHURN, path="src/repro/core/padding.py",
                symbol=regime, line=0,
                message=f"{len(keys)} distinct reservations across "
                        f"{n_tenants} tenants (bound: {bound}) — the jit "
                        f"cache grows with tenant count"))
    return findings
