"""Write-conflict prover for the fused MTTKRP kernel (segment + stash).

Two halves, cross-checked against each other:

* **traced facts** — walk the fused kernel's jaxpr and extract its write
  set: every scatter primitive with its declared ``unique_indices``
  claim, and every ``pallas_call`` whose grid-sequential block writes
  are the other accumulation mechanism.  The *stash* variant must stage
  NO scatter at all (its one-hot matmul accumulates every contribution
  to a row inside a single add per grid step — single-writer-per-row-
  per-step by construction on TPU's sequential grid).  The *segment*
  variant's per-tile outputs write disjoint compressed slots (single
  writer per slot), and all conflicts are deferred to exactly one final
  scatter-add which must declare ``unique_indices=False`` — the same
  row can be targeted by multiple discovered segments (non-adjacent
  repeats within a tile, repeats across tiles, and the padding
  segments that land on row 0).

* **conflict report** — the per-launch conflict *structure* of a real
  tensor, computed host-side from the BLCO encoding itself: segments
  per tile, writers per output row, and whether a ``unique_indices``
  claim would be sound.  This machine-readable report is the artifact
  the future opportunistic conflict-resolution kernel (ROADMAP item 3)
  will be validated against: any replacement of the pre-planned
  segmented reduction must preserve exactly the per-row write
  multiplicities recorded here.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.linter import Finding

from .jaxprs import walk_eqns

PASS_CONFLICT = "trace-write-conflict"

_FUSED = "src/repro/kernels/fused.py"

SCATTER_PRIMITIVES = ("scatter-add", "scatter", "scatter-mul",
                      "scatter-max", "scatter-min")


def scatter_facts(closed) -> list[dict]:
    """Every scatter/pallas write site in the traced kernel, with claims."""
    facts = []
    for site in walk_eqns(closed):
        if site.primitive in SCATTER_PRIMITIVES:
            facts.append({
                "primitive": site.primitive,
                "unique_indices": bool(site.eqn.params.get("unique_indices",
                                                           False)),
                "inside_pallas": "pallas_call" in site.context,
                "context": "/".join(site.context) or "<top>",
            })
        elif site.primitive == "pallas_call":
            facts.append({"primitive": "pallas_call",
                          "context": "/".join(site.context) or "<top>"})
    return facts


def prove_variant(variant: str, *, symbol: str | None = None):
    """Trace one fused variant and prove its write-set structure.

    Returns ``(facts, findings)``; empty findings = the proof holds.
    """
    from .hotpaths import _fused
    symbol = symbol or f"_fused_flat[{variant}]"
    facts = scatter_facts(_fused(variant))
    return facts, check_write_structure(facts, variant=variant,
                                        symbol=symbol)


def check_write_structure(facts: list, *, variant: str,
                          symbol: str) -> list[Finding]:
    """The per-variant single-writer proof over extracted write facts."""
    findings = []

    def flag(msg):
        findings.append(Finding(pass_id=PASS_CONFLICT, path=_FUSED,
                                symbol=symbol, line=0, message=msg))

    scatters = [f for f in facts if f["primitive"] in SCATTER_PRIMITIVES
                and not f.get("inside_pallas")]
    pallas = [f for f in facts if f["primitive"] == "pallas_call"]
    if not pallas:
        flag("no pallas_call staged — the fused pipeline is not fused")
    if variant == "stash":
        if scatters:
            flag(f"stash variant stages {len(scatters)} scatter(s) outside "
                 f"the kernel; its single-writer proof requires ALL "
                 f"accumulation to happen in the sequential-grid one-hot "
                 f"matmul")
    elif variant == "segment":
        if len(scatters) != 1:
            flag(f"segment variant stages {len(scatters)} scatters "
                 f"(expected exactly one per-segment apply)")
        for s in scatters:
            if s["unique_indices"]:
                flag(f"{s['primitive']} claims unique_indices=True, but "
                     f"multiple discovered segments (non-adjacent repeats, "
                     f"cross-tile repeats, padding) can target one row — "
                     f"the claim licenses XLA to drop the conflict "
                     f"handling and corrupt the accumulation")
    else:
        flag(f"unknown fused variant {variant!r}")
    return findings


def check_scatter_claims(closed, *, duplicates_possible: bool, path: str,
                         symbol: str) -> list[Finding]:
    """Generic check: no scatter may claim uniqueness conflicts violate."""
    findings = []
    if not duplicates_possible:
        return findings
    for f in scatter_facts(closed):
        if f["primitive"] in SCATTER_PRIMITIVES and f["unique_indices"]:
            findings.append(Finding(
                pass_id=PASS_CONFLICT, path=path, symbol=symbol, line=0,
                message=f"{f['primitive']} (at {f['context']}) claims "
                        f"unique_indices=True while the write set provably "
                        f"contains duplicate rows"))
    return findings


# ------------------------------------------------------------------ report
def conflict_report(blco, mode: int, *, tile: int = 256) -> dict:
    """Per-launch conflict structure of ``blco``'s fused-kernel write set.

    Pure host arithmetic over the encoding (no device, no tracing): the
    target coordinates come from ``decode_coords`` — i.e. from the very
    bit fields the kernel extracts — split into the reservation-padded
    flat stream exactly as ``LaunchCache.flat()`` lays it out, with
    segments discovered per tile the way the fused kernel discovers them.
    """
    from repro.core.blco import decode_coords
    from repro.core.launches import default_reservation
    from repro.core.mttkrp import choose_resolution

    tgt_all = decode_coords(blco)[:, mode] if blco.nnz else \
        np.zeros(0, np.int64)
    max_launch = max((l.nnz for l in blco.launches), default=1)
    res = default_reservation(max_launch)
    tile = int(np.gcd(res, max(1, min(tile, res))))
    resolution = choose_resolution(blco.dims[mode])

    launches = []
    global_writers = np.zeros(blco.dims[mode], np.int64)
    for i, launch in enumerate(blco.launches):
        tgt = np.zeros(res, np.int64)
        valid = np.zeros(res, bool)
        n = launch.nnz
        tgt[:n] = tgt_all[launch.start:launch.end]
        valid[:n] = True

        # per-tile segment discovery: boundary at each tile start and
        # wherever the target changes (paper §5.1 step 3)
        pos = np.arange(res)
        prev = np.roll(tgt, 1)
        starts = (pos % tile == 0) | (tgt != prev)
        seg_starts = np.flatnonzero(starts)
        seg_valid = valid[seg_starts]           # segment has real data?
        seg_rows = tgt[seg_starts]

        writers = np.bincount(seg_rows[seg_valid],
                              minlength=blco.dims[mode])
        global_writers += writers
        conflict_rows = np.flatnonzero(writers > 1)
        padding_segments = int((~seg_valid).sum())
        launches.append({
            "launch": i,
            "nnz": int(n),
            "padded_nnz": int(res),
            "tiles": int(res // tile),
            "segments": int(seg_valid.sum()),
            "padding_segments": padding_segments,
            "distinct_rows": int((writers > 0).sum()),
            "max_writers_per_row": int(writers.max()) if n else 0,
            "conflict_rows": [int(r) for r in conflict_rows[:8]],
        })

    max_writers = int(global_writers.max()) if blco.nnz else 0
    return {
        "mode": int(mode),
        "dims": [int(d) for d in blco.dims],
        "tile": int(tile),
        "reservation": int(res),
        "resolution": resolution,
        "launches": launches,
        "total_segments": int(sum(l["segments"] for l in launches)),
        # writers per row across the ONE fused scatter (all launches'
        # segments merge in a single update step)
        "max_writers_per_row_per_step": max_writers,
        # padding segments always target row 0 with zero sums, so the
        # final scatter sees duplicate indices whenever any padding or
        # any repeated target exists:
        "unique_indices_sound": bool(
            max_writers <= 1
            and all(l["padding_segments"] == 0 for l in launches)),
    }


def audit_conflicts(blco=None, *, mode: int = 0, tile: int = 256):
    """Tier entry: prove both variants + report a representative tensor.

    Returns ``(findings, report)``.  Cross-check: when the report shows
    conflicting writers, the traced segment kernel must not claim
    uniqueness (the structural proof already enforces it; the report
    makes the *reason* machine-readable per launch).
    """
    findings = []
    for variant in ("segment", "stash"):
        _, fs = prove_variant(variant)
        findings.extend(fs)
    if blco is None:
        from repro.core.blco import build_blco
        from repro.core.tensor import random_tensor
        blco = build_blco(random_tensor((40, 25, 30), 2000, seed=1,
                                        dist="powerlaw"),
                          target_bits=12, max_nnz_per_block=256)
    report = conflict_report(blco, mode, tile=tile)
    if not report["unique_indices_sound"]:
        facts, _ = prove_variant("segment")
        for f in facts:
            if f["primitive"] in SCATTER_PRIMITIVES \
                    and not f.get("inside_pallas") and f["unique_indices"]:
                findings.append(Finding(
                    pass_id=PASS_CONFLICT, path=_FUSED,
                    symbol="_fused_flat[segment]", line=0,
                    message="kernel claims unique scatter indices but the "
                            "conflict report proves duplicate writers "
                            f"(max {report['max_writers_per_row_per_step']}"
                            " per row per step)"))
    return findings, report
