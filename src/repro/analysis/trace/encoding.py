"""Symbolic BLCO encoding verifier: bit-width/interval proofs, no arrays.

Given a tensor's dims and build parameters (or an arbitrary — possibly
hand-broken — ``LinearSpec``/``ReencodeSpec`` pair), prove with pure
integer arithmetic every invariant the device pipeline assumes:

1. the ALTO bit layout is a bijection onto ``[0, total_bits)`` and every
   mode's bit count covers its extent (losslessness of the linearization);
2. the re-encoding partitions each mode's bits exactly
   (``field + block == bits`` — no bit lost to the split, so
   re-encode∘delinearize is the identity on every in-range coordinate);
3. the packed fields are disjoint, in-range and fit the stored-word width
   (``shift + width <= 64`` — no mask overflow at the u64 boundary), and
   the block key fits 64 bits (``block_key``'s own guard, proven here
   before any data exists);
4. every field is <= 32 bits wide and every decoded coordinate fits int32
   (the 2x-uint32 TPU adaptation: ``u64.extract_field`` asserts
   width <= 32, and coords/bases/gather indices are int32 throughout);
5. every delinearized coordinate is in-bounds for its factor gather:
   ``max decoded = ((dim-1) >> field) << field | (field mask over the
   residue) = dim - 1``, by the exact-partition property;
6. padded lanes are provably no-ops: all-zero index words decode to
   coordinate 0 of every mode (fields of 0 are 0, padding bases are 0),
   row 0 always exists (dims >= 1), and the padded value 0 annihilates
   the hadamard product — the update contributes +0.0 to row 0.

``prove_encoding`` returns an :class:`EncodingProof` (the machine-
readable certificate) plus findings; an empty finding list IS the proof.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.linter import Finding

PASS_ENCODING = "trace-encoding"

_LINEARIZE = "src/repro/core/linearize.py"


@dataclasses.dataclass(frozen=True)
class EncodingProof:
    """Certificate of one verified (dims, spec, re) encoding."""
    dims: tuple
    bits: tuple
    total_bits: int
    field_bits: tuple
    field_shift: tuple
    block_bits: tuple
    stored_bits: int            # sum(field_bits) — width of the packed index
    key_bits: int               # sum(block_bits) — width of the block key
    max_coord: tuple            # per-mode maximum decodable coordinate
    padded_lane_noop: bool

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


def verify_layout(dims, spec, re, *, target_bits: int = 64,
                  symbol: str = "encoding") -> list[Finding]:
    """All invariant checks over an explicit (possibly broken) layout."""
    findings: list[Finding] = []

    def flag(msg):
        findings.append(Finding(pass_id=PASS_ENCODING, path=_LINEARIZE,
                                symbol=symbol, line=0, message=msg))

    n_modes = len(dims)
    if not (len(spec.bits) == len(spec.positions) == len(re.field_bits)
            == len(re.field_shift) == len(re.block_bits) == n_modes):
        flag("spec/reencode arity mismatch with dims")
        return findings

    # (1) ALTO layout: bijection onto [0, total_bits), extents covered
    flat = [p for pos in spec.positions for p in pos]
    if sorted(flat) != list(range(spec.total_bits)):
        flag(f"ALTO positions are not a bijection onto "
             f"[0, {spec.total_bits}): the linearization is lossy or "
             f"double-books a bit")
    if spec.total_bits > 128:
        flag(f"total index width {spec.total_bits} exceeds the 128-bit "
             f"(hi, lo) u64 pair")
    for n, (d, b, pos) in enumerate(zip(dims, spec.bits, spec.positions)):
        if len(pos) != b:
            flag(f"mode {n}: {len(pos)} ALTO positions for {b} bits")
        if d > (1 << b):
            flag(f"mode {n}: extent {d} does not fit {b} bits — "
                 f"coordinates >= {1 << b} alias under encode")

    # (2) exact per-mode bit partition (losslessness of the re-encode)
    for n in range(n_modes):
        if re.field_bits[n] + re.block_bits[n] != spec.bits[n]:
            flag(f"mode {n}: field({re.field_bits[n]}) + "
                 f"block({re.block_bits[n]}) != bits({spec.bits[n]}) — "
                 f"the re-encode drops or invents coordinate bits")
        if re.field_bits[n] < 0 or re.block_bits[n] < 0:
            flag(f"mode {n}: negative bit width in the re-encode")

    # (3) packed fields: disjoint, in-range, no u64 mask overflow
    covered: set[int] = set()
    for n in range(n_modes):
        fb, sh = re.field_bits[n], re.field_shift[n]
        if fb == 0:
            continue
        if sh < 0 or sh + fb > 64:
            flag(f"mode {n}: field [{sh}, {sh + fb}) overflows the 64-bit "
                 f"stored word — the shifted mask wraps")
            continue
        span = set(range(sh, sh + fb))
        if covered & span:
            flag(f"mode {n}: field [{sh}, {sh + fb}) overlaps another "
                 f"mode's field — decode reads foreign bits")
        covered |= span
    stored_bits = sum(re.field_bits)
    if stored_bits > target_bits:
        flag(f"packed index needs {stored_bits} bits but target_bits is "
             f"{target_bits}")
    key_bits = sum(re.block_bits)
    if key_bits > 64:
        flag(f"block key needs {key_bits} bits; >64 unsupported "
             f"(block_key would raise at build time)")

    # (4) 32-bit device constraints
    for n in range(n_modes):
        if re.field_bits[n] > 32:
            flag(f"mode {n}: field width {re.field_bits[n]} > 32 — "
                 f"u64.extract_field asserts at trace time on device")
        if dims[n] > 1 << 31:
            flag(f"mode {n}: extent {dims[n]} > 2^31 — coordinates are "
                 f"int32 throughout the device pipeline")
        if dims[n] < 1:
            flag(f"mode {n}: empty extent {dims[n]}")

    # (5) gather in-bounds: decode(encode(c)) = (c >> fb << fb) | (c & mask)
    # = c for every c in [0, dim) — the identity holds exactly when the
    # per-mode partition is exact and fields are disjoint (checks 2-3), so
    # the decoded set IS the encoded set and max decoded = dim-1 < dim.
    # Verify the algebra at the extent's edge rather than assuming it:
    if not findings:
        for n, d in enumerate(dims):
            fb = re.field_bits[n]
            mask = (1 << fb) - 1
            edge = ((d - 1) >> fb << fb) | ((d - 1) & mask)
            if edge != d - 1:
                flag(f"mode {n}: round-trip of extent edge {d - 1} gives "
                     f"{edge} — factor gather would read the wrong row")
    return findings


def max_coords(dims, re) -> tuple:
    """Per-mode maximum decodable coordinate: ``dim-1`` exactly, because
    the verified partition makes decode∘encode the identity on [0, dim)."""
    return tuple(int(d) - 1 for d in dims)


def prove_encoding(dims, *, target_bits: int = 64,
                   symbol: str = "encoding"):
    """Build the shipped layout for ``dims`` and verify it.

    Returns ``(proof_or_None, findings)`` — ``proof`` only when the
    layout verifies clean.  A construction-time rejection (``LinearSpec
    .make``/``reencode_spec`` raising) is itself a finding: the verifier
    must witness the guard, not crash on it.
    """
    from repro.core import linearize as lin
    try:
        spec = lin.LinearSpec.make(dims)
        re = lin.reencode_spec(spec, target_bits)
    except (ValueError, AssertionError) as exc:
        return None, [Finding(
            pass_id=PASS_ENCODING, path=_LINEARIZE, symbol=symbol, line=0,
            message=f"construction rejected dims={tuple(dims)} "
                    f"target_bits={target_bits}: {exc}")]
    findings = verify_layout(dims, spec, re, target_bits=target_bits,
                             symbol=symbol)
    if findings:
        return None, findings
    proof = EncodingProof(
        dims=tuple(int(d) for d in dims), bits=spec.bits,
        total_bits=spec.total_bits, field_bits=re.field_bits,
        field_shift=re.field_shift, block_bits=re.block_bits,
        stored_bits=sum(re.field_bits), key_bits=sum(re.block_bits),
        max_coord=max_coords(dims, re),
        padded_lane_noop=all(d >= 1 for d in dims))
    return proof, findings


#: the configurations the tier sweeps by default: small tensors, mixed
#: extents, the 128-bit total ceiling, and adversarial near-2^31 modes
DEFAULT_CONFIGS = (
    ((8, 6, 4), 64),
    ((40, 25, 30), 12),                     # forces blocking (tests' shape)
    ((1, 1, 1), 64),
    ((2**31, 4), 64),                       # int32 boundary, exactly legal
    ((2**31 - 1, 2**31 - 1, 4, 4), 64),     # 66 encoded bits -> split
    ((2**20, 2**20, 2**20, 2**20, 2**20, 2**20), 64),  # 120/128 bits
    ((2**31, 2**31, 2**31, 2**31), 64),     # the full 124-bit ALTO index
)


def audit_encodings(configs=DEFAULT_CONFIGS):
    """Verify the default configuration sweep; returns (proofs, findings)."""
    proofs, findings = [], []
    for dims, target in configs:
        proof, fs = prove_encoding(
            dims, target_bits=target,
            symbol=f"encoding[{'x'.join(str(d) for d in dims)}@{target}]")
        if proof is not None:
            proofs.append(proof)
        findings.extend(fs)
    return proofs, findings
