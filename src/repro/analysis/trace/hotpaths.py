"""Registered hot paths: the jitted entry points the trace tier audits.

Each :class:`HotPath` names one jit-compiled dataflow the paper's pipeline
actually executes — the stacked-scan MTTKRP, the fused Pallas kernel (both
conflict-resolution variants), the streamed regime's per-launch body, and
the CP-ALS sweep update — together with a builder that traces it over
*abstract* inputs (``jax.ShapeDtypeStruct``), so auditing needs no device
and allocates no arrays.  Shapes are small representative instances; the
properties checked (no host callbacks, no narrowing on accumulation edges,
declared scatter uniqueness) are shape-independent because every primitive
the walk inspects appears identically at any size.

``path``/``symbol`` place findings in the lint framework's stable keying
(``pass:path:symbol``), so trace findings share the AST tier's baseline
and inline-suppression machinery unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .jaxprs import ClosedJaxpr, trace_jaxpr

# one small representative tensor: dims (8, 6, 4), re-encoded as 3+3+2-bit
# contiguous fields — the layout build_blco(dims=(8,6,4)) itself produces
_DIMS = (8, 6, 4)
_FIELDS = (3, 3, 2)
_SHIFTS = (0, 3, 6)
_RANK = 16
_RES = 256          # one LANE-multiple reservation


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _factors():
    return tuple(_f32(d, _RANK) for d in _DIMS)


@dataclasses.dataclass(frozen=True)
class HotPath:
    """One auditable jitted dataflow: identity + an abstract tracer."""
    name: str            # finding symbol (function the jaxpr came from)
    path: str            # repo-relative source path the finding points at
    build: object        # () -> ClosedJaxpr

    def trace(self) -> ClosedJaxpr:
        return self.build()


def _stacked(resolution: str):
    from repro.core.launches import stacked_mttkrp
    launches = 2
    return trace_jaxpr(
        stacked_mttkrp,
        _u32(launches, _RES), _u32(launches, _RES), _f32(launches, _RES),
        _i32(launches, _RES, len(_DIMS)), _factors(),
        re_fields=_FIELDS, re_shifts=_SHIFTS, mode=0, out_rows=_DIMS[0],
        resolution=resolution, copies=8)


def _launch_body(resolution: str):
    # the per-launch dataflow shared by the scan body AND the streamed
    # regime (stream_mttkrp dispatches exactly this, one launch at a time)
    from repro.core.mttkrp import launch_mttkrp_impl
    return trace_jaxpr(
        launch_mttkrp_impl,
        _u32(_RES), _u32(_RES), _f32(_RES), _i32(_RES, len(_DIMS)),
        _factors(),
        re_fields=_FIELDS, re_shifts=_SHIFTS, mode=0, out_rows=_DIMS[0],
        resolution=resolution, copies=8)


def _fused(variant: str):
    from repro.kernels.fused import _fused_flat
    t = 2 * _RES
    return trace_jaxpr(
        _fused_flat,
        _u32(t), _u32(t), _f32(t), _i32(t, len(_DIMS)), _factors(),
        field_bits=_FIELDS, field_shifts=_SHIFTS, mode=0, out_rows=_DIMS[0],
        variant=variant, tile=_RES, interpret=False)


def _sweep():
    from repro.core.cp_als import sweep_mode_update
    grams = [_f32(_RANK, _RANK) for _ in _DIMS]
    return trace_jaxpr(sweep_mode_update, _f32(_DIMS[0], _RANK), grams,
                       mode=0)


def registered_hot_paths() -> list[HotPath]:
    """Every audited dataflow (late-bound so import stays cheap)."""
    return [
        HotPath("stacked_mttkrp[register]", "src/repro/core/launches.py",
                lambda: _stacked("register")),
        HotPath("stacked_mttkrp[hierarchical]", "src/repro/core/launches.py",
                lambda: _stacked("hierarchical")),
        HotPath("launch_mttkrp_impl[streamed]", "src/repro/core/streaming.py",
                lambda: _launch_body("register")),
        HotPath("_fused_flat[segment]", "src/repro/kernels/fused.py",
                lambda: _fused("segment")),
        HotPath("_fused_flat[stash]", "src/repro/kernels/fused.py",
                lambda: _fused("stash")),
        HotPath("sweep_mode_update", "src/repro/core/cp_als.py", _sweep),
    ]
