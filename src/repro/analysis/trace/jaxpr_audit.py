"""Jaxpr auditor: host-callback and dtype-narrowing checks over hot paths.

Two verdicts per traced hot path, both on what JAX will *execute* rather
than on Python source (the AST tier's ``host-sync``/``dtype-promotion``
passes are the source-level complements):

* **host callbacks / transfers in jitted regions** — any ``*_callback``,
  ``infeed``/``outfeed`` or ``device_put`` primitive staged inside a hot
  path forces a host round-trip per dispatch, exactly the per-launch
  overhead the launch cache exists to eliminate;
* **silent dtype narrowing on accumulation edges** — a
  ``convert_element_type`` that loses float precision whose value flows
  (through any chain of ops, including re-widening) into an accumulation
  primitive.  The repo's contract is promote-never-downcast
  (``jnp.result_type``); a narrowing conversion ahead of the accumulator
  silently converts a float64-tensor run into float32 math.

The taint propagation is per-jaxpr (narrowing and sink inside the same
(sub-)jaxpr); conservative — any eqn consuming a tainted var taints all
its outputs — so re-widening before the accumulator does NOT clear the
finding, by design.
"""
from __future__ import annotations

from repro.analysis.linter import Finding

from .jaxprs import is_float_narrowing, leaf_jaxprs, var_dtype, walk_eqns

PASS_CALLBACK = "trace-host-callback"
PASS_NARROWING = "trace-dtype-narrowing"

#: primitives that hand control (or data) back to the host mid-jit
HOST_PRIMITIVES = ("infeed", "outfeed", "device_put")

#: primitives that accumulate values — the sinks narrowing must not reach
ACCUMULATION_PRIMITIVES = frozenset({
    "scatter-add", "scatter-mul", "add_any", "reduce_sum", "cumsum",
    "dot_general", "segment_sum",
})


def _is_host_primitive(name: str) -> bool:
    return "callback" in name or name in HOST_PRIMITIVES


def audit_callbacks(closed, *, path: str, symbol: str) -> list[Finding]:
    """Flag every host-callback/transfer primitive staged in the jaxpr."""
    findings = []
    for site in walk_eqns(closed):
        if _is_host_primitive(site.primitive):
            where = "/".join(site.context) or "<top>"
            findings.append(Finding(
                pass_id=PASS_CALLBACK, path=path, symbol=symbol, line=0,
                message=f"host primitive '{site.primitive}' staged inside "
                        f"the jitted hot path (at {where}, depth "
                        f"{site.depth}): forces a host round-trip per "
                        f"dispatch"))
    return findings


def audit_narrowing(closed, *, path: str, symbol: str) -> list[Finding]:
    """Taint floats through narrowing converts; flag tainted accumulators."""
    findings = []
    for jaxpr, context in leaf_jaxprs(closed):
        tainted: dict[object, str] = {}     # var -> narrowing description
        for eqn in jaxpr.eqns:
            src_taint = None
            for v in eqn.invars:
                if id(v) in tainted:
                    src_taint = tainted[id(v)]
                    break
            name = eqn.primitive.name
            if name == "convert_element_type" and eqn.invars:
                src = var_dtype(eqn.invars[0])
                dst = eqn.params.get("new_dtype")
                if is_float_narrowing(src, dst):
                    src_taint = src_taint or f"{src} -> {dst}"
            if src_taint is None:
                continue
            if name in ACCUMULATION_PRIMITIVES:
                where = "/".join(context) or "<top>"
                findings.append(Finding(
                    pass_id=PASS_NARROWING, path=path, symbol=symbol,
                    line=0,
                    message=f"accumulation primitive '{name}' (at {where}) "
                            f"consumes a value that passed through a "
                            f"narrowing convert ({src_taint}); accumulate "
                            f"at the promoted dtype instead"))
            for v in eqn.outvars:
                tainted[id(v)] = src_taint
    return findings


def audit_hot_path(hot_path) -> list[Finding]:
    """Both audits over one :class:`~.hotpaths.HotPath`."""
    closed = hot_path.trace()
    return (audit_callbacks(closed, path=hot_path.path,
                            symbol=hot_path.name)
            + audit_narrowing(closed, path=hot_path.path,
                              symbol=hot_path.name))
