"""Jaxpr tracing + walking utilities shared by the trace-tier verifiers.

The AST tier (``repro.analysis.passes``) sees Python source; this tier sees
what JAX will actually *execute*: the jaxpr of each registered hot path,
including every nested sub-jaxpr (``pjit`` bodies, ``scan``/``while`` carry
bodies, ``cond`` branches, ``pallas_call`` kernel bodies, scatter update
functions).  Everything here is backend-free — tracing happens with
abstract values only, so the verifiers run offline on a CPU container with
no accelerator attached.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

try:                                # jax >= 0.4.x
    from jax.extend import core as _jex_core
    Jaxpr = _jex_core.Jaxpr
    ClosedJaxpr = _jex_core.ClosedJaxpr
except ImportError:                 # pragma: no cover - older jax fallback
    from jax import core as _jax_core
    Jaxpr = _jax_core.Jaxpr
    ClosedJaxpr = _jax_core.ClosedJaxpr


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One primitive application inside a traced hot path.

    ``depth`` is the sub-jaxpr nesting depth (0 = the outermost jaxpr) and
    ``context`` the chain of enclosing primitive names (e.g.
    ``('pjit', 'scan')``) — enough to say *where* in the traced program a
    finding lives, since jaxprs carry no source lines.
    """
    primitive: str
    depth: int
    context: tuple
    eqn: object = dataclasses.field(hash=False, compare=False)


def trace_jaxpr(fn, *args, **kwargs) -> ClosedJaxpr:
    """``jax.make_jaxpr`` with kwargs threaded through (abstract tracing)."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


def _sub_jaxprs(params: dict):
    """Every nested (Closed)Jaxpr reachable from one eqn's params."""
    for value in params.values():
        stack = [value]
        while stack:
            v = stack.pop()
            if isinstance(v, ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, Jaxpr):
                yield v
            elif isinstance(v, (tuple, list)):
                stack.extend(v)


def walk_eqns(closed: ClosedJaxpr):
    """Yield an :class:`EqnSite` for every eqn, sub-jaxprs included."""
    jaxpr = closed.jaxpr if isinstance(closed, ClosedJaxpr) else closed

    def _walk(j, depth, context):
        for eqn in j.eqns:
            yield EqnSite(primitive=eqn.primitive.name, depth=depth,
                          context=context, eqn=eqn)
            sub_context = context + (eqn.primitive.name,)
            for sub in _sub_jaxprs(eqn.params):
                yield from _walk(sub, depth + 1, sub_context)

    yield from _walk(jaxpr, 0, ())


def leaf_jaxprs(closed: ClosedJaxpr):
    """Yield every (jaxpr, context) pair, sub-jaxprs included — the unit the
    per-jaxpr dataflow analyses (taint propagation) operate on."""
    jaxpr = closed.jaxpr if isinstance(closed, ClosedJaxpr) else closed

    def _walk(j, context):
        yield j, context
        for eqn in j.eqns:
            for sub in _sub_jaxprs(eqn.params):
                yield from _walk(sub, context + (eqn.primitive.name,))

    yield from _walk(jaxpr, ())


def var_dtype(v):
    """dtype of a jaxpr var/literal aval, or None for non-array avals."""
    aval = getattr(v, "aval", None)
    return getattr(aval, "dtype", None)


def is_float_narrowing(src_dtype, dst_dtype) -> bool:
    """True when a convert loses floating-point precision (f64->f32,
    f32->bf16/f16, ...).  Integer/bool converts never count — index math
    legitimately moves between integer widths."""
    if src_dtype is None or dst_dtype is None:
        return False
    src = np.dtype(src_dtype)
    dst = np.dtype(dst_dtype)
    src_float = np.issubdtype(src, np.floating)
    dst_float = np.issubdtype(dst, np.floating)
    if not src_float:
        return False
    if not dst_float:
        return True                 # float -> int truncates outright
    return dst.itemsize < src.itemsize
