"""Trace-tier verifier metrics: runtime + finding counters for CI trends.

One :class:`TraceVerifyMetrics` per tier run, filled by
``run_trace_tier`` and exported two ways:

* ``snapshot()`` — JSON-safe dict whose key set is pinned grow-only by
  ``tests/test_metrics_schema.py`` (the same contract every other
  metrics snapshot in the repo honours);
* :func:`repro.obs.export.render_prometheus_analysis` — Prometheus text
  exposition, so CI can scrape verifier runtime and finding counts into
  the same trend lines as the service metrics.
"""
from __future__ import annotations

import dataclasses

#: family key per pass id — how findings are bucketed into counters
FAMILY_OF_PASS = {
    "trace-host-callback": "jaxpr_audit",
    "trace-dtype-narrowing": "jaxpr_audit",
    "trace-cache-churn": "cache_churn",
    "trace-encoding": "encoding",
    "trace-write-conflict": "conflicts",
}


@dataclasses.dataclass
class TraceVerifyMetrics:
    """Counters/gauges of one trace-tier run (grow-only snapshot keys)."""
    hot_paths_traced: int = 0
    jaxpr_eqns_walked: int = 0
    encodings_verified: int = 0
    launches_analyzed: int = 0
    findings_total: int = 0
    findings_jaxpr_audit: int = 0
    findings_cache_churn: int = 0
    findings_encoding: int = 0
    findings_conflicts: int = 0
    runtime_jaxpr_audit_s: float = 0.0
    runtime_cache_churn_s: float = 0.0
    runtime_encoding_s: float = 0.0
    runtime_conflicts_s: float = 0.0
    runtime_total_s: float = 0.0

    def count_findings(self, findings) -> None:
        for f in findings:
            self.findings_total += 1
            family = FAMILY_OF_PASS.get(f.pass_id)
            if family is not None:
                attr = f"findings_{family}"
                setattr(self, attr, getattr(self, attr) + 1)

    def snapshot(self) -> dict:
        return {
            "hot_paths_traced": self.hot_paths_traced,
            "jaxpr_eqns_walked": self.jaxpr_eqns_walked,
            "encodings_verified": self.encodings_verified,
            "launches_analyzed": self.launches_analyzed,
            "findings_total": self.findings_total,
            "findings_jaxpr_audit": self.findings_jaxpr_audit,
            "findings_cache_churn": self.findings_cache_churn,
            "findings_encoding": self.findings_encoding,
            "findings_conflicts": self.findings_conflicts,
            "runtime_jaxpr_audit_s": self.runtime_jaxpr_audit_s,
            "runtime_cache_churn_s": self.runtime_cache_churn_s,
            "runtime_encoding_s": self.runtime_encoding_s,
            "runtime_conflicts_s": self.runtime_conflicts_s,
            "runtime_total_s": self.runtime_total_s,
        }
