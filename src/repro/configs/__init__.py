from .base import ArchConfig, get_config, all_configs, ASSIGNED
__all__ = ["ArchConfig", "get_config", "all_configs", "ASSIGNED"]
