"""Architecture config schema + registry.

Every assigned architecture is one frozen ``ArchConfig`` in its own module
(``src/repro/configs/<id>.py``), selectable via ``--arch <id>`` in the
launchers. ``reduced()`` derives the CPU smoke-test config of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | ssm | moe | hybrid | encdec-audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention
    attention: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    mlp_type: str = "swiglu"         # swiglu | gelu

    # MLA (DeepSeek)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert ffn width
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # deepseek: leading dense layers

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256
    # beyond-paper perf knob: separate z/x/B/C/dt projections instead of one
    # fused in_proj whose TP-sharded output must be sliced (slicing a sharded
    # dim inserts halo collective-permutes; see EXPERIMENTS.md §Perf)
    ssm_split_proj: bool = False
    # keep SSD B/C/x tensors in bf16 (decay/dt stay fp32); §Perf iteration A6
    ssd_bf16: bool = False

    # hybrid (Zamba2)
    shared_attn_every: int = 0       # apply shared attn block every k ssm layers
    shared_attn_lora_rank: int = 0

    # enc-dec
    encoder_layers: int = 0

    # modality frontend stub
    input_mode: str = "tokens"       # tokens | embeddings
    frontend_dim: int = 0            # embedding input width (0 -> d_model)

    # embeddings / output
    tie_embeddings: bool = True
    embed_grad: str = "segment"      # the paper's technique: segment | scatter

    # numerics / training
    norm_eps: float = 1e-5
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    schedule: str = "cosine"         # cosine | wsd (MiniCPM)

    # dry-run eligibility
    subquadratic: bool = False       # eligible for long_500k decode

    # remat policy for train_step (perf knob, see EXPERIMENTS §Perf)
    remat_policy: str = "nothing_saveable"  # nothing_saveable | dots_saveable

    # unroll layer scans in the lowered HLO: XLA's cost analysis counts a
    # while-loop body ONCE, so the dry-run unrolls for exact flops/collective
    # accounting (trainers keep scan=rolled for compile time)
    unroll_layers: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.num_heads))

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/LM-head rows padded to a multiple of 256 so the vocab dim
        shards evenly over the model axis (Megatron-style padding; labels are
        always < vocab_size so padding rows receive zero gradient signal)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // max(1, self.num_heads))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.attention == "mla":
            kw.update(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32, head_dim=0)
        if self.moe:
            kw.update(num_experts=4, top_k=2, moe_d_ff=64,
                      num_shared_experts=min(1, self.num_shared_experts),
                      first_dense_layers=min(1, self.first_dense_layers))
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=32, ssd_chunk=16)
        if self.shared_attn_every:
            kw.update(num_layers=4, shared_attn_every=2,
                      shared_attn_lora_rank=8)
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        if self.sliding_window:
            kw.update(sliding_window=32)
        if self.frontend_dim:
            kw.update(frontend_dim=64)
        return ArchConfig(**kw)


ASSIGNED = [
    "stablelm_12b", "qwen2_5_14b", "minicpm_2b", "h2o_danube_3_4b",
    "mamba2_370m", "internvl2_2b", "seamless_m4t_large_v2", "zamba2_1_2b",
    "dbrx_132b", "deepseek_v2_236b",
]

_ALIASES = {
    "stablelm-12b": "stablelm_12b",
    "qwen2.5-14b": "qwen2_5_14b",
    "minicpm-2b": "minicpm_2b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mamba2-370m": "mamba2_370m",
    "internvl2-2b": "internvl2_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-1.2b": "zamba2_1_2b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {n: get_config(n) for n in ASSIGNED}
