"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 (expert width)
vocab=102400. MLA kv_lora=512 (rope 64 / nope 128 / v 128, q_lora 1536),
2 shared + 160 routed experts top-6, first layer dense. [arXiv:2405.04434; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288,                      # dense first-layer FFN width (v2 paper)
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512, q_lora_rank=1536,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    moe=True, num_experts=160, top_k=6, num_shared_experts=2,
    moe_d_ff=1536,                   # assigned d_ff = expert width
    capacity_factor=1.25, first_dense_layers=1,
    tie_embeddings=False,
)
