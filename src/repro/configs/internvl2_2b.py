"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
InternViT vision frontend is a STUB per the assignment: input_specs() feeds
precomputed patch embeddings. Backbone = InternLM2-like dense LM.
[arXiv:2404.16821; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    attention="gqa", mlp_type="swiglu",
    input_mode="embeddings", frontend_dim=1024,   # InternViT patch embed width
    tie_embeddings=False,
)
