"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760
vocab=122753. WSD schedule, llama-like. [arXiv:2404.06395; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    attention="gqa", mlp_type="swiglu",
    schedule="wsd", tie_embeddings=True,
)
