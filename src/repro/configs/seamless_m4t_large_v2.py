"""seamless-m4t-large-v2 [audio] — enc-dec, 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206. Speech frontend is a STUB per the assignment:
input_specs() feeds precomputed frame embeddings to the encoder.
[arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec-audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    attention="gqa", mlp_type="gelu",
    encoder_layers=24,
    input_mode="embeddings", frontend_dim=1024,   # speech frame embed width
    tie_embeddings=True,
)
