"""zamba2-1.2b [hybrid] — 38 Mamba2 layers d_model=2048, ssm_state=64, plus a
SHARED full-attention block (32H MHA, d_ff=8192) applied every 6 SSM layers
with per-site LoRA on its projections. vocab=32000. [arXiv:2411.15242; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    attention="gqa", mlp_type="gelu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    conv_width=4, ssd_chunk=256,
    shared_attn_every=6, shared_attn_lora_rank=128,
    tie_embeddings=True,
    subquadratic=True,   # SSM spine; shared attn sees the same KV cache
)
