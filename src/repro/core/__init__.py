"""Core library: the paper's contribution (BLCO format + mode-agnostic MTTKRP
+ OOM streaming + CP-ALS) and its baselines."""
from .tensor import SparseTensor, random_tensor, from_coo, load_tns, paper_like
from .blco import BLCOTensor, build_blco, decode_coords, format_bytes
from .mttkrp import (mttkrp, mttkrp_per_launch, choose_resolution,
                     clear_launch_cache, launch_cache_for,
                     mttkrp_dense_oracle, khatri_rao, DeviceBLCO)
from .launches import LaunchCache, launch_cache_bytes, stacked_mttkrp
from .counters import dispatch_count
from .baselines import (COOFormat, coo_mttkrp, FCOOFormat, fcoo_mttkrp,
                        CSFFormat, csf_mttkrp)
from .cp_als import (cp_als, cp_als_init, cp_als_step, as_mttkrp_fn, CPResult,
                     CPState, init_factors, reconstruct_dense)
from .streaming import (EngineStats, LaunchChunks, OOMExecutor,
                        ReservationSpec, StreamStats)
from .embed_grad import embedding_lookup

__all__ = [
    "SparseTensor", "random_tensor", "from_coo", "load_tns", "paper_like",
    "BLCOTensor", "build_blco", "decode_coords", "format_bytes",
    "mttkrp", "mttkrp_per_launch", "choose_resolution",
    "clear_launch_cache", "launch_cache_for",
    "mttkrp_dense_oracle", "khatri_rao", "DeviceBLCO",
    "LaunchCache", "launch_cache_bytes", "stacked_mttkrp", "dispatch_count",
    "COOFormat", "coo_mttkrp", "FCOOFormat", "fcoo_mttkrp",
    "CSFFormat", "csf_mttkrp",
    "cp_als", "cp_als_init", "cp_als_step", "as_mttkrp_fn", "CPResult",
    "CPState", "init_factors", "reconstruct_dense",
    "EngineStats", "LaunchChunks", "OOMExecutor", "ReservationSpec",
    "StreamStats",
    "embedding_lookup",
]
