"""Baseline sparse-MTTKRP formats the paper compares against (§3, §6).

* ``COOFormat``   — plain coordinate list, per-nnz scatter-add (GenTen-style
  "atomic" path). Mode-agnostic, one copy, maximal update conflicts.
* ``FCOOFormat``  — F-COO (Liu et al.): one *mode-specific sorted copy per
  mode* with precomputed segment flags; segmented reduction + one update per
  segment. Models both F-COO's strength (few conflicts) and its cost (N tensor
  copies + flag storage).
* ``CSFFormat``   — compressed-sparse-fiber tree (SPLATT/B-CSF family): one
  tree per root mode (N copies); root-mode MTTKRP is conflict-free (one write
  per sub-tree root), non-root modes fall back to scatter updates. This is the
  CSF-1 traversal; MM-CSF's mixed-root refinement is a compression optimization
  on top of the same dataflow and is represented here by the best-root variant
  (``csf_best_root``).

All formats share the element-wise MTTKRP semantics, so every one is validated
against the same dense oracle in tests, and benchmarks/ compares them against
BLCO on matched tensors (paper Fig. 8/9 analogue).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .tensor import SparseTensor


# ------------------------------------------------------------------ plain COO
@dataclasses.dataclass
class COOFormat:
    dims: tuple[int, ...]
    indices: np.ndarray     # (nnz, N) int32
    values: np.ndarray      # (nnz,)

    @staticmethod
    def build(t: SparseTensor) -> "COOFormat":
        return COOFormat(t.dims, t.indices.astype(np.int32), t.values)

    def device_bytes(self) -> int:
        return int(self.indices.nbytes + self.values.nbytes)


@functools.partial(jax.jit, static_argnames=("mode", "out_rows"))
def _coo_mttkrp(indices, values, factors, *, mode: int, out_rows: int):
    partial = values[:, None].astype(jnp.result_type(values, factors[0]))
    for m, f in enumerate(factors):
        if m == mode:
            continue
        partial = partial * jnp.take(f, indices[:, m], axis=0)
    out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
    return out.at[indices[:, mode]].add(partial)


def coo_mttkrp(fmt: COOFormat, factors, mode: int):
    factors = tuple(jnp.asarray(f) for f in factors)
    return _coo_mttkrp(jnp.asarray(fmt.indices), jnp.asarray(fmt.values),
                       factors, mode=mode, out_rows=fmt.dims[mode])


# ---------------------------------------------------------------------- F-COO
@dataclasses.dataclass
class FCOOFormat:
    """One sorted copy + bit-flag arrays per mode (the paper's Fig. 4b)."""
    dims: tuple[int, ...]
    per_mode_indices: list[np.ndarray]   # N arrays (nnz, N) int32, sorted by mode
    per_mode_values: list[np.ndarray]
    per_mode_segids: list[np.ndarray]    # precomputed segment ids (from bf/sf flags)

    @staticmethod
    def build(t: SparseTensor) -> "FCOOFormat":
        idxs, vals, segs = [], [], []
        for mode in range(t.order):
            order = np.argsort(t.indices[:, mode], kind="stable")
            si = t.indices[order].astype(np.int32)
            sv = t.values[order]
            tgt = si[:, mode]
            flags = np.concatenate(([1], (tgt[1:] != tgt[:-1]).astype(np.int64)))
            segs.append(np.cumsum(flags) - 1)
            idxs.append(si)
            vals.append(sv)
        return FCOOFormat(t.dims, idxs, vals, [s.astype(np.int32) for s in segs])

    def device_bytes(self) -> int:
        b = 0
        for i, v, s in zip(self.per_mode_indices, self.per_mode_values,
                           self.per_mode_segids):
            b += i.nbytes + v.nbytes + s.nbytes
        return int(b)


@functools.partial(jax.jit, static_argnames=("mode", "out_rows", "num_segments"))
def _fcoo_mttkrp(indices, values, segids, factors, *, mode: int, out_rows: int,
                 num_segments: int):
    partial = values[:, None].astype(jnp.result_type(values, factors[0]))
    for m, f in enumerate(factors):
        if m == mode:
            continue
        partial = partial * jnp.take(f, indices[:, m], axis=0)
    seg_sums = jax.ops.segment_sum(partial, segids, num_segments=num_segments)
    seg_tgt = jnp.zeros((num_segments,), jnp.int32).at[segids].max(indices[:, mode])
    out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
    return out.at[seg_tgt].add(seg_sums)


def fcoo_mttkrp(fmt: FCOOFormat, factors, mode: int):
    factors = tuple(jnp.asarray(f) for f in factors)
    segids = fmt.per_mode_segids[mode]
    nseg = int(segids[-1]) + 1 if len(segids) else 1
    return _fcoo_mttkrp(jnp.asarray(fmt.per_mode_indices[mode]),
                        jnp.asarray(fmt.per_mode_values[mode]),
                        jnp.asarray(segids), factors,
                        mode=mode, out_rows=fmt.dims[mode], num_segments=nseg)


# ------------------------------------------------------------------------ CSF
@dataclasses.dataclass
class CSFTree:
    root_mode: int
    fiber_ptr: np.ndarray     # (num_fibers+1,) int32 into sorted nnz
    fiber_root: np.ndarray    # (num_fibers,) int32 root-mode index per fiber
    indices: np.ndarray       # (nnz, N) int32 sorted by (root, others)
    values: np.ndarray


@dataclasses.dataclass
class CSFFormat:
    """One two-level CSF tree per root mode (SPLATT's N-copy strategy)."""
    dims: tuple[int, ...]
    trees: list[CSFTree]

    @staticmethod
    def build(t: SparseTensor) -> "CSFFormat":
        trees = []
        for root in range(t.order):
            key = [t.indices[:, m] for m in range(t.order) if m != root]
            order = np.lexsort(tuple(reversed(key)) + (t.indices[:, root],))
            si = t.indices[order].astype(np.int32)
            sv = t.values[order]
            roots = si[:, root]
            starts = np.flatnonzero(
                np.concatenate(([True], roots[1:] != roots[:-1])))
            ptr = np.append(starts, len(roots)).astype(np.int32)
            trees.append(CSFTree(root, ptr, roots[starts].astype(np.int32), si, sv))
        return CSFFormat(t.dims, trees)

    def device_bytes(self) -> int:
        b = 0
        for tr in self.trees:
            b += tr.fiber_ptr.nbytes + tr.fiber_root.nbytes
            b += tr.indices.nbytes + tr.values.nbytes
        return int(b)


@functools.partial(jax.jit, static_argnames=("mode", "out_rows", "num_segments"))
def _csf_root_mttkrp(indices, values, segids, seg_root, factors, *, mode: int,
                     out_rows: int, num_segments: int):
    """Root-mode traversal: accumulate per sub-tree, ONE write per root index
    (conflict-free — the CSF family's core advantage for the root mode)."""
    partial = values[:, None].astype(jnp.result_type(values, factors[0]))
    for m, f in enumerate(factors):
        if m == mode:
            continue
        partial = partial * jnp.take(f, indices[:, m], axis=0)
    seg_sums = jax.ops.segment_sum(partial, segids, num_segments=num_segments)
    out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
    return out.at[seg_root].set(seg_sums)   # set, not add: roots are unique


class DeviceCOO:
    """Device-resident COO (in-memory benchmarking parity with DeviceBLCO)."""

    def __init__(self, fmt: COOFormat):
        self.indices = jnp.asarray(fmt.indices)
        self.values = jnp.asarray(fmt.values)
        self.dims = fmt.dims

    def mttkrp(self, factors, mode: int):
        return _coo_mttkrp(self.indices, self.values, tuple(factors),
                           mode=mode, out_rows=self.dims[mode])

    def device_bytes(self) -> int:
        return int(self.indices.nbytes + self.values.nbytes)


class DeviceFCOO:
    def __init__(self, fmt: FCOOFormat):
        self.dims = fmt.dims
        self.per_mode = []
        for m in range(len(fmt.per_mode_indices)):
            seg = fmt.per_mode_segids[m]
            self.per_mode.append((jnp.asarray(fmt.per_mode_indices[m]),
                                  jnp.asarray(fmt.per_mode_values[m]),
                                  jnp.asarray(seg),
                                  int(seg[-1]) + 1 if len(seg) else 1))

    def mttkrp(self, factors, mode: int):
        idx, vals, seg, nseg = self.per_mode[mode]
        return _fcoo_mttkrp(idx, vals, seg, tuple(factors), mode=mode,
                            out_rows=self.dims[mode], num_segments=nseg)

    def device_bytes(self) -> int:
        return int(sum(i.nbytes + v.nbytes + s.nbytes
                       for i, v, s, _ in self.per_mode))


class DeviceCSF:
    def __init__(self, fmt: CSFFormat):
        self.dims = fmt.dims
        self.trees = []
        for tr in fmt.trees:
            segids = np.repeat(np.arange(len(tr.fiber_root), dtype=np.int32),
                               np.diff(tr.fiber_ptr))
            self.trees.append((jnp.asarray(tr.indices), jnp.asarray(tr.values),
                               jnp.asarray(segids), jnp.asarray(tr.fiber_root),
                               len(tr.fiber_root)))

    def mttkrp(self, factors, mode: int):
        idx, vals, seg, root, nseg = self.trees[mode]
        return _csf_root_mttkrp(idx, vals, seg, root, tuple(factors),
                                mode=mode, out_rows=self.dims[mode],
                                num_segments=nseg)

    def device_bytes(self) -> int:
        return int(sum(i.nbytes + v.nbytes + s.nbytes + r.nbytes
                       for i, v, s, r, _ in self.trees))


def csf_mttkrp(fmt: CSFFormat, factors, mode: int, *, root: int | None = None):
    """MTTKRP using the tree rooted at ``root`` (defaults to the target mode,
    i.e. the conflict-free traversal; other roots use scatter-add fallback —
    the paper's 'top-down/bottom-up' cost asymmetry)."""
    factors = tuple(jnp.asarray(f) for f in factors)
    root = mode if root is None else root
    tree = fmt.trees[root]
    if root == mode:
        nnz = len(tree.values)
        segids = np.repeat(np.arange(len(tree.fiber_root), dtype=np.int32),
                           np.diff(tree.fiber_ptr))
        return _csf_root_mttkrp(jnp.asarray(tree.indices),
                                jnp.asarray(tree.values), jnp.asarray(segids),
                                jnp.asarray(tree.fiber_root), factors,
                                mode=mode, out_rows=fmt.dims[mode],
                                num_segments=len(tree.fiber_root))
    # non-root mode on this tree: plain scatter-add over leaves
    return _coo_mttkrp(jnp.asarray(tree.indices), jnp.asarray(tree.values),
                       factors, mode=mode, out_rows=fmt.dims[mode])
