"""BLCO format construction: adaptive blocking + batching (paper §4.2).

Pipeline (host, vectorized numpy — the paper also constructs on the CPU, §6.5):

  COO -> ALTO-encode -> sort by ALTO index -> strip top bits to block keys ->
  re-encode survivors into contiguous fields -> split oversized blocks ->
  batch small blocks into launches.

The device-facing arrays are two uint32 index words + one value array per
tensor, with blocks/launches as (start, end) views — a *single* tensor copy,
mode-agnostic, exactly the property the paper is built around.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from . import linearize as lin
from .tensor import SparseTensor
from .u64 import split64


@dataclasses.dataclass(frozen=True)
class Block:
    """One BLCO block: a contiguous run of the sorted nnz arrays."""
    key: int                 # stripped upper ALTO bits (the paper's `b`)
    start: int
    end: int
    upper: tuple[int, ...]   # per-mode upper coordinate bits recovered from key

    @property
    def nnz(self) -> int:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class Launch:
    """A batch of blocks issued as one device launch (paper's block batching).

    block_ids index into BLCOTensor.blocks; all their nnz ranges are contiguous
    in the global arrays by construction, so a launch is itself a (start, end)
    range plus a per-element block-id array used to apply per-block offsets.
    """
    block_ids: tuple[int, ...]
    start: int
    end: int

    @property
    def nnz(self) -> int:
        return self.end - self.start


@dataclasses.dataclass
class BLCOTensor:
    dims: tuple[int, ...]
    spec: lin.LinearSpec
    re: lin.ReencodeSpec
    idx_hi: np.ndarray          # (nnz,) uint32 — stored index, high word
    idx_lo: np.ndarray          # (nnz,) uint32 — stored index, low word
    values: np.ndarray          # (nnz,)
    blocks: list[Block]
    launches: list[Launch]
    construction_stats: dict    # timing breakdown (paper Fig. 12)

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    def block_upper_bases(self) -> np.ndarray:
        """(num_blocks, N) int64: per-block coordinate base = upper << field_bits."""
        out = np.zeros((len(self.blocks), self.order), dtype=np.int64)
        for i, b in enumerate(self.blocks):
            for n in range(self.order):
                out[i, n] = b.upper[n] << self.re.field_bits[n]
        return out

    def element_block_ids(self) -> np.ndarray:
        """(nnz,) int32 block id per element (for batched launches)."""
        out = np.empty(self.nnz, dtype=np.int32)
        for i, b in enumerate(self.blocks):
            out[b.start:b.end] = i
        return out


def build_blco(t: SparseTensor, *, target_bits: int = 64,
               max_nnz_per_block: int = 1 << 27,
               launch_nnz_budget: int | None = None) -> BLCOTensor:
    """Construct the BLCO representation of a COO tensor.

    target_bits: native integer width of the device (64 in the paper; smaller
        values exercise the blocking machinery on small test tensors).
    max_nnz_per_block: device memory constraint (2^27 in the paper).
    launch_nnz_budget: batch blocks into launches of at most this many nnz
        (defaults to max_nnz_per_block) — the paper's work-group batching for
        hypersparse tensors.
    """
    stats: dict[str, float] = {}
    t0 = time.perf_counter()
    spec = lin.LinearSpec.make(t.dims)
    hi, lo = lin.alto_encode(spec, t.indices)
    stats["linearize"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    perm = lin.sort_by_alto(hi, lo)
    hi, lo = hi[perm], lo[perm]
    indices = t.indices[perm]
    values = t.values[perm]
    stats["sort"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    re = lin.reencode_spec(spec, target_bits)
    keys = lin.block_key(spec, re, hi, lo)
    stats["block_keys"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    stored = lin.reencode(spec, re, indices)
    idx_hi, idx_lo = split64(stored)
    stats["reencode"] = time.perf_counter() - t0

    # --- initial blocks: runs of equal key in sorted order -------------------
    t0 = time.perf_counter()
    nnz = values.shape[0]
    blocks: list[Block] = []
    if nnz:
        boundaries = np.flatnonzero(np.concatenate(([True], keys[1:] != keys[:-1])))
        boundaries = np.append(boundaries, nnz)
        for s, e in zip(boundaries[:-1], boundaries[1:]):
            key = int(keys[s])
            upper = tuple(int(u) for u in lin.key_to_upper_coords(spec, re, key))
            # split oversized blocks to the device budget (paper: 2^27 nnz)
            for cs in range(int(s), int(e), max_nnz_per_block):
                ce = min(cs + max_nnz_per_block, int(e))
                blocks.append(Block(key=key, start=cs, end=ce, upper=upper))
    stats["blocking"] = time.perf_counter() - t0

    # --- batch small blocks into launches ------------------------------------
    t0 = time.perf_counter()
    budget = launch_nnz_budget or max_nnz_per_block
    launches: list[Launch] = []
    cur: list[int] = []
    cur_nnz = 0
    for i, b in enumerate(blocks):
        if cur and cur_nnz + b.nnz > budget:
            launches.append(Launch(tuple(cur), blocks[cur[0]].start, blocks[cur[-1]].end))
            cur, cur_nnz = [], 0
        cur.append(i)
        cur_nnz += b.nnz
    if cur:
        launches.append(Launch(tuple(cur), blocks[cur[0]].start, blocks[cur[-1]].end))
    stats["batching"] = time.perf_counter() - t0

    return BLCOTensor(dims=t.dims, spec=spec, re=re, idx_hi=idx_hi, idx_lo=idx_lo,
                      values=values, blocks=blocks, launches=launches,
                      construction_stats=stats)


def format_bytes(b: BLCOTensor) -> int:
    """True device-resident bytes of the format (Table-3-style analysis).

    Counts everything an in-memory MTTKRP keeps on the device per element:
    the two uint32 index words, the value, AND the per-element int32 block
    coordinate bases (order words wide).  This matches
    ``ReservationSpec.bytes_per_launch`` per nnz slot, so the streaming and
    in-memory regimes account device bytes identically.
    """
    bases_bytes = 4 * b.order * b.nnz
    return int(b.idx_hi.nbytes + b.idx_lo.nbytes + b.values.nbytes
               + bases_bytes)


def decode_coords(b: BLCOTensor) -> np.ndarray:
    """Recover the (nnz, N) original coordinates from the stored encoding.

    Host-side inverse of the ALTO re-encode: extract each mode's field from
    the 64-bit stored index and add the per-block upper-bit base.  Rows are
    in BLCO (ALTO-sorted) order, matching ``b.values``.
    """
    stored = (b.idx_hi.astype(np.uint64) << np.uint64(32)) \
        | b.idx_lo.astype(np.uint64)
    bases = b.block_upper_bases()[b.element_block_ids()] if b.nnz else \
        np.zeros((0, b.order), np.int64)
    coords = np.empty((b.nnz, b.order), np.int64)
    for n, (shift, width) in enumerate(zip(b.re.field_shift, b.re.field_bits)):
        mask = (1 << width) - 1
        field = (stored >> np.uint64(shift)).astype(np.int64) & mask
        coords[:, n] = field + bases[:, n]
    return coords
