"""Process-wide dispatch accounting for MTTKRP execution paths.

A *dispatch* is one host->device invocation of a jitted compute callable
(the unit the paper's "kernel launching overhead" is paid in).  Every
MTTKRP path in this repo records its dispatches here, so tests and
benchmarks can assert launch-count claims directly:

* the legacy per-launch loop records one dispatch per BLCO launch;
* the launch-cache scan path records exactly ONE per ``mttkrp`` call;
* the fused Pallas path records exactly ONE per ``mttkrp`` call.

The counter is monotonic; callers snapshot it before/after
(``dispatch_count()``) rather than resetting, so concurrent readers never
race each other's deltas.
"""
from __future__ import annotations

_dispatches = 0


def record_dispatch(n: int = 1) -> None:
    """Record ``n`` host->device compute dispatches."""
    global _dispatches
    _dispatches += int(n)


def dispatch_count() -> int:
    """Monotonic count of compute dispatches recorded so far."""
    return _dispatches
