"""CP-ALS (paper Algorithm 1) on top of any MTTKRP backend.

The MTTKRP backend is a callable ``(factors, mode) -> M`` so the same driver
runs over BLCO (in-memory or streaming/OOM), COO, F-COO, CSF, or the Pallas
kernel path — mirroring how the paper swaps formats under one algorithm.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CPResult:
    factors: list        # N arrays (I_n, R), unit-norm columns
    lam: np.ndarray      # (R,) column weights
    fits: list           # per-iteration fit
    converged: bool
    iterations: int


def init_factors(dims, rank, *, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)), dtype=dtype) for d in dims]


def _grams(factors):
    return [f.T @ f for f in factors]


def cp_als(mttkrp_fn, dims, rank, *, norm_x: float, iters: int = 25,
           tol: float = 1e-5, seed: int = 0, dtype=jnp.float32,
           factors=None) -> CPResult:
    """Alternating least squares for rank-R CPD.

    mttkrp_fn(factors, mode) must return the (I_mode, R) MTTKRP result.
    norm_x: Frobenius norm of the sparse tensor (sum of squared values)**0.5.
    """
    n_modes = len(dims)
    factors = list(factors) if factors is not None else \
        init_factors(dims, rank, seed=seed, dtype=dtype)
    lam = jnp.ones((rank,), dtype)
    grams = _grams(factors)

    fits: list[float] = []
    prev_fit = -np.inf
    converged = False
    it = 0
    for it in range(1, iters + 1):
        for n in range(n_modes):
            # V = hadamard of Gram matrices of all other modes (Alg. 1 line 3)
            v = jnp.ones((rank, rank), dtype)
            for m in range(n_modes):
                if m != n:
                    v = v * grams[m]
            m_mat = mttkrp_fn(factors, n)                    # line 4
            a_new = m_mat @ jnp.linalg.pinv(v)               # line 5
            lam = jnp.linalg.norm(a_new, axis=0)
            lam = jnp.where(lam > 0, lam, 1.0)
            factors[n] = a_new / lam
            grams[n] = factors[n].T @ factors[n]

        # fit = 1 - ||X - X_hat||_F / ||X||_F, computed without materializing
        # X_hat (standard CP-ALS identity; m_mat is the last mode's MTTKRP).
        last = n_modes - 1
        v_all = jnp.ones((rank, rank), dtype)
        for m in range(n_modes):
            v_all = v_all * grams[m]
        norm_est_sq = lam @ (v_all @ lam)
        inner = jnp.sum(lam * jnp.sum(m_mat * factors[last], axis=0))
        resid_sq = jnp.maximum(norm_x ** 2 + norm_est_sq - 2.0 * inner, 0.0)
        fit = float(1.0 - jnp.sqrt(resid_sq) / norm_x)
        fits.append(fit)
        if abs(fit - prev_fit) < tol:
            converged = True
            break
        prev_fit = fit

    return CPResult(factors=factors, lam=np.asarray(lam), fits=fits,
                    converged=converged, iterations=it)


def reconstruct_dense(result: CPResult) -> np.ndarray:
    """Dense reconstruction from factors (test oracle; small tensors only)."""
    factors = [np.asarray(f, np.float64) for f in result.factors]
    lam = np.asarray(result.lam, np.float64)
    rank = lam.shape[0]
    dims = [f.shape[0] for f in factors]
    out = np.zeros(dims)
    for r in range(rank):
        term = lam[r]
        vecs = [f[:, r] for f in factors]
        acc = vecs[0]
        for v in vecs[1:]:
            acc = np.multiply.outer(acc, v)
        out += term * acc
    return out
