"""CP-ALS (paper Algorithm 1) on top of any MTTKRP backend.

The MTTKRP backend is either a bare callable ``(factors, mode) -> M`` or any
object exposing ``.mttkrp(factors, mode)`` — in particular an
``repro.engine.ExecutionPlan`` (the unified engine API), but also the legacy
``DeviceBLCO`` / ``OOMExecutor`` wrappers.  ``as_mttkrp_fn`` is the adapter;
every driver below resolves its backend through it, so the same algorithm
runs over BLCO (in-memory or streaming/OOM), COO, F-COO, CSF, sharded, or
the Pallas kernel path — mirroring how the paper swaps formats under one
algorithm.

The algorithm is exposed at two granularities:

* ``cp_als`` — the one-shot driver (runs to convergence / iteration cap);
* ``cp_als_init`` + ``cp_als_step`` — a resumable stepper over an explicit
  ``CPState``, one full ALS sweep (all modes + fit update) per call. The
  multi-tenant service scheduler interleaves *iterations* of many jobs
  through this interface; ``cp_als`` is literally a loop over it, so both
  paths are numerically identical.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CPResult:
    factors: list        # N arrays (I_n, R), unit-norm columns
    lam: np.ndarray      # (R,) column weights
    fits: list           # per-iteration fit
    converged: bool
    iterations: int


@dataclasses.dataclass
class CPState:
    """Resumable CP-ALS state: everything one ALS sweep reads and writes."""
    dims: tuple
    rank: int
    norm_x: float
    tol: float
    factors: list        # N arrays (I_n, R), unit-norm columns
    lam: jnp.ndarray     # (R,) column weights
    grams: list          # N arrays (R, R) = factors[n].T @ factors[n]
    fits: list           # per-iteration fit, appended by each step
    prev_fit: float
    iteration: int       # completed ALS sweeps
    converged: bool

    def as_result(self) -> CPResult:
        return CPResult(factors=self.factors, lam=np.asarray(self.lam),
                        fits=self.fits, converged=self.converged,
                        iterations=self.iteration)


def as_mttkrp_fn(backend):
    """Adapt an engine/plan-or-callable MTTKRP backend to ``(factors, mode)``.

    Accepts (in priority order) any object with an ``mttkrp(factors, mode)``
    method — an ``ExecutionPlan``, ``DeviceBLCO``, ``OOMExecutor``, baseline
    device format — or a bare callable.  Bare callables pass through
    untouched, keeping the original ``cp_als(lambda f, m: ...)`` form intact.
    """
    method = getattr(backend, "mttkrp", None)
    if method is not None and callable(method):
        return method
    if callable(backend):
        return backend
    raise TypeError(
        f"MTTKRP backend must be a callable (factors, mode) -> M or expose "
        f".mttkrp(factors, mode); got {type(backend).__name__}")


def init_factors(dims, rank, *, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)), dtype=dtype) for d in dims]


def _grams(factors):
    return [f.T @ f for f in factors]


def cp_als_init(dims, rank, *, norm_x: float, tol: float = 1e-5,
                seed: int = 0, dtype=jnp.float32, factors=None) -> CPState:
    """Fresh CP-ALS state (factors drawn from ``seed`` unless given)."""
    factors = list(factors) if factors is not None else \
        init_factors(dims, rank, seed=seed, dtype=dtype)
    return CPState(dims=tuple(dims), rank=rank, norm_x=norm_x, tol=tol,
                   factors=factors, lam=jnp.ones((rank,), dtype),
                   grams=_grams(factors), fits=[], prev_fit=-np.inf,
                   iteration=0, converged=False)


def sweep_mode_update(m_mat, grams, mode: int):
    """Pure device math of one ALS mode update (Alg. 1 lines 3 + 5).

    ``m_mat`` is the mode's MTTKRP result (line 4), ``grams`` the current
    Gram matrices.  Returns ``(factor, lam, gram)``: the column-normalized
    new factor, its column norms, and its refreshed Gram matrix.  Kept as a
    free jnp-pure function so the trace-tier jaxpr auditor
    (``repro.analysis.trace``) can audit the sweep body exactly as the
    scheduler executes it.
    """
    rank = m_mat.shape[1]
    dtype = grams[mode].dtype
    # V = hadamard of Gram matrices of all other modes (Alg. 1 line 3)
    v = jnp.ones((rank, rank), dtype)
    for m, g in enumerate(grams):
        if m != mode:
            v = v * g
    a_new = m_mat @ jnp.linalg.pinv(v)                   # line 5
    lam = jnp.linalg.norm(a_new, axis=0)
    lam = jnp.where(lam > 0, lam, 1.0)
    factor = a_new / lam
    return factor, lam, factor.T @ factor


def cp_als_step(mttkrp_fn, state: CPState) -> CPState:
    """One full ALS sweep (all modes, Alg. 1 lines 2-6) + fit update, in place.

    ``mttkrp_fn`` is an engine plan, any ``.mttkrp``-bearing backend, or a
    bare callable returning the (I_mode, R) MTTKRP result (``as_mttkrp_fn``).
    Returns ``state`` for chaining; a converged state is returned unchanged.
    """
    if state.converged:
        return state
    mttkrp_fn = as_mttkrp_fn(mttkrp_fn)
    n_modes = len(state.dims)
    rank = state.rank
    dtype = state.factors[0].dtype
    factors, grams = state.factors, state.grams
    m_mat = None
    for n in range(n_modes):
        m_mat = mttkrp_fn(factors, n)                    # line 4
        factors[n], lam, grams[n] = sweep_mode_update(m_mat, grams, n)
        state.lam = lam

    # fit = 1 - ||X - X_hat||_F / ||X||_F, computed without materializing
    # X_hat (standard CP-ALS identity; m_mat is the last mode's MTTKRP).
    last = n_modes - 1
    v_all = jnp.ones((rank, rank), dtype)
    for m in range(n_modes):
        v_all = v_all * grams[m]
    norm_est_sq = state.lam @ (v_all @ state.lam)
    inner = jnp.sum(state.lam * jnp.sum(m_mat * factors[last], axis=0))
    resid_sq = jnp.maximum(state.norm_x ** 2 + norm_est_sq - 2.0 * inner, 0.0)
    fit = float(1.0 - jnp.sqrt(resid_sq) / state.norm_x)
    state.fits.append(fit)
    state.iteration += 1
    if abs(fit - state.prev_fit) < state.tol:
        state.converged = True
    state.prev_fit = fit
    return state


def cp_als(mttkrp_fn, dims, rank, *, norm_x: float, iters: int = 25,
           tol: float = 1e-5, seed: int = 0, dtype=jnp.float32,
           factors=None) -> CPResult:
    """Alternating least squares for rank-R CPD (one-shot driver).

    ``mttkrp_fn``: an engine plan / ``.mttkrp``-bearing backend or a bare
    callable (factors, mode) -> (I_mode, R) — see ``as_mttkrp_fn``.
    norm_x: Frobenius norm of the sparse tensor (sum of squared values)**0.5.
    """
    mttkrp_fn = as_mttkrp_fn(mttkrp_fn)
    state = cp_als_init(dims, rank, norm_x=norm_x, tol=tol, seed=seed,
                        dtype=dtype, factors=factors)
    for _ in range(iters):
        cp_als_step(mttkrp_fn, state)
        if state.converged:
            break
    return state.as_result()


def reconstruct_dense(result: CPResult) -> np.ndarray:
    """Dense reconstruction from factors (test oracle; small tensors only)."""
    factors = [np.asarray(f, np.float64) for f in result.factors]
    lam = np.asarray(result.lam, np.float64)
    rank = lam.shape[0]
    dims = [f.shape[0] for f in factors]
    out = np.zeros(dims)
    for r in range(rank):
        term = lam[r]
        vecs = [f[:, r] for f in factors]
        acc = vecs[0]
        for v in vecs[1:]:
            acc = np.multiply.outer(acc, v)
        out += term * acc
    return out
