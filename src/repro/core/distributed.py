"""Distributed sparse MTTKRP / CP-ALS over a device mesh (beyond-paper).

The paper targets a single GPU (+ host streaming). Scaling its format out to a
pod is natural because BLCO is list-based and mode-agnostic:

* **nnz parallelism (data axis)** — the sorted nnz stream is range-partitioned
  across devices (each shard holds whole launches); every device runs the same
  mode-agnostic launch kernel on its shard and the per-mode outputs are merged
  with one ``psum`` (or ``psum_scatter`` when the factor is row-sharded).
  Because partials are segment-compressed *before* the collective, the reduce
  payload per device is O(I_mode x R), independent of nnz.
* **rank parallelism (model axis)** — MTTKRP columns are independent, so the
  factor matrices shard along R with *zero* communication in MTTKRP itself;
  CP-ALS then needs only an R x R gram psum per mode (tiny).

This mirrors the DP x TP mesh used by the LM half of the framework and is
exercised on 8 fake XLA devices in tests and on the 16x16 / 2x16x16 meshes in
the dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map

from .blco import BLCOTensor
from .mttkrp import delinearize, _segment_compress


def shard_launch_arrays(blco: BLCOTensor, num_shards: int):
    """Range-partition the nnz stream into equal padded shards (host side).

    Returns dict of (num_shards, padded) arrays ready for device_put with a
    sharded layout. Each shard is independent: the segment discovery never
    crosses shard boundaries (a split segment just produces one extra merged
    update, exactly like the paper's tile-boundary handling).
    """
    n = blco.nnz
    per = -(-n // num_shards)
    padded = per * num_shards
    hi = np.zeros(padded, np.uint32); hi[:n] = blco.idx_hi
    lo = np.zeros(padded, np.uint32); lo[:n] = blco.idx_lo
    vals = np.zeros(padded, blco.values.dtype); vals[:n] = blco.values
    bases = np.zeros((padded, blco.order), np.int32)
    bases[:n] = blco.block_upper_bases()[blco.element_block_ids()]
    return {
        "idx_hi": hi.reshape(num_shards, per),
        "idx_lo": lo.reshape(num_shards, per),
        "vals": vals.reshape(num_shards, per),
        "bases": bases.reshape(num_shards, per, blco.order),
    }


def make_distributed_mttkrp(blco: BLCOTensor, mesh, *, data_axis="data",
                            model_axis="model"):
    """Build a jitted distributed mode-n MTTKRP over ``mesh``.

    Factors: replicated over data axis, sharded over model axis along R.
    nnz arrays: sharded over data axis (leading dim), replicated over model.
    """
    re_fields = blco.re.field_bits
    re_shifts = blco.re.field_shift
    n_modes = blco.order
    data_size = 1
    for ax in (data_axis if isinstance(data_axis, tuple) else (data_axis,)):
        data_size *= mesh.shape[ax]

    shards = shard_launch_arrays(blco, data_size)

    nnz_spec = P(data_axis)
    bases_spec = P(data_axis, None)
    factor_spec = P(None, model_axis)

    device_shards = {
        k: jax.device_put(v, jax.NamedSharding(
            mesh, bases_spec if k == "bases" else nnz_spec))
        for k, v in shards.items()
    }

    @functools.lru_cache(maxsize=None)
    def _build(mode: int):
        out_rows = blco.dims[mode]

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(nnz_spec, nnz_spec, nnz_spec, bases_spec,
                      tuple(factor_spec for _ in range(n_modes))),
            out_specs=factor_spec)
        def _shard_fn(hi, lo, vals, bases, factors):
            # each device holds exactly one shard row: drop the leading dim
            hi, lo, vals = hi.reshape(-1), lo.reshape(-1), vals.reshape(-1)
            bases = bases.reshape(-1, n_modes)
            coords = delinearize(re_fields, re_shifts, hi, lo)
            coords = [c + bases[:, m] for m, c in enumerate(coords)]
            partial = vals[:, None].astype(jnp.result_type(vals, factors[0]))
            for m, f in enumerate(factors):
                if m == mode:
                    continue
                partial = partial * jnp.take(f, coords[m], axis=0)
            seg_tgt, seg_sums = _segment_compress(coords[mode], partial)
            out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
            out = out.at[seg_tgt].add(seg_sums)
            # one collective per mode; payload O(I_mode x R_shard), nnz-independent
            return jax.lax.psum(out, data_axis)

        return jax.jit(_shard_fn)

    def run(factors, mode: int):
        return _build(mode)(device_shards["idx_hi"], device_shards["idx_lo"],
                            device_shards["vals"], device_shards["bases"],
                            tuple(factors))

    return run
