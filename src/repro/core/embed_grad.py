"""BLCO-style embedding-gradient accumulation — the paper's technique inside
the LM training path (DESIGN.md §5).

The backward pass of a token-embedding lookup is a sparse MTTKRP: the gradient
of the (V, D) table is X_(1) @ G where X is the sparse (vocab x position)
occurrence tensor of the batch and G the upstream gradients — i.e. many sparse
indexed updates into a dense table, with exactly the update-conflict structure
the paper attacks (hot tokens = dense fibers).

Two resolutions, mirroring core/mttkrp.py:

* ``scatter``  — naive per-token scatter-add (the COO baseline);
* ``segment``  — sort token ids (the 1-D analogue of ALTO linearization
  ordering), discover runs on the fly, segment-reduce, and issue one update
  per *distinct token* instead of per token occurrence (the BLCO conflict
  resolution). On TPU this converts a high-duplicate scatter into a
  sort + segmented reduction + low-duplicate scatter.

Selectable per-config via ``embed_grad={"scatter","segment"}``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _grad_scatter(ids, g, vocab):
    out = jnp.zeros((vocab, g.shape[-1]), g.dtype)
    return out.at[ids].add(g)


def _grad_segment(ids, g, vocab):
    """ids: (B, S); g: (B, S, D). The sort is per batch row so that under
    GSPMD (batch dim sharded over data axes) it stays device-local — no
    distributed sort; only the final per-segment scatter touches the sharded
    table, exactly like the paper's per-block independence."""
    b, s = ids.shape
    order = jnp.argsort(ids, axis=1)                    # row-local sort
    sid = jnp.take_along_axis(ids, order, axis=1)
    sg = jnp.take_along_axis(g, order[..., None], axis=1)
    flags = jnp.concatenate(
        [jnp.ones((b, 1), jnp.int32),
         (sid[:, 1:] != sid[:, :-1]).astype(jnp.int32)], axis=1)
    seg = jnp.cumsum(flags, axis=1) - 1                 # per-row segment ids
    flat_seg = (seg + jnp.arange(b, dtype=seg.dtype)[:, None] * s).reshape(-1)
    seg_sums = jax.ops.segment_sum(sg.reshape(b * s, -1), flat_seg,
                                   num_segments=b * s)
    seg_tgt = jnp.zeros((b * s,), ids.dtype).at[flat_seg].max(sid.reshape(-1))
    out = jnp.zeros((vocab, g.shape[-1]), g.dtype)
    return out.at[seg_tgt].add(seg_sums)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def embedding_lookup(table, ids, method: str = "segment"):
    """table: (V, D); ids: int array (any shape). Returns ids.shape + (D,)."""
    return jnp.take(table, ids, axis=0)


def _fwd(table, ids, method):
    return jnp.take(table, ids, axis=0), (ids, table.shape[0])


def _bwd(method, res, g):
    ids, vocab = res
    if method == "segment":
        ids2 = ids.reshape(ids.shape[0], -1) if ids.ndim >= 2 \
            else ids.reshape(1, -1)
        g3 = g.reshape(ids2.shape + (g.shape[-1],))
        dtable = _grad_segment(ids2, g3, vocab)
    elif method == "scatter":
        dtable = _grad_scatter(ids.reshape(-1),
                               g.reshape(-1, g.shape[-1]), vocab)
    else:
        raise ValueError(f"unknown embed_grad method {method!r}")
    return dtable, None


embedding_lookup.defvjp(_fwd, _bwd)
