"""Device-resident launch cache: padded launches prepared once, stacked.

The paper's BLCO pitch is that blocking "reduces kernel launching overhead";
PR 2's engine still paid one XLA dispatch and one host numpy padding pass
per launch per ``mttkrp`` call.  This module is the fix:

* :class:`LaunchCache` pads every launch to ONE reservation shape (reusing
  ``prepare_chunks``/``ReservationSpec`` from the streaming layer, so both
  regimes share the padding code and the byte accounting), stacks the
  chunks into ``(L, reservation)`` device arrays, and uploads them once;
* :func:`stacked_mttkrp` replaces the per-launch Python loop + ``out = out
  + ...`` chain with a single jitted ``lax.scan`` over the stacked
  launches — ONE dispatch per MTTKRP call regardless of launch count, and
  per-step intermediates (coordinates, gathered factor rows) bounded by the
  reservation size instead of the full nnz count.

The stacked arrays are also the zero-copy source for the fused Pallas
pipeline (``repro.kernels.fused``): ``flat()`` reshapes ``(L, reservation)``
to one contiguous ``(L * reservation,)`` stream on device, which the fused
kernel tiles directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace

from .blco import BLCOTensor
from .counters import record_dispatch
from .mttkrp import (DEFAULT_COPIES, choose_resolution, launch_mttkrp_impl)
from .padding import pad_bucket, pad_multiple


def default_reservation(max_launch: int) -> int:
    """The in-memory regime's default reservation for a given largest launch.

    Size-class rounding (``pad_bucket``): bounded distinct reservations
    (each reservation is a traced shape, i.e. a jit cache key for the
    stacked scan and the fused kernel), ≤ 25% padding waste.  The single
    definition the cache builder, the byte predictor and the trace-tier
    cache-churn audit all share — so the audited rounding IS the shipped
    rounding.
    """
    return pad_bucket(max_launch)


@functools.partial(
    jax.jit,
    static_argnames=("re_fields", "re_shifts", "mode", "out_rows",
                     "resolution", "copies"))
def stacked_mttkrp(hi, lo, vals, bases, factors, *,
                   re_fields: tuple, re_shifts: tuple, mode: int,
                   out_rows: int, resolution: str, copies: int):
    """Single-dispatch MTTKRP over stacked launches.

    hi/lo: (L, R) uint32; vals: (L, R); bases: (L, R, N) int32; factors:
    tuple of (I_n, R) arrays.  ``lax.scan`` runs the per-launch dataflow
    sequentially on device, accumulating into one (out_rows, rank) output —
    the launch order (and therefore the floating-point accumulation order)
    matches the legacy per-launch loop exactly.
    """
    factors = tuple(factors)
    rank = factors[0].shape[1]
    # accumulate at the promoted precision: float64 tensor values against
    # float32 factors must not be silently downcast by the accumulator
    out0 = jnp.zeros((out_rows, rank), jnp.result_type(vals, factors[0]))

    def body(out, xs):
        h, l, v, b = xs
        return out + launch_mttkrp_impl(
            h, l, v, b, factors, re_fields=re_fields, re_shifts=re_shifts,
            mode=mode, out_rows=out_rows, resolution=resolution,
            copies=copies), None

    out, _ = jax.lax.scan(body, out0, (hi, lo, vals, bases))
    return out


class LaunchCache:
    """Stacked, device-resident, reservation-padded launches of one tensor.

    Built once per plan; every ``mttkrp`` call afterwards is one jitted
    dispatch with zero host-side work.  The reservation defaults to the
    largest launch rounded up to a geometric size class
    (``default_reservation``): near-memory-tight (≤ 25% padding) while
    keeping the number of distinct traced shapes — and therefore compiled
    executables — logarithmic in launch size, unlike a bare ``LANE``
    multiple (the streaming regime uses coarser power-of-two cross-tensor
    buckets instead).

    Padding waste is bounded by construction: ``build_blco`` splits every
    block to ``max_nnz_per_block`` and greedily batches blocks into
    launches up to the same budget, so all launches except the final tail
    are at least ``budget - max_block`` nnz — stacking to the max-launch
    reservation is within a small constant of the tight footprint (there is
    no "one huge launch + many tiny ones" shape to blow it up).
    """

    def __init__(self, hi, lo, vals, bases, *, re_fields: tuple,
                 re_shifts: tuple, dims: tuple):
        self.hi = hi                    # (L, R) uint32
        self.lo = lo                    # (L, R) uint32
        self.vals = vals                # (L, R) float
        self.bases = bases              # (L, R, N) int32
        self.re_fields = tuple(re_fields)
        self.re_shifts = tuple(re_shifts)
        self.dims = tuple(dims)
        self.closed = False

    # ------------------------------------------------------------ construct
    @classmethod
    def from_blco(cls, blco: BLCOTensor,
                  reservation_nnz: int | None = None) -> "LaunchCache":
        """Pad + stack + upload every launch of ``blco`` (host work, once)."""
        from repro.faults import inject as faults
        from .streaming import prepare_chunks
        # the device-resident regime's single allocation moment: a real
        # RESOURCE_EXHAUSTED surfaces from the device_put below exactly
        # like this injected probe, and the plan_for/ServiceEngine ladder
        # demotes either to a streamed regime
        faults.maybe_fail("plan.alloc")
        max_launch = max((l.nnz for l in blco.launches), default=1)
        if reservation_nnz:
            if int(reservation_nnz) < max_launch:
                raise ValueError(
                    f"reservation {int(reservation_nnz)} smaller than "
                    f"largest launch ({max_launch} nnz)")
            # the byte predictor (launch_cache_bytes) and the fused Pallas
            # tiler both assume LANE-multiple reservations; a ragged explicit
            # reservation is rounded up, never honoured as-is
            res = pad_multiple(int(reservation_nnz))
        else:
            res = default_reservation(max_launch)
        chunks = prepare_chunks(blco, res)
        return cls.from_chunks(chunks, blco, reservation_nnz=res)

    @classmethod
    def from_chunks(cls, chunks, blco: BLCOTensor, *,
                    reservation_nnz: int) -> "LaunchCache":
        """Stack already reservation-padded chunks (e.g. a service handle's)."""
        n_launch = len(chunks)
        res = int(reservation_nnz)
        order = blco.order
        if n_launch:
            hi = np.stack([c[0] for c in chunks])
            lo = np.stack([c[1] for c in chunks])
            vals = np.stack([c[2] for c in chunks])
            bases = np.stack([c[3] for c in chunks])
        else:
            hi = np.zeros((0, res), np.uint32)
            lo = np.zeros((0, res), np.uint32)
            vals = np.zeros((0, res), blco.values.dtype)
            bases = np.zeros((0, res, order), np.int32)
        return cls(jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(vals),
                   jnp.asarray(bases), re_fields=blco.re.field_bits,
                   re_shifts=blco.re.field_shift, dims=blco.dims)

    # ------------------------------------------------------------ introspect
    @property
    def num_launches(self) -> int:
        return int(self.hi.shape[0])

    @property
    def reservation(self) -> int:
        return int(self.hi.shape[1])

    @property
    def order(self) -> int:
        return len(self.dims)

    def device_bytes(self) -> int:
        """Exact resident footprint: hi + lo + vals + bases (stacked)."""
        if self.closed:
            return 0
        return int(self.hi.nbytes + self.lo.nbytes + self.vals.nbytes
                   + self.bases.nbytes)

    def flat(self):
        """Device-side flat views: (T,) hi/lo/vals + (T, N) bases with
        ``T = L * reservation`` — the fused Pallas pipeline's input stream."""
        t = self.num_launches * self.reservation
        return (self.hi.reshape(t), self.lo.reshape(t), self.vals.reshape(t),
                self.bases.reshape(t, self.order))

    # --------------------------------------------------------------- compute
    def mttkrp(self, factors, mode: int, *, resolution: str = "auto",
               copies: int = DEFAULT_COPIES):
        """Single-dispatch MTTKRP (XLA scan path) from the cached launches."""
        if self.closed:
            raise RuntimeError("launch cache is closed")
        assert 0 <= mode < self.order
        if resolution == "auto":
            resolution = choose_resolution(self.dims[mode])
        factors = tuple(jnp.asarray(f) for f in factors)
        if self.num_launches == 0:
            rank = factors[0].shape[1]
            return jnp.zeros((self.dims[mode], rank),
                             jnp.result_type(self.vals, factors[0]))
        record_dispatch()
        # span covers the host-side issue of the one scan dispatch (async);
        # the fenced device time is the plan's device.fence event
        with obs_trace.span("launch_cache.scan", "dispatch",
                            launches=self.num_launches, mode=mode):
            return stacked_mttkrp(
                self.hi, self.lo, self.vals, self.bases, factors,
                re_fields=self.re_fields, re_shifts=self.re_shifts, mode=mode,
                out_rows=self.dims[mode], resolution=resolution, copies=copies)

    # ---------------------------------------------------------------- release
    def delete(self) -> None:
        """Release the device buffers (the cache must not be used after)."""
        self.closed = True
        for arr in (self.hi, self.lo, self.vals, self.bases):
            try:
                arr.delete()
            except Exception:   # already deleted / backend without delete()
                pass


def launch_cache_bytes(blco: BLCOTensor) -> int:
    """Predicted device footprint of a ``LaunchCache`` for ``blco``:
    L stacked launches x (hi + lo + vals + bases) at the default
    size-class reservation — what ``DeviceBLCO``/``InMemoryPlan`` hold."""
    if not blco.launches:
        return 0
    max_launch = max(l.nnz for l in blco.launches)
    res = default_reservation(max_launch)
    per_elem = 4 + 4 + blco.values.dtype.itemsize + 4 * blco.order
    return len(blco.launches) * res * per_elem
