"""ALTO-ordered linearization and BLCO re-encoding (paper §4.1).

Two index encodings are in play:

* **ALTO index** — bits of the per-mode coordinates interleaved round-robin
  (LSB-first over modes that still have bits left), i.e. the adaptive
  space-filling-curve order of the ALTO paper, which BLCO adopts as its nnz
  *ordering*. Used only on the host, for sorting and for deriving block keys.
  Up to 128 bits, held as (hi, lo) uint64 word pairs.

* **BLCO re-encoded index** — the *stored* per-nnz index: each mode's surviving
  (in-block) bits packed into a contiguous field so that de-linearization on
  device is a single shift+mask per mode (paper Fig. 6b). At most 64 bits by
  construction (adaptive blocking strips the excess), stored device-side as
  (hi, lo) uint32 pairs.

All construction is vectorized numpy on the host — the paper likewise builds the
format on the CPU (§6.5) — and is benchmarked in benchmarks/format_construction.
"""
from __future__ import annotations

import dataclasses
import numpy as np

U64_1 = np.uint64(1)


def mode_bits(dims) -> list[int]:
    """Bits needed per mode: ceil(log2(I_n)), min 1."""
    out = []
    for d in dims:
        d = int(d)
        assert d >= 1
        out.append(max(1, int(np.ceil(np.log2(d))) if d > 1 else 1))
    return out


def alto_bit_positions(dims) -> list[list[int]]:
    """ALTO bit layout: positions[n] = global bit positions (LSB→MSB) receiving
    successive bits (LSB→MSB) of mode n's coordinate.

    Round-robin from bit 0 over modes with bits remaining; modes with fewer bits
    drop out early, so the uppermost positions belong to the longest modes —
    matching ALTO's adaptive interleaving (paper Fig. 6a shows the special case
    of equal mode lengths, i.e. Morton order).
    """
    bits = mode_bits(dims)
    positions: list[list[int]] = [[] for _ in dims]
    taken = [0] * len(dims)
    p = 0
    while any(t < b for t, b in zip(taken, bits)):
        for n in range(len(dims)):
            if taken[n] < bits[n]:
                positions[n].append(p)
                taken[n] += 1
                p += 1
    return positions


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Static description of one tensor's linearization."""
    dims: tuple[int, ...]
    bits: tuple[int, ...]            # bits per mode
    positions: tuple[tuple[int, ...], ...]  # ALTO positions per mode
    total_bits: int

    @staticmethod
    def make(dims) -> "LinearSpec":
        # the whole device pipeline carries coordinates, block bases and
        # gather indices as int32, and the 32-bit-word field extraction
        # (core.u64.extract_field) asserts width <= 32 — a mode longer
        # than 2^31 would pass construction here and crash (or wrap) deep
        # inside a traced kernel; reject it at the API boundary instead
        for d in dims:
            if int(d) > 1 << 31:
                raise ValueError(
                    f"mode length {int(d)} exceeds 2^31; coordinates are "
                    f"int32 throughout the device pipeline")
        bits = mode_bits(dims)
        pos = alto_bit_positions(dims)
        total = sum(bits)
        if total > 128:
            raise ValueError(f"tensor needs {total} index bits; >128 unsupported")
        return LinearSpec(tuple(int(d) for d in dims), tuple(bits),
                          tuple(tuple(p) for p in pos), total)


def alto_encode(spec: LinearSpec, indices: np.ndarray):
    """(nnz, N) int64 coords -> ALTO index as (hi, lo) uint64 arrays."""
    nnz = indices.shape[0]
    hi = np.zeros(nnz, dtype=np.uint64)
    lo = np.zeros(nnz, dtype=np.uint64)
    for n, positions in enumerate(spec.positions):
        coord = indices[:, n].astype(np.uint64)
        for b, p in enumerate(positions):
            bit = (coord >> np.uint64(b)) & U64_1
            if p < 64:
                lo |= bit << np.uint64(p)
            else:
                hi |= bit << np.uint64(p - 64)
    return hi, lo


def alto_decode(spec: LinearSpec, hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """Inverse of alto_encode (host-side; used in tests and format checks)."""
    nnz = hi.shape[0]
    out = np.zeros((nnz, len(spec.dims)), dtype=np.int64)
    for n, positions in enumerate(spec.positions):
        coord = np.zeros(nnz, dtype=np.uint64)
        for b, p in enumerate(positions):
            bit = ((lo >> np.uint64(p)) if p < 64 else (hi >> np.uint64(p - 64))) & U64_1
            coord |= bit << np.uint64(b)
        out[:, n] = coord.astype(np.int64)
    return out


def sort_by_alto(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """Permutation sorting nnz by 128-bit ALTO index (hi major)."""
    return np.lexsort((lo, hi))


# ---------------------------------------------------------------- re-encoding
@dataclasses.dataclass(frozen=True)
class ReencodeSpec:
    """Contiguous-field layout for in-block (BLCO) indices.

    field_bits[n]  : surviving bits of mode n inside a block
    field_shift[n] : LSB position of mode n's field in the 64-bit stored index
    block_bits[n]  : bits of mode n stripped into the block key
    """
    field_bits: tuple[int, ...]
    field_shift: tuple[int, ...]
    block_bits: tuple[int, ...]

    @property
    def inblock_bits(self) -> int:
        return sum(self.field_bits)


def reencode_spec(spec: LinearSpec, target_bits: int = 64) -> ReencodeSpec:
    """Decide which bits are stripped to the block key (paper §4.2).

    The uppermost ``total_bits - target_bits`` bits *of the ALTO layout* are
    stripped; because ALTO interleaves, they come "from every mode" exactly as
    the paper prescribes. The survivors are packed contiguously, mode 0 lowest.
    """
    strip_from = max(0, spec.total_bits - target_bits)  # number of top bits stripped
    cutoff = spec.total_bits - strip_from               # ALTO positions >= cutoff go to key
    field_bits = []
    block_bits = []
    for n, positions in enumerate(spec.positions):
        inblock = sum(1 for p in positions if p < cutoff)
        field_bits.append(inblock)
        block_bits.append(spec.bits[n] - inblock)
    shifts = []
    acc = 0
    for fb in field_bits:
        shifts.append(acc)
        acc += fb
    assert acc <= target_bits
    return ReencodeSpec(tuple(field_bits), tuple(shifts), tuple(block_bits))


def block_key(spec: LinearSpec, re: ReencodeSpec, hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """Top ALTO bits as the block key (uint64; stripped bits always <= 64)."""
    cutoff = spec.total_bits - sum(re.block_bits)
    if sum(re.block_bits) > 64:
        raise ValueError("block key wider than 64 bits unsupported")
    if cutoff >= 64:
        return hi >> np.uint64(cutoff - 64)
    # key straddles: low part from lo, high part from hi
    key = lo >> np.uint64(cutoff)
    if spec.total_bits > 64:
        key |= hi << np.uint64(64 - cutoff)
    mask_bits = sum(re.block_bits)
    if mask_bits < 64:
        key &= (U64_1 << np.uint64(mask_bits)) - U64_1
    return key


def key_to_upper_coords(spec: LinearSpec, re: ReencodeSpec, key: int) -> np.ndarray:
    """Recover each mode's stripped upper coordinate bits from a block key.

    Returns (N,) int64 b where mode-n original coord = (b[n] << field_bits[n]) | field.
    """
    cutoff = spec.total_bits - sum(re.block_bits)
    out = np.zeros(len(spec.dims), dtype=np.int64)
    for n, positions in enumerate(spec.positions):
        v = 0
        for b, p in enumerate(positions):
            if p >= cutoff:
                bit = (int(key) >> (p - cutoff)) & 1
                v |= bit << (b - re.field_bits[n])
        out[n] = v
    return out


def reencode(spec: LinearSpec, re: ReencodeSpec, indices: np.ndarray) -> np.ndarray:
    """(nnz, N) coords -> 64-bit BLCO stored index (contiguous fields)."""
    out = np.zeros(indices.shape[0], dtype=np.uint64)
    for n in range(len(spec.dims)):
        fb = re.field_bits[n]
        if fb == 0:
            continue
        field = indices[:, n].astype(np.uint64) & ((U64_1 << np.uint64(fb)) - U64_1)
        out |= field << np.uint64(re.field_shift[n])
    return out


def delinearize_host(re: ReencodeSpec, stored: np.ndarray, upper: np.ndarray) -> np.ndarray:
    """Host-side inverse of `reencode` given the block's upper coords (oracle)."""
    nnz = stored.shape[0]
    n_modes = len(re.field_bits)
    out = np.zeros((nnz, n_modes), dtype=np.int64)
    for n in range(n_modes):
        fb = re.field_bits[n]
        field = (stored >> np.uint64(re.field_shift[n])) & ((U64_1 << np.uint64(fb)) - U64_1) \
            if fb else np.zeros(nnz, np.uint64)
        out[:, n] = (int(upper[n]) << fb) | field.astype(np.int64)
    return out
