"""Mode-agnostic BLCO MTTKRP with opportunistic conflict resolution (paper §5).

One implementation serves *every* mode — the paper's headline property. Per
launch the dataflow is the paper's two phases:

  processing phase: coalesced load of (hi, lo) stored indices -> shift+mask
      de-linearization of every mode (§5.1.1);
  computing phase:  gather non-target factor rows -> hadamard x value ->
      on-the-fly segment discovery on the target-index stream -> segmented
      reduction -> one update per *segment* (not per nnz) into the output
      (§5.1.2), either directly ("register" resolution, §5.2) or via C partial
      copies merged at the end ("hierarchical" resolution, §5.1 steps 5-7).

The XLA path below is the faithful reference dataflow; `repro.kernels` provides
the fused Pallas-TPU version of the computing phase. Both are validated against
the dense matricization oracle in tests.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import u64
from .blco import BLCOTensor

# TPU analogue of the paper's "#SMs" constant in the §5.3 heuristic: below this
# target-mode length, update contention dominates and the hierarchical
# (multi-copy) mechanism wins; above it, direct per-segment updates win.
CONTENTION_THRESHOLD = 128
DEFAULT_COPIES = 8


def choose_resolution(mode_len: int, threshold: int = CONTENTION_THRESHOLD) -> str:
    """Paper §5.3 adaptation heuristic, re-keyed for TPU (DESIGN.md §2)."""
    return "hierarchical" if mode_len < threshold else "register"


def delinearize(re_fields, re_shifts, idx_hi, idx_lo):
    """Recover all mode coordinates from stored (hi, lo) uint32 index words.

    re_fields/re_shifts: static tuples. Returns list of int32 arrays (no block
    base applied).
    """
    coords = []
    for shift, width in zip(re_shifts, re_fields):
        coords.append(u64.extract_field(idx_hi, idx_lo, shift, width).astype(jnp.int32))
    return coords


def _segment_compress(tgt, partial):
    """On-the-fly segment discovery + segmented reduction (paper §5.1 steps 3-5).

    tgt: (T,) int32 target-mode indices in ALTO order (NOT sorted by target —
    segments are runs of equal target, discovered on the fly, exactly the
    paper's opportunistic scheme). Returns (seg_tgt, seg_sums) of length T where
    only the first #segments rows are meaningful; the rest are (0, 0-rows).
    """
    n = tgt.shape[0]
    flags = jnp.concatenate([jnp.ones((1,), jnp.int32),
                             (tgt[1:] != tgt[:-1]).astype(jnp.int32)])
    seg_id = jnp.cumsum(flags) - 1                       # (T,) 0-based segment ids
    seg_sums = jax.ops.segment_sum(partial, seg_id, num_segments=n)
    seg_tgt = jnp.zeros((n,), jnp.int32).at[seg_id].max(tgt)
    return seg_tgt, seg_sums


@functools.partial(
    jax.jit,
    static_argnames=("re_fields", "re_shifts", "mode", "out_rows",
                     "resolution", "copies"))
def launch_mttkrp(idx_hi, idx_lo, vals, bases, factors, *,
                  re_fields: tuple, re_shifts: tuple, mode: int, out_rows: int,
                  resolution: str, copies: int):
    """MTTKRP for one launch (a batch of BLCO blocks).

    idx_hi/idx_lo: (T,) uint32 stored indices. vals: (T,). bases: (T, N) int32
    per-element block coordinate bases (upper bits << field width). factors:
    tuple of (I_n, R) arrays. Returns (out_rows, R) partial output.
    """
    coords = delinearize(re_fields, re_shifts, idx_hi, idx_lo)
    coords = [c + bases[:, n] for n, c in enumerate(coords)]

    partial = vals[:, None].astype(factors[0].dtype)
    for m, f in enumerate(factors):
        if m == mode:
            continue
        partial = partial * jnp.take(f, coords[m], axis=0)
    tgt = coords[mode]

    if resolution == "direct":
        # per-nnz scatter (no conflict resolution) — the COO dataflow on the
        # BLCO layout; cheapest on hardware with fast serialized scatter
        # (CPU); the paper's mechanisms below win where conflicting updates
        # serialize (GPU atomics / TPU scatter with duplicate rows).
        out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
        return out.at[tgt].add(partial)

    seg_tgt, seg_sums = _segment_compress(tgt, partial)

    if resolution == "register":
        out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
        return out.at[seg_tgt].add(seg_sums)
    elif resolution == "hierarchical":
        # Spread segments over C partial copies (paper's factor-matrix copies,
        # step 6) and merge (step 7). Reduces duplicate-row scatter contention.
        n = seg_tgt.shape[0]
        copy_id = (jnp.arange(n, dtype=jnp.int32) % copies)
        out = jnp.zeros((copies, out_rows, partial.shape[1]), partial.dtype)
        out = out.at[copy_id, seg_tgt].add(seg_sums)
        return out.sum(axis=0)
    raise ValueError(f"unknown resolution {resolution!r}")


def _pad_pow2(n: int, floor: int = 256) -> int:
    return max(floor, 1 << math.ceil(math.log2(max(1, n))))


def mttkrp(blco: BLCOTensor, factors, mode: int, *,
           resolution: str = "auto", copies: int = DEFAULT_COPIES,
           pad: bool = True):
    """Full mode-n MTTKRP over all launches of a BLCO tensor.

    factors: list/tuple of N device arrays (I_n, R). Returns (I_mode, R).
    Launches are padded to power-of-two sizes so each bucket compiles once —
    the analogue of the paper's fixed per-queue memory reservations.
    """
    assert 0 <= mode < blco.order
    if resolution == "auto":
        resolution = choose_resolution(blco.dims[mode])
    factors = tuple(jnp.asarray(f) for f in factors)
    rank = factors[0].shape[1]
    out = jnp.zeros((blco.dims[mode], rank), factors[0].dtype)

    bases_all = blco.block_upper_bases()           # (num_blocks, N)
    block_ids = blco.element_block_ids()           # (nnz,)
    for launch in blco.launches:
        s, e = launch.start, launch.end
        n = e - s
        padded = _pad_pow2(n) if pad else n
        hi = np.zeros(padded, np.uint32)
        lo = np.zeros(padded, np.uint32)
        vals = np.zeros(padded, blco.values.dtype)
        bases = np.zeros((padded, blco.order), np.int32)
        hi[:n] = blco.idx_hi[s:e]
        lo[:n] = blco.idx_lo[s:e]
        vals[:n] = blco.values[s:e]
        bases[:n] = bases_all[block_ids[s:e]]
        out = out + launch_mttkrp(
            jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(vals),
            jnp.asarray(bases), factors,
            re_fields=blco.re.field_bits, re_shifts=blco.re.field_shift,
            mode=mode, out_rows=blco.dims[mode],
            resolution=resolution, copies=copies)
    return out


class DeviceBLCO:
    """Device-resident BLCO tensor for in-memory benchmarking/serving.

    All nnz arrays are uploaded once (the paper's in-memory regime: the
    tensor lives in device HBM across CP-ALS iterations); each ``mttkrp``
    call is a single jitted dispatch with zero host work.
    """

    def __init__(self, blco: BLCOTensor):
        n = blco.nnz
        padded = -(-n // 256) * 256          # pad to lane multiple, not pow2
        hi = np.zeros(padded, np.uint32); hi[:n] = blco.idx_hi
        lo = np.zeros(padded, np.uint32); lo[:n] = blco.idx_lo
        vals = np.zeros(padded, blco.values.dtype); vals[:n] = blco.values
        bases = np.zeros((padded, blco.order), np.int32)
        bases[:n] = blco.block_upper_bases()[blco.element_block_ids()]
        self.idx_hi = jnp.asarray(hi)
        self.idx_lo = jnp.asarray(lo)
        self.vals = jnp.asarray(vals)
        self.bases = jnp.asarray(bases)
        self.re_fields = blco.re.field_bits
        self.re_shifts = blco.re.field_shift
        self.dims = blco.dims
        self.order = blco.order

    def device_bytes(self) -> int:
        """Exact device footprint: hi + lo + vals + bases (padded)."""
        return int(self.idx_hi.nbytes + self.idx_lo.nbytes + self.vals.nbytes
                   + self.bases.nbytes)

    def mttkrp(self, factors, mode: int, *, resolution: str = "auto",
               copies: int = DEFAULT_COPIES):
        if resolution == "auto":
            resolution = choose_resolution(self.dims[mode])
        if self.idx_hi.shape[0] == 0:
            rank = factors[0].shape[1]
            return jnp.zeros((self.dims[mode], rank), factors[0].dtype)
        return launch_mttkrp(
            self.idx_hi, self.idx_lo, self.vals, self.bases, tuple(factors),
            re_fields=self.re_fields, re_shifts=self.re_shifts, mode=mode,
            out_rows=self.dims[mode], resolution=resolution, copies=copies)

    def delete(self) -> None:
        """Release the device buffers (the arrays must not be used after)."""
        for arr in (self.idx_hi, self.idx_lo, self.vals, self.bases):
            try:
                arr.delete()
            except Exception:   # already deleted / backend without delete()
                pass


# --------------------------------------------------------------------- oracle
def khatri_rao(mats) -> np.ndarray:
    """Column-wise Kronecker product of a list of (I_n, R) matrices."""
    out = mats[0]
    for m in mats[1:]:
        r = out.shape[1]
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, r)
    return out


def mttkrp_dense_oracle(t, factors, mode: int) -> np.ndarray:
    """Dense-matricization oracle: X_(n) @ KR(...) over the non-target modes.

    The element-wise MTTKRP result is convention-independent; what matters is
    that the matricization's column ordering matches the Khatri-Rao row
    ordering. `SparseTensor.matricize` uses a C-order reshape (highest
    remaining mode varies fastest), so the KR list must be ascending (lowest
    mode listed first = slowest-varying).
    """
    xs = t.matricize(mode).astype(np.float64)
    others = [np.asarray(factors[m], np.float64)
              for m in range(len(factors)) if m != mode]
    kr = khatri_rao(others)
    return xs @ kr
