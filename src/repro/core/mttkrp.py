"""Mode-agnostic BLCO MTTKRP with opportunistic conflict resolution (paper §5).

One implementation serves *every* mode — the paper's headline property. Per
launch the dataflow is the paper's two phases:

  processing phase: coalesced load of (hi, lo) stored indices -> shift+mask
      de-linearization of every mode (§5.1.1);
  computing phase:  gather non-target factor rows -> hadamard x value ->
      on-the-fly segment discovery on the target-index stream -> segmented
      reduction -> one update per *segment* (not per nnz) into the output
      (§5.1.2), either directly ("register" resolution, §5.2) or via C partial
      copies merged at the end ("hierarchical" resolution, §5.1 steps 5-7).

The XLA path below is the faithful reference dataflow; `repro.kernels`
provides the fused single-``pallas_call`` version of the whole pipeline.
Both are validated against the dense matricization oracle in tests.

Execution is launch-cache driven: ``mttkrp`` pads the launches ONCE into a
device-resident :class:`repro.core.launches.LaunchCache` and then every call
is a single jitted dispatch (``lax.scan`` over the stacked launches) with
zero host-side work.  ``mttkrp_per_launch`` keeps the old per-launch
loop — one dispatch and one numpy padding pass per launch per call — as the
benchmark baseline the fused path is measured against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import u64
from .blco import BLCOTensor
from .counters import record_dispatch
from .padding import pad_pow2

# TPU analogue of the paper's "#SMs" constant in the §5.3 heuristic: below this
# target-mode length, update contention dominates and the hierarchical
# (multi-copy) mechanism wins; above it, direct per-segment updates win.
CONTENTION_THRESHOLD = 128
DEFAULT_COPIES = 8

KERNELS = ("xla", "pallas")


def validate_kernel(kernel: str) -> str:
    """Reject unknown compute-kernel names (one validator for every layer)."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of "
                         f"{KERNELS}")
    return kernel


def choose_resolution(mode_len: int, threshold: int = CONTENTION_THRESHOLD) -> str:
    """Paper §5.3 adaptation heuristic, re-keyed for TPU (DESIGN.md §2)."""
    return "hierarchical" if mode_len < threshold else "register"


def delinearize(re_fields, re_shifts, idx_hi, idx_lo):
    """Recover all mode coordinates from stored (hi, lo) uint32 index words.

    re_fields/re_shifts: static tuples. Returns list of int32 arrays (no block
    base applied).
    """
    coords = []
    for shift, width in zip(re_shifts, re_fields):
        coords.append(u64.extract_field(idx_hi, idx_lo, shift, width).astype(jnp.int32))
    return coords


def _segment_compress(tgt, partial):
    """On-the-fly segment discovery + segmented reduction (paper §5.1 steps 3-5).

    tgt: (T,) int32 target-mode indices in ALTO order (NOT sorted by target —
    segments are runs of equal target, discovered on the fly, exactly the
    paper's opportunistic scheme). Returns (seg_tgt, seg_sums) of length T where
    only the first #segments rows are meaningful; the rest are (0, 0-rows).
    """
    n = tgt.shape[0]
    flags = jnp.concatenate([jnp.ones((1,), jnp.int32),
                             (tgt[1:] != tgt[:-1]).astype(jnp.int32)])
    seg_id = jnp.cumsum(flags) - 1                       # (T,) 0-based segment ids
    seg_sums = jax.ops.segment_sum(partial, seg_id, num_segments=n)
    seg_tgt = jnp.zeros((n,), jnp.int32).at[seg_id].max(tgt)
    return seg_tgt, seg_sums


def launch_mttkrp_impl(idx_hi, idx_lo, vals, bases, factors, *,
                       re_fields: tuple, re_shifts: tuple, mode: int,
                       out_rows: int, resolution: str, copies: int):
    """One launch's MTTKRP dataflow (traceable; reused under ``lax.scan``).

    idx_hi/idx_lo: (T,) uint32 stored indices. vals: (T,). bases: (T, N) int32
    per-element block coordinate bases (upper bits << field width). factors:
    tuple of (I_n, R) arrays. Returns (out_rows, R) partial output.
    """
    coords = delinearize(re_fields, re_shifts, idx_hi, idx_lo)
    coords = [c + bases[:, n] for n, c in enumerate(coords)]

    # promote, never downcast: float64 values against float32 factors
    # accumulate in float64 (jnp.result_type), on every kernel path
    partial = vals[:, None].astype(jnp.result_type(vals, factors[0]))
    for m, f in enumerate(factors):
        if m == mode:
            continue
        partial = partial * jnp.take(f, coords[m], axis=0)
    tgt = coords[mode]

    if resolution == "direct":
        # per-nnz scatter (no conflict resolution) — the COO dataflow on the
        # BLCO layout; cheapest on hardware with fast serialized scatter
        # (CPU); the paper's mechanisms below win where conflicting updates
        # serialize (GPU atomics / TPU scatter with duplicate rows).
        out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
        return out.at[tgt].add(partial)

    seg_tgt, seg_sums = _segment_compress(tgt, partial)

    if resolution == "register":
        out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
        return out.at[seg_tgt].add(seg_sums)
    elif resolution == "hierarchical":
        # Spread segments over C partial copies (paper's factor-matrix copies,
        # step 6) and merge (step 7). Reduces duplicate-row scatter contention.
        n = seg_tgt.shape[0]
        copy_id = (jnp.arange(n, dtype=jnp.int32) % copies)
        out = jnp.zeros((copies, out_rows, partial.shape[1]), partial.dtype)
        out = out.at[copy_id, seg_tgt].add(seg_sums)
        return out.sum(axis=0)
    raise ValueError(f"unknown resolution {resolution!r}")


launch_mttkrp = functools.partial(
    jax.jit,
    static_argnames=("re_fields", "re_shifts", "mode", "out_rows",
                     "resolution", "copies"))(launch_mttkrp_impl)


def launch_cache_for(blco: BLCOTensor):
    """The tensor's attached device-resident launch cache (built once).

    The cache holds device memory for the tensor's lifetime and is NOT
    visible to engine/service admission accounting — it backs the
    free-function ``mttkrp`` convenience API only.  Engine plans build and
    own their own cache (via ``DeviceBLCO``) so that ``plan.close()`` can
    release it without invalidating other users.  Call
    :func:`clear_launch_cache` to drop the attached copy.
    """
    from .launches import LaunchCache
    cache = getattr(blco, "_launch_cache", None)
    if cache is None or cache.closed:
        cache = LaunchCache.from_blco(blco)
        blco._launch_cache = cache
    return cache


def clear_launch_cache(blco: BLCOTensor) -> int:
    """Release the launch cache attached by ``mttkrp``/``launch_cache_for``.

    Returns the device bytes freed (0 when no cache was attached).
    """
    cache = getattr(blco, "_launch_cache", None)
    if cache is None:
        return 0
    freed = cache.device_bytes()
    cache.delete()
    blco._launch_cache = None
    return freed


def mttkrp(blco: BLCOTensor, factors, mode: int, *,
           resolution: str = "auto", copies: int = DEFAULT_COPIES,
           pad: bool = True, cache=None):
    """Full mode-n MTTKRP over all launches of a BLCO tensor.

    factors: list/tuple of N device arrays (I_n, R). Returns (I_mode, R).

    The padded launches are prepared ONCE (a device-resident ``LaunchCache``
    attached to ``blco``, or pass ``cache=`` explicitly) and the whole call
    is a single jitted ``lax.scan`` dispatch — zero per-call host work.
    ``pad=False`` keeps the exact-shape per-launch reference path (one
    dispatch per launch, no padding slots) used by the padding-exactness
    property tests.
    """
    assert 0 <= mode < blco.order
    if not pad:
        return mttkrp_per_launch(blco, factors, mode, resolution=resolution,
                                 copies=copies, pad=False)
    cache = cache if cache is not None else launch_cache_for(blco)
    return cache.mttkrp(factors, mode, resolution=resolution, copies=copies)


def mttkrp_per_launch(blco: BLCOTensor, factors, mode: int, *,
                      resolution: str = "auto", copies: int = DEFAULT_COPIES,
                      pad: bool = True):
    """The pre-launch-cache reference path: one host padding pass + one
    jitted dispatch PER LAUNCH per call.

    Kept as (a) the exact-shape ``pad=False`` oracle for the padding
    property tests and (b) the benchmark baseline that ``BENCH_3.json``
    measures the single-dispatch paths against.
    """
    assert 0 <= mode < blco.order
    if resolution == "auto":
        resolution = choose_resolution(blco.dims[mode])
    factors = tuple(jnp.asarray(f) for f in factors)
    rank = factors[0].shape[1]
    out = jnp.zeros((blco.dims[mode], rank),
                    jnp.result_type(jnp.asarray(blco.values[:0]), factors[0]))

    bases_all = blco.block_upper_bases()           # (num_blocks, N)
    block_ids = blco.element_block_ids()           # (nnz,)
    for launch in blco.launches:
        s, e = launch.start, launch.end
        n = e - s
        padded = pad_pow2(n) if pad else n
        hi = np.zeros(padded, np.uint32)
        lo = np.zeros(padded, np.uint32)
        vals = np.zeros(padded, blco.values.dtype)
        bases = np.zeros((padded, blco.order), np.int32)
        hi[:n] = blco.idx_hi[s:e]
        lo[:n] = blco.idx_lo[s:e]
        vals[:n] = blco.values[s:e]
        bases[:n] = bases_all[block_ids[s:e]]
        record_dispatch()
        out = out + launch_mttkrp(
            jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(vals),
            jnp.asarray(bases), factors,
            re_fields=blco.re.field_bits, re_shifts=blco.re.field_shift,
            mode=mode, out_rows=blco.dims[mode],
            resolution=resolution, copies=copies)
    return out


class DeviceBLCO:
    """Device-resident BLCO tensor for in-memory benchmarking/serving.

    The paper's in-memory regime: the padded launches are uploaded once (a
    stacked :class:`~repro.core.launches.LaunchCache`) and every ``mttkrp``
    call is a single jitted dispatch with zero host work — a ``lax.scan``
    over the stacked launches on the XLA path, or one fused ``pallas_call``
    pipeline on the Pallas path (``kernel="pallas"``).
    """

    def __init__(self, blco: BLCOTensor, *, kernel: str = "xla",
                 reservation_nnz: int | None = None, interpret: bool = True):
        from .launches import LaunchCache
        validate_kernel(kernel)
        self.cache = LaunchCache.from_blco(blco,
                                           reservation_nnz=reservation_nnz)
        self.dims = blco.dims
        self.order = blco.order
        self.kernel = kernel
        self.interpret = interpret

    def device_bytes(self) -> int:
        """Exact device footprint: hi + lo + vals + bases (stacked, padded)."""
        return self.cache.device_bytes()

    def mttkrp(self, factors, mode: int, *, resolution: str = "auto",
               copies: int = DEFAULT_COPIES, kernel: str | None = None):
        kernel = kernel if kernel is not None else self.kernel
        if kernel == "pallas":
            from repro.kernels.fused import fused_cache_mttkrp
            return fused_cache_mttkrp(self.cache, factors, mode,
                                      resolution=resolution,
                                      interpret=self.interpret)
        return self.cache.mttkrp(factors, mode, resolution=resolution,
                                 copies=copies)

    def delete(self) -> None:
        """Release the device buffers (the arrays must not be used after)."""
        self.cache.delete()


# --------------------------------------------------------------------- oracle
def khatri_rao(mats) -> np.ndarray:
    """Column-wise Kronecker product of a list of (I_n, R) matrices."""
    out = mats[0]
    for m in mats[1:]:
        r = out.shape[1]
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, r)
    return out


def mttkrp_dense_oracle(t, factors, mode: int) -> np.ndarray:
    """Dense-matricization oracle: X_(n) @ KR(...) over the non-target modes.

    The element-wise MTTKRP result is convention-independent; what matters is
    that the matricization's column ordering matches the Khatri-Rao row
    ordering. `SparseTensor.matricize` uses a C-order reshape (highest
    remaining mode varies fastest), so the KR list must be ascending (lowest
    mode listed first = slowest-varying).
    """
    xs = t.matricize(mode).astype(np.float64)
    others = [np.asarray(factors[m], np.float64)
              for m in range(len(factors)) if m != mode]
    kr = khatri_rao(others)
    return xs @ kr
