"""Shared launch-padding arithmetic (one home for the repo's three copies).

Every execution path pads launches to a fixed *reservation* so device
buffer shapes (and therefore compiled executables) are reused — the JAX
analogue of the paper's fixed per-queue memory reservations (§4.2).  Two
roundings are in deliberate use:

* ``next_pow2`` / ``pad_pow2`` — power-of-two buckets, so *different*
  tensors whose largest launches land in the same bucket share one
  compiled executable (the streaming regime's cross-tensor reuse);
* ``pad_multiple`` — round up to a lane/tile multiple only, for callers
  that pinned an explicit reservation and just need it tile-divisible;
* ``pad_bucket`` — geometric size classes (at most ``2 + 8·octaves``
  distinct values up to any bound), the in-memory regime's default
  reservation.  ``pad_multiple`` alone admits O(max_launch / LANE)
  distinct reservation shapes — and therefore that many jit cache
  entries for the stacked scan — which the trace-tier cache-churn audit
  (``repro.analysis.trace.cachekeys``) flags as unbounded in launch
  shape.  Size classes cap the executable count at O(log max_launch)
  while keeping padding waste ≤ 25% (vs up to 2x for pure pow2
  buckets).

``LANE`` is the TPU lane count: nnz buffers are kept at a multiple of it
so vector loads are aligned and every Pallas tile size that divides the
reservation also divides the total.
"""
from __future__ import annotations

import math

LANE = 256


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 << max(0, math.ceil(math.log2(max(1, n))))


def pad_pow2(n: int, floor: int = LANE) -> int:
    """Power-of-two bucket for ``n``, never below ``floor``."""
    return max(floor, next_pow2(n))


def pad_multiple(n: int, multiple: int = LANE) -> int:
    """Round ``n`` up to a multiple (minimum one multiple)."""
    return max(multiple, -(-n // multiple) * multiple)


def pad_bucket(n: int, multiple: int = LANE) -> int:
    """Size-class rounding: round ``n`` up to the next of 8 geometrically
    spaced classes per power-of-two octave (classes are LANE multiples).

    With ``n`` in (2^(k-1), 2^k] the class step is ``max(multiple,
    2^(k-3))``, so the overshoot is < 2^(k-3) < n/4 — at most 25% padded
    waste — while the number of distinct buckets below any bound N is at
    most ``8·log2(N)`` plus a constant.  That bound is what keeps the
    in-memory regime's jit cache (reservation is a traced shape) from
    growing linearly with launch size.
    """
    n = max(int(n), multiple)
    step = max(multiple, (1 << (n - 1).bit_length()) >> 3)
    return -(-n // step) * step
