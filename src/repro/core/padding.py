"""Shared launch-padding arithmetic (one home for the repo's three copies).

Every execution path pads launches to a fixed *reservation* so device
buffer shapes (and therefore compiled executables) are reused — the JAX
analogue of the paper's fixed per-queue memory reservations (§4.2).  Two
roundings are in deliberate use:

* ``next_pow2`` / ``pad_pow2`` — power-of-two buckets, so *different*
  tensors whose largest launches land in the same bucket share one
  compiled executable (the streaming regime's cross-tensor reuse);
* ``pad_multiple`` — round up to a lane/tile multiple only, the memory-
  tight choice for a device-resident copy whose shapes are private to one
  tensor anyway (the in-memory regime).

``LANE`` is the TPU lane count: nnz buffers are kept at a multiple of it
so vector loads are aligned and every Pallas tile size that divides the
reservation also divides the total.
"""
from __future__ import annotations

import math

LANE = 256


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    return 1 << max(0, math.ceil(math.log2(max(1, n))))


def pad_pow2(n: int, floor: int = LANE) -> int:
    """Power-of-two bucket for ``n``, never below ``floor``."""
    return max(floor, next_pow2(n))


def pad_multiple(n: int, multiple: int = LANE) -> int:
    """Round ``n`` up to a multiple (minimum one multiple)."""
    return max(multiple, -(-n // multiple) * multiple)
