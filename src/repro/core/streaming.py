"""Out-of-memory (OOM) MTTKRP: stream BLCO launches through device queues.

The paper (§4.2, §6.4.2) streams BLCO blocks host->device through up to 8
device queues, each with a fixed memory reservation, overlapping transfers of
pending blocks with compute on active blocks. The JAX adaptation:

* a fixed per-queue **reservation** = padded launch size, so every launch
  reuses the same compiled executable and the same device buffer shape
  (donated), exactly like the paper's reused queue reservations;
* **overlap** comes from JAX's async dispatch: we issue `jax.device_put` for
  up to ``queues`` launches ahead of the compute consuming them, so on a real
  accelerator H2D copies of pending launches run under compute of active ones
  (on this CPU container the mechanism is exercised, the overlap is measured
  on-device);
* the factor matrices and the (I_mode, R) accumulator are device-resident;
  only nnz data streams.

The building blocks (``ReservationSpec``, ``LaunchChunks``,
``stream_mttkrp``) are free-standing so higher layers can pool them:
``repro.service.executor`` streams many tenants' tensors through one shared
set of reservation shapes, reusing the same compiled executables.
``OOMExecutor`` is the single-tensor convenience wrapper.

``OOMExecutor.stats`` records bytes moved and per-phase wall time so the
Fig.-10 style benchmark can report overall vs in-memory throughput.

``repro.engine`` is the unified front door over this module: a
``StreamedPlan`` owns the reservation + chunks + an ``EngineStats`` and is
the one public way to execute a streamed MTTKRP; ``OOMExecutor`` remains as
the thin single-tensor convenience wrapper.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults import inject as faults
from repro.faults.retry import retry_call
from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace
from repro.obs.hist import EngineHists

from .blco import BLCOTensor
from .counters import record_dispatch
from .mttkrp import launch_mttkrp, choose_resolution, DEFAULT_COPIES
from .padding import next_pow2 as _next_pow2


@dataclasses.dataclass
class EngineStats:
    """Unified per-plan execution counters (every engine backend fills one).

    Timing is split so async dispatch is never mistaken for device compute:
    ``dispatch_time_s`` is the host wall time spent issuing (possibly async)
    compute calls; ``device_time_s`` is the fenced span from the first compute
    dispatch of a call until ``block_until_ready()`` returns, i.e. it includes
    the actual device execution.  ``compute_time_s`` is kept as a deprecated
    read-only alias of ``device_time_s`` for pre-engine callers.

    ``hist`` keeps the per-event *distributions* behind the scalar totals
    (per-launch dispatch latency, per-chunk H2D and disk-fetch times,
    per-launch nnz — see :class:`repro.obs.hist.EngineHists`): the scalar
    sums equal the corresponding histogram sums by construction, the
    scalars stay for snapshot compatibility.
    """
    backend: str = ""
    mttkrp_calls: int = 0
    h2d_bytes: int = 0
    disk_bytes: int = 0          # disk->host bytes fetched (disk-streamed plans)
    launches: int = 0
    put_time_s: float = 0.0
    disk_time_s: float = 0.0     # host wall time fetching chunks from the store
    dispatch_time_s: float = 0.0
    device_time_s: float = 0.0
    total_time_s: float = 0.0
    retries: int = 0             # transient failures retried successfully
    giveups: int = 0             # retry budgets exhausted (error surfaced)
    demotions: int = 0           # regime/kernel fallbacks the ladder took
    hist: EngineHists = dataclasses.field(default_factory=EngineHists)

    @property
    def compute_time_s(self) -> float:
        return self.device_time_s

    def snapshot(self) -> dict:
        return {
            "backend": self.backend,
            "mttkrp_calls": self.mttkrp_calls,
            "h2d_bytes": self.h2d_bytes,
            "disk_bytes": self.disk_bytes,
            "launches": self.launches,
            "put_time_s": self.put_time_s,
            "disk_time_s": self.disk_time_s,
            "dispatch_time_s": self.dispatch_time_s,
            "device_time_s": self.device_time_s,
            "total_time_s": self.total_time_s,
            "retries": self.retries,
            "giveups": self.giveups,
            "demotions": self.demotions,
            "hist": self.hist.snapshot(),
        }


# Deprecated name: the streaming layer's ad-hoc stats object predates the
# unified engine API; all backends now share EngineStats.
StreamStats = EngineStats


@dataclasses.dataclass(frozen=True)
class ReservationSpec:
    """A fixed device launch-buffer shape (the paper's queue reservation).

    Every launch padded to this shape reuses one compiled executable and one
    device buffer footprint — the unit the service's admission control and
    executor pooling reason about.
    """
    nnz: int                 # padded slots per launch buffer
    order: int               # tensor order (bases array width)
    value_itemsize: int      # bytes per value

    @property
    def bytes_per_launch(self) -> int:
        """Device bytes of one in-flight launch (hi + lo + vals + bases)."""
        return self.nnz * (4 + 4 + self.value_itemsize + 4 * self.order)

    def bytes_in_flight(self, queues: int) -> int:
        return self.bytes_per_launch * queues


def reservation_for(blco: BLCOTensor,
                    reservation_nnz: int | None = None) -> ReservationSpec:
    """Reservation covering the largest launch (pow2-padded unless given)."""
    max_launch = max((l.nnz for l in blco.launches), default=1)
    nnz = int(reservation_nnz or _next_pow2(max_launch))
    if nnz < max_launch:
        raise ValueError("reservation smaller than largest launch")
    return ReservationSpec(nnz=nnz, order=blco.order,
                           value_itemsize=blco.values.dtype.itemsize)


class LaunchChunks:
    """Lazily padded reservation chunks of a host-resident BLCO (re-iterable).

    Each iteration pads ONE launch at a time to the reservation size, so the
    streaming loop's host overhead is O(queues x reservation) padded buffers
    in flight instead of all launches resident at once (the pre-store code
    eagerly materialized every padded launch up front, which made the "OOM"
    path require more host memory than the tensor itself).  Zero-padding is
    exact for MTTKRP: pad slots delinearize to coordinate 0 with value 0,
    contributing +0.0 to row 0.

    ``pads`` counts chunk materializations — the regression observable that
    construction does no padding work and each ``mttkrp`` call pads exactly
    ``len(self)`` chunks.
    """

    def __init__(self, blco: BLCOTensor, reservation_nnz: int):
        r = int(reservation_nnz)
        max_launch = max((l.nnz for l in blco.launches), default=0)
        if max_launch > r:
            raise ValueError(f"launch of {max_launch} nnz exceeds "
                             f"reservation {r}")
        self.blco = blco
        self.reservation_nnz = r
        self._bases_all = blco.block_upper_bases()
        self._block_ids = blco.element_block_ids()
        self.pads = 0

    def __len__(self) -> int:
        return len(self.blco.launches)

    def chunk(self, i: int):
        """Pad launch ``i`` to the reservation (one fresh numpy tuple)."""
        b = self.blco
        r = self.reservation_nnz
        launch = b.launches[i]
        s, e = launch.start, launch.end
        n = e - s
        hi = np.zeros(r, np.uint32); hi[:n] = b.idx_hi[s:e]
        lo = np.zeros(r, np.uint32); lo[:n] = b.idx_lo[s:e]
        vals = np.zeros(r, b.values.dtype); vals[:n] = b.values[s:e]
        bases = np.zeros((r, b.order), np.int32)
        bases[:n] = self._bases_all[self._block_ids[s:e]]
        self.pads += 1
        return (hi, lo, vals, bases, n)

    def __iter__(self):
        for i in range(len(self)):
            yield self.chunk(i)


def prepare_chunks(blco: BLCOTensor, reservation_nnz: int):
    """Pad every launch to the reservation size, materialized as a list.

    The eager variant of :class:`LaunchChunks` — the in-memory regime's
    launch cache genuinely needs every padded launch at once (it stacks
    them); streaming callers should hold a ``LaunchChunks`` instead.
    """
    return list(LaunchChunks(blco, reservation_nnz))


def stream_mttkrp(chunks, blco: BLCOTensor, factors, mode: int, *,
                  queues: int, resolution: str = "auto",
                  copies: int = DEFAULT_COPIES,
                  stats: StreamStats | None = None,
                  kernel: str = "xla", interpret: bool = True):
    """Stream reservation chunks through the launch kernel.

    Keeps up to ``queues`` H2D transfers in flight ahead of compute (the
    paper's queue overlap). ``chunks`` is any (re-)iterable of
    ``(hi, lo, vals, bases, n)`` tuples that all share one reservation
    shape, so every launch hits the same compiled executable — a lazily
    padding :class:`LaunchChunks` (host-resident tensor), a disk-backed
    ``repro.store`` chunk source (mmap'd slices), or a plain list.  Chunks
    are pulled one at a time, so the host-side window never exceeds the
    ``queues`` transfers in flight.  ``kernel`` selects the per-chunk
    compute: the XLA reference dataflow or the fused single-``pallas_call``
    pipeline (``repro.kernels.fused``).
    """
    b = blco
    if resolution == "auto":
        resolution = choose_resolution(b.dims[mode])
    from .mttkrp import validate_kernel
    validate_kernel(kernel)
    if kernel == "pallas":
        from repro.kernels.fused import fused_mttkrp_flat
    factors = tuple(jnp.asarray(f) for f in factors)
    rank = factors[0].shape[1]
    # accumulate at the promoted precision (f64 values vs f32 factors must
    # not downcast); ``b`` is a BLCOTensor or a StoredBLCO — the empty
    # asarray canonicalizes the value dtype under the active x64 setting
    val_dtype = getattr(b, "value_dtype", None)
    if val_dtype is None:
        val_dtype = b.values.dtype
    out_dtype = jnp.result_type(jnp.asarray(np.zeros(0, val_dtype)),
                                factors[0])
    out = jnp.zeros((b.dims[mode], rank), out_dtype)
    stats = stats if stats is not None else StreamStats()

    t_start = time.perf_counter()
    in_flight: list[tuple] = []
    t_first_dispatch: float | None = None
    nnz_total = 0                     # true nnz launched, for the HBM model

    def _issue(chunk):
        t0 = time.perf_counter()
        hi, lo, vals, bases, n = chunk

        def _put():
            faults.maybe_fail("stream.h2d")
            return (jax.device_put(hi), jax.device_put(lo),
                    jax.device_put(vals), jax.device_put(bases))

        # transient put failures (injected or genuine transport flake)
        # retry with backoff; the reservation shapes make a re-put
        # side-effect-free
        dev = retry_call(_put, site="stream.h2d", stats=stats)
        t1 = time.perf_counter()
        nbytes = hi.nbytes + lo.nbytes + vals.nbytes + bases.nbytes
        stats.put_time_s += t1 - t0
        stats.h2d_bytes += nbytes
        stats.hist.put_chunk_s.record(t1 - t0)
        stats.hist.launch_nnz.record(n)
        if obs_trace.TRACING.enabled:
            obs_trace.add_event("h2d.put", "h2d", t0, t1, bytes=nbytes, nnz=n)
        if obs_ledger.LEDGER.enabled:
            # same nbytes / t1 - t0 that fed the stats counters above:
            # the ledger's host_device account conserves put_time_s /
            # h2d_bytes exactly, by construction
            obs_ledger.record(obs_ledger.HOST_DEVICE, nbytes, t1 - t0,
                              regime=stats.backend)
        return dev, n

    def _consume(item):
        nonlocal out, t_first_dispatch, nnz_total
        (hi, lo, vals, bases), n = item
        t0 = time.perf_counter()
        if t_first_dispatch is None:
            t_first_dispatch = t0
        if kernel == "pallas":
            # fused_mttkrp_flat records its own dispatch
            out = out + fused_mttkrp_flat(
                hi, lo, vals, bases, factors,
                field_bits=b.re.field_bits, field_shifts=b.re.field_shift,
                mode=mode, out_rows=b.dims[mode], resolution=resolution,
                interpret=interpret)
        else:
            record_dispatch()
            out = out + launch_mttkrp(
                hi, lo, vals, bases, factors,
                re_fields=b.re.field_bits, re_shifts=b.re.field_shift,
                mode=mode, out_rows=b.dims[mode],
                resolution=resolution, copies=copies)
        # host wall time of the (async) dispatch only — NOT device compute
        t1 = time.perf_counter()
        stats.dispatch_time_s += t1 - t0
        stats.hist.dispatch_s.record(t1 - t0)
        stats.launches += 1
        nnz_total += int(n)
        if obs_trace.TRACING.enabled:
            obs_trace.add_event("dispatch.launch", "dispatch", t0, t1, nnz=n)

    for chunk in chunks:
        # keep up to `queues` transfers in flight ahead of compute
        in_flight.append(_issue(chunk))
        if len(in_flight) >= queues:
            _consume(in_flight.pop(0))
    while in_flight:
        _consume(in_flight.pop(0))
    out.block_until_ready()
    t_end = time.perf_counter()
    if t_first_dispatch is not None:
        # fenced: first dispatch -> all launches retired on device
        stats.device_time_s += t_end - t_first_dispatch
        if obs_trace.TRACING.enabled:
            obs_trace.add_event("device.fence", "device",
                                t_first_dispatch, t_end,
                                launches=stats.launches)
        if obs_ledger.LEDGER.enabled:
            # fenced seconds are measured (same window as device_time_s);
            # HBM bytes are model-attributed over the true nnz launched
            obs_ledger.record(
                obs_ledger.DEVICE_HBM,
                obs_ledger.hbm_model_bytes(
                    nnz_total, order=b.order, rank=rank,
                    value_itemsize=np.dtype(val_dtype).itemsize,
                    factor_itemsize=np.dtype(factors[0].dtype).itemsize,
                    kernel=kernel),
                t_end - t_first_dispatch, regime=stats.backend,
                flops=obs_ledger.mttkrp_flops(nnz_total, order=b.order,
                                              rank=rank))
    stats.mttkrp_calls += 1
    stats.total_time_s += t_end - t_start
    return out


class OOMExecutor:
    """Streams a (host-resident) BLCO tensor through fixed device reservations."""

    def __init__(self, blco: BLCOTensor, *, queues: int = 4,
                 reservation_nnz: int | None = None, kernel: str = "xla"):
        self.blco = blco
        self.queues = queues
        self.kernel = kernel
        self.spec = reservation_for(blco, reservation_nnz)
        self._prepared = LaunchChunks(blco, self.spec.nnz)
        self.stats = EngineStats(backend="streamed")

    @property
    def reservation(self) -> int:
        return self.spec.nnz

    def mttkrp(self, factors, mode: int, *, resolution: str = "auto",
               copies: int = DEFAULT_COPIES):
        return stream_mttkrp(self._prepared, self.blco, factors, mode,
                             queues=self.queues, resolution=resolution,
                             copies=copies, stats=self.stats,
                             kernel=self.kernel)
