"""Host-side sparse tensor container (COO) + synthetic generators + FROSTT IO.

The paper evaluates 14 real-world FROSTT/HaTen2 tensors. Offline we synthesize
tensors that reproduce the *structural* properties the paper's analysis keys on
(fiber density, mode-length skew, hypersparsity); a ``.tns`` loader is provided
for the real data sets when available.
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """An N-order sparse tensor in coordinate (COO) form, host resident.

    indices: (nnz, N) int64, 0-based coordinates, deduplicated.
    values:  (nnz,) float32/float64.
    dims:    mode lengths.
    """
    dims: tuple[int, ...]
    indices: np.ndarray
    values: np.ndarray

    def __post_init__(self):
        assert self.indices.ndim == 2 and self.indices.shape[1] == len(self.dims)
        assert self.values.shape == (self.indices.shape[0],)
        assert self.indices.dtype == np.int64

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]

    @property
    def density(self) -> float:
        size = float(np.prod([float(d) for d in self.dims]))
        return self.nnz / size

    def to_dense(self) -> np.ndarray:
        """Dense materialization — test oracle only (small tensors)."""
        dense = np.zeros(self.dims, dtype=self.values.dtype)
        dense[tuple(self.indices.T)] += self.values
        return dense

    def matricize(self, mode: int) -> np.ndarray:
        """Mode-n matricization X_(n) as a dense matrix — test oracle only."""
        dense = self.to_dense()
        perm = (mode,) + tuple(m for m in range(self.order) if m != mode)
        return dense.transpose(perm).reshape(self.dims[mode], -1)


def _dedupe(indices: np.ndarray, values: np.ndarray, dims) -> SparseTensor:
    # Lexicographic dedupe, summing duplicate values (standard COO semantics).
    order = np.lexsort(indices.T[::-1])
    indices = indices[order]
    values = values[order]
    keep = np.ones(len(values), dtype=bool)
    if len(values) > 1:
        same = np.all(indices[1:] == indices[:-1], axis=1)
        keep[1:] = ~same
    # sum duplicates into the kept representative
    group = np.cumsum(keep) - 1
    out_vals = np.zeros(int(group[-1]) + 1 if len(values) else 0, dtype=values.dtype)
    np.add.at(out_vals, group, values)
    return SparseTensor(tuple(int(d) for d in dims), indices[keep], out_vals)


def random_tensor(dims, nnz, *, seed=0, dtype=np.float32, dist="uniform") -> SparseTensor:
    """Synthetic sparse tensor.

    dist="uniform":   coordinates i.i.d. uniform (models hypersparse FROSTT sets
                      like Flickr/Delicious — density 1e-14).
    dist="powerlaw":  per-mode Zipf-distributed coordinates → dense fibers for a
                      few indices (models NELL-2 / Reddit fiber-density skew,
                      which drives the paper's conflict-resolution behavior).
    dist="clustered": coordinates drawn inside a few random sub-boxes (models
                      the block structure HiCOO exploits; stresses ALTO ordering).
    """
    rng = np.random.default_rng(seed)
    dims = tuple(int(d) for d in dims)
    n = len(dims)
    nnz = int(nnz)
    idx = np.empty((nnz, n), dtype=np.int64)
    if dist == "uniform":
        for m, d in enumerate(dims):
            idx[:, m] = rng.integers(0, d, size=nnz)
    elif dist == "powerlaw":
        for m, d in enumerate(dims):
            # Zipf over the mode, clipped to the mode length.
            z = rng.zipf(1.3, size=nnz) - 1
            idx[:, m] = np.minimum(z, d - 1)
            rng.shuffle(idx[:, m])  # decorrelate rank across modes
    elif dist == "clustered":
        k = max(1, min(8, min(dims) // 2))
        centers = np.stack([rng.integers(0, d, size=k) for d in dims], axis=1)
        box = [max(1, d // 8) for d in dims]
        pick = rng.integers(0, k, size=nnz)
        for m, d in enumerate(dims):
            off = rng.integers(0, box[m], size=nnz)
            idx[:, m] = np.minimum(centers[pick, m] + off, d - 1)
    else:
        raise ValueError(f"unknown dist {dist!r}")
    vals = rng.standard_normal(nnz).astype(dtype)
    # avoid exact zeros (degenerate nnz)
    vals = np.where(vals == 0, np.asarray(1.0, dtype), vals).astype(dtype)
    return _dedupe(idx, vals, dims)


def from_coo(indices, values, dims) -> SparseTensor:
    return _dedupe(np.asarray(indices, np.int64), np.asarray(values), dims)


def load_tns(path: str, dtype=np.float64) -> SparseTensor:
    """FROSTT ``.tns`` loader: one nnz per line, 1-based indices then value."""
    raw = np.loadtxt(path, dtype=np.float64, ndmin=2)
    idx = raw[:, :-1].astype(np.int64) - 1
    vals = raw[:, -1].astype(dtype)
    dims = tuple(int(d) for d in idx.max(axis=0) + 1)
    return _dedupe(idx, vals, dims)


# Shapes/nnz modeled on Table 2 of the paper, scaled for CPU-offline runs.
PAPER_LIKE_SUITE = {
    # name: (dims, nnz, dist) — scaled ~1000x down, preserving mode-length skew.
    "nips-like":   ((256, 256, 1024, 16), 30_000, "uniform"),
    "uber-like":   ((183, 24, 1140, 1717), 33_000, "powerlaw"),
    "chicago-like": ((620, 24, 77, 32), 53_000, "powerlaw"),
    "vast-like":   ((16384, 1024, 2), 26_000, "uniform"),
    "darpa-like":  ((2048, 2048, 65536), 28_000, "powerlaw"),
    "nell2-like":  ((1210, 920, 2880), 76_000, "powerlaw"),
    "fb-like":     ((262144, 262144, 166), 10_000, "uniform"),
    "deli-like":   ((8192, 65536, 32768, 1400), 14_000, "uniform"),
    "amazon-like": ((65536, 16384, 16384), 170_000, "powerlaw"),
}


def paper_like(name: str, *, seed=0, dtype=np.float32) -> SparseTensor:
    dims, nnz, dist = PAPER_LIKE_SUITE[name]
    return random_tensor(dims, nnz, seed=seed, dtype=dtype, dist=dist)
