"""64-bit unsigned integer arithmetic as pairs of 32-bit words.

TPU's VPU is a 32-bit vector machine: 64-bit integer vector ops are emulated and
Pallas-TPU does not lower them well. The paper stores BLCO linear indices as native
64-bit integers on GPUs; the TPU-native adaptation (DESIGN.md §2) keeps every
linear index as an (hi, lo) pair of uint32 arrays and performs the shift+mask
de-linearization with 32-bit ops only.

All functions are pure jnp (usable inside Pallas kernel bodies and under jit),
operating element-wise on equal-shaped (hi, lo) uint32 arrays. Host-side
construction uses numpy uint64 / Python ints and `split64`/`join64`.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

U32_MASK = np.uint64(0xFFFFFFFF)


# ---------------------------------------------------------------- host helpers
def split64(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """uint64 array -> (hi, lo) uint32 arrays."""
    x = np.asarray(x, dtype=np.uint64)
    lo = (x & U32_MASK).astype(np.uint32)
    hi = (x >> np.uint64(32)).astype(np.uint32)
    return hi, lo


def join64(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """(hi, lo) uint32 arrays -> uint64 array (host side only)."""
    return (np.asarray(hi, np.uint64) << np.uint64(32)) | np.asarray(lo, np.uint64)


# --------------------------------------------------------------- device helpers
def _u32(x):
    return jnp.asarray(x, jnp.uint32)


def extract_field(hi, lo, shift: int, width: int):
    """Extract bits [shift, shift+width) of the 64-bit value (hi<<32)|lo.

    shift/width are Python ints (static under jit). Returns uint32 (width <= 32
    is required — BLCO mode fields never exceed 32 bits because no single mode
    length exceeds 2^32 in any supported tensor).
    """
    assert 0 <= width <= 32, "mode field wider than 32 bits is unsupported"
    if width == 0:
        return jnp.zeros_like(_u32(lo))
    mask = jnp.uint32((1 << width) - 1) if width < 32 else jnp.uint32(0xFFFFFFFF)
    if shift >= 32:
        # field entirely in hi
        return (_u32(hi) >> jnp.uint32(shift - 32)) & mask
    if shift + width <= 32:
        # field entirely in lo
        return (_u32(lo) >> jnp.uint32(shift)) & mask
    # field straddles the 32-bit boundary: stitch
    lo_bits = 32 - shift
    lo_part = _u32(lo) >> jnp.uint32(shift)                     # lo_bits wide
    hi_part = _u32(hi) & jnp.uint32((1 << (shift + width - 32)) - 1)
    return (lo_part | (hi_part << jnp.uint32(lo_bits))) & mask


def neq64(hi_a, lo_a, hi_b, lo_b):
    """Element-wise (a != b) for 64-bit pairs."""
    return jnp.logical_or(_u32(hi_a) != _u32(hi_b), _u32(lo_a) != _u32(lo_b))


def shift_right(hi, lo, n: int):
    """Logical right shift of the 64-bit pair by a static n in [0, 64]."""
    assert 0 <= n <= 64
    hi = _u32(hi)
    lo = _u32(lo)
    if n == 0:
        return hi, lo
    if n >= 64:
        z = jnp.zeros_like(hi)
        return z, z
    if n >= 32:
        return jnp.zeros_like(hi), hi >> jnp.uint32(n - 32) if n > 32 else hi
    new_lo = (lo >> jnp.uint32(n)) | (hi << jnp.uint32(32 - n))
    new_hi = hi >> jnp.uint32(n)
    return new_hi, new_lo
