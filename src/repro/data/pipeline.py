"""Deterministic, shardable synthetic data pipeline.

Real deployments swap ``SyntheticLM`` for a file-backed source; everything
downstream (host sharding, resume-from-step, prefetch) is source-agnostic.

Properties needed at scale and provided here:
  * per-host sharding: host h of H draws only its 1/H slice of the global
    batch (``host_slice``) — no cross-host data traffic;
  * exact resume: batch at step s is a pure function of (seed, s), so a
    restarted trainer replays the stream from the checkpointed step with no
    state file;
  * prefetch: a depth-k iterator that keeps device_put ahead of compute
    (same discipline as core/streaming.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    input_mode: str = "tokens"     # tokens | embeddings
    frontend_dim: int = 0
    encdec: bool = False


class SyntheticLM:
    """Zipf-distributed token stream (hot tokens stress the embedding-grad
    MTTKRP exactly like dense fibers stress the paper's kernels)."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id))          # pure function of step
        b, s = self.local_batch, cfg.seq_len
        out = {}
        toks = rng.zipf(1.2, size=(b, s + 1)) % cfg.vocab_size
        toks = toks.astype(np.int32)
        if cfg.input_mode == "embeddings":
            fd = cfg.frontend_dim
            out["embeds"] = rng.standard_normal((b, s, fd)).astype(np.float32)
            out["labels"] = toks[:, 1:]
            if cfg.encdec:
                out["tokens"] = toks[:, :-1]
        else:
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it, depth: int, put_fn=None):
    """Keep up to ``depth`` batches in flight (device_put'ed if put_fn)."""
    import collections
    q: collections.deque = collections.deque()
    it = iter(it)
    try:
        for _ in range(depth):
            b = next(it)
            q.append(put_fn(b) if put_fn else b)
        while True:
            out = q.popleft()
            b = next(it)
            q.append(put_fn(b) if put_fn else b)
            yield out
    except StopIteration:
        while q:
            yield q.popleft()
