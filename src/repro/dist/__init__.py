"""Distributed substrate: mesh context, parameter sharding rules, compat.

Split out of the model/launch layers so every consumer (models, launch,
trainer, benchmarks, tests) shares one source of truth:

* ``context``  — process-wide mesh registry + logical-axis activation
  constraints ("dp" = the data/ZeRO axes, "tp" = the model axis);
* ``sharding`` — shape-only parameter partition specs (ZeRO/TP planning
  that works on ``jax.eval_shape`` trees, no devices needed);
* ``compat``   — thin wrappers over jax APIs that moved between releases
  (``shard_map``, mesh construction).
"""
from . import compat, context, sharding

__all__ = ["compat", "context", "sharding"]
