"""Version-tolerant wrappers over jax APIs that moved between releases."""
from __future__ import annotations

import jax


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (<=0.4.x).

    Usable both directly and as a keyword-only partial/decorator, mirroring
    the modern ``jax.shard_map`` call patterns. Replication checking is
    disabled by default (``check_vma=False`` / legacy ``check_rep=False``):
    the call sites psum/pmean into replicated outputs themselves.
    """
    if hasattr(jax, "shard_map"):
        deco = lambda fn: jax.shard_map(  # noqa: E731
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    else:
        from jax.experimental.shard_map import shard_map as _sm
        deco = lambda fn: _sm(            # noqa: E731
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma)
    return deco if f is None else deco(f)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the release supports them."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)
