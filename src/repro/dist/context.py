"""Process-wide mesh context + logical-axis activation constraints.

Model code never names mesh axes directly; it anchors activations with
logical names — ``"dp"`` (all data-parallel/ZeRO axes: ``data``, or
``(pod, data)`` on multi-pod meshes) and ``"tp"`` (the ``model`` axis).
With no mesh set (single-device tests, CPU smoke runs) every constraint is
an exact no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh(mesh) -> None:
    """Install (or clear, with ``None``) the process-wide mesh."""
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def _resolve(mesh, logical):
    """Map a logical axis name to concrete mesh axes (or None to drop it)."""
    if logical is None:
        return None
    if logical == "dp":
        from .sharding import fsdp_axes
        axes = tuple(a for a in fsdp_axes(mesh) if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    if logical == "tp":
        return "model" if "model" in mesh.axis_names else None
    # already a concrete mesh axis name
    return logical if logical in mesh.axis_names else None


def constrain(x, *axes):
    """``with_sharding_constraint`` by logical per-dim axis names.

    ``axes`` has one entry per dim of ``x``: "dp", "tp", a concrete mesh
    axis name, or None. Dims whose extent the axis size does not divide are
    left unconstrained (GSPMD would pad; the call sites treat these anchors
    as hints, not requirements).
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = []
    for dim, logical in zip(x.shape, axes):
        ax = _resolve(mesh, logical)
        if ax is not None:
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            if size == 0 or dim % size != 0:
                ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
