"""Parameter sharding rules: shape-only ZeRO/TP partition-spec planning.

Works on ``jax.eval_shape`` trees (no devices, no allocation): every rule
keys on the parameter *path* and *shape* alone, so the dry-run can plan
256/512-chip layouts from a laptop.

Rules:

* layer-stacked parameters (top-level groups named ``*_layers``, plus the
  per-site LoRA stack) are never sharded on their leading stack dims —
  those dims are scanned over, not matmul dims;
* one dim per parameter is sharded over the data/ZeRO axes (``data``, or
  ``(pod, data)``): the largest dim the axis-size product divides;
* tiny parameters stay replicated (sharding a 4 KiB scale vector buys
  nothing and costs a collective on every use).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Production axis extents (launch/mesh.py: 16x16 single pod, 2x16x16
# multi-pod) — used for shape-only planning when no live mesh is given.
PLAN_AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}

# Parameters smaller than this many elements stay replicated.
MIN_SHARD_ELEMS = 1 << 14


def fsdp_axes(mesh) -> tuple[str, ...]:
    """All data-parallel/ZeRO axes of a mesh: every axis except ``model``."""
    return tuple(a for a in mesh.axis_names if a != "model")


def tree_paths(tree) -> dict:
    """Flatten a param tree to {"a/b/c": leaf} (dict/list keys joined by /)."""
    out = {}
    for kp, leaf in jax.tree_util.tree_leaves_with_path(tree):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out["/".join(parts)] = leaf
    return out


def _n_stack_dims(path: str) -> int:
    """Leading dims that index stacked (scanned) layers, never sharded."""
    top = path.split("/", 1)[0]
    if top == "group_layers":
        return 2                      # (group, layer-in-group, ...)
    if top.endswith("_layers") or top == "site_lora":
        return 1
    return 0


def param_spec(path: str, shape, fsdp, *, axis_sizes=None) -> P:
    """PartitionSpec for one parameter: shard one dim over the given axes.

    ``fsdp``: a mesh axis name or tuple of names (the ZeRO axes; also used
    with ``("model",)`` for TP-style serving layouts). ``axis_sizes`` maps
    axis name -> extent; defaults to the production mesh extents so the
    spec is computable from shapes alone.
    """
    axes = (fsdp,) if isinstance(fsdp, str) else tuple(fsdp)
    sizes = axis_sizes or PLAN_AXIS_SIZES
    size = int(np.prod([sizes[a] for a in axes])) if axes else 0
    spec = [None] * len(shape)
    if size <= 1 or int(np.prod(shape)) < MIN_SHARD_ELEMS:
        return P(*spec)
    best = None
    for d in range(_n_stack_dims(path), len(shape)):
        if shape[d] % size == 0 and (best is None or shape[d] > shape[best]):
            best = d
    if best is not None:
        spec[best] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def param_shardings(mesh, tree, *, mode: str = "train"):
    """NamedSharding tree for a param (or optimizer-moment) tree.

    mode="train": ZeRO — shard over the data axes (gradients/optimizer
    states follow the same layout). mode="serve": prefer TP — weights stay
    sharded over ``model`` where divisible (no per-step ZeRO all-gather),
    falling back to the data axes otherwise.
    """
    sizes = dict(mesh.shape)
    f = fsdp_axes(mesh)
    preference = [("model",), f] if mode == "serve" else [f]

    def one(path, leaf):
        for axes in preference:
            spec = param_spec(path, leaf.shape, axes, axis_sizes=sizes)
            if any(ax is not None for ax in tuple(spec)):
                return NamedSharding(mesh, spec)
        return NamedSharding(mesh, P())

    paths = tree_paths(tree)
    flat = {p: one(p, leaf) for p, leaf in paths.items()}

    def rebuild(kp, leaf):
        parts = [str(k.key) if hasattr(k, "key") else str(getattr(k, "idx", k))
                 for k in kp]
        return flat["/".join(parts)]

    return jax.tree_util.tree_map_with_path(rebuild, tree)
