"""Unified MTTKRP engine: one ExecutionPlan API across every regime.

    from repro.engine import plan_for
    plan = plan_for(build_blco(t), device_budget_bytes=1 << 30, rank=16)
    out = plan.mttkrp(factors, mode)        # the one way to run an MTTKRP
    plan.device_bytes(); plan.stats(); plan.close()

Backends: InMemoryPlan (device-resident), StreamedPlan (out-of-memory,
fixed reservations), DiskStreamedPlan (disk-resident store, mmap'd chunks
— ``repro.store``), ShardedPlan (mesh scale-out), BaselinePlan
(COO/F-COO/CSF parity).  ``plan_for`` implements the paper's regime
decision (give it ``host_budget_bytes`` to extend it to the disk tier);
the ``MTTKRPEngine``/``ExecutionPlan`` protocols let higher layers (the
multi-tenant service) substitute pooled variants.

In-memory, streamed, and disk-streamed plans take ``kernel="xla"``
(reference dataflow) or ``kernel="pallas"`` (fused single-``pallas_call``
pipeline).
"""
from repro.core.mttkrp import KERNELS
from repro.core.streaming import EngineStats

from .api import ExecutionPlan, MTTKRPEngine, factor_bytes, in_memory_bytes
from .plans import (BASELINE_KINDS, BaselinePlan, InMemoryPlan, ShardedPlan,
                    StreamedPlan, sharded_bytes)
from .select import AUTO_BACKENDS, DefaultEngine, plan_for
from repro.store import DiskStreamedPlan

__all__ = [
    "EngineStats", "ExecutionPlan", "MTTKRPEngine",
    "factor_bytes", "in_memory_bytes", "sharded_bytes",
    "InMemoryPlan", "StreamedPlan", "DiskStreamedPlan", "ShardedPlan",
    "BaselinePlan", "BASELINE_KINDS", "AUTO_BACKENDS", "KERNELS",
    "DefaultEngine", "plan_for",
]
