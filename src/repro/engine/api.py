"""The unified MTTKRP engine API: one ``ExecutionPlan`` for every regime.

The paper's headline property is that ONE implementation on ONE tensor copy
serves every mode in both the in-memory and out-of-memory regimes.  This
module is that property restated as an API: every way this repo can execute
an MTTKRP — device-resident, streamed through fixed reservations, sharded
over a mesh, or a baseline format for benchmark parity — is an
``ExecutionPlan`` with the same four methods.  Consumers (CP-ALS, the
multi-tenant service, benchmarks, examples) never pick a kernel path
directly; they hold a plan.

    plan.mttkrp(factors, mode)   -> (I_mode, R) result
    plan.device_bytes()          -> exact bytes the plan holds resident
                                    (hi + lo + vals + bases, padded)
    plan.stats()                 -> unified EngineStats
    plan.close()                 -> release device buffers; returns bytes freed

An ``MTTKRPEngine`` turns a BLCO tensor + a device budget into a plan; the
default engine (``repro.engine.plan_for``) implements the paper's regime
decision, and the service's ``ServiceEngine`` adds reservation/residency
pooling across tenants.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.blco import BLCOTensor
from repro.core.streaming import EngineStats


@runtime_checkable
class ExecutionPlan(Protocol):
    """A concrete, introspectable way to execute MTTKRPs for one tensor."""

    backend: str          # "in_memory" | "streamed" | "sharded" | "coo" | ...

    def mttkrp(self, factors, mode: int):
        """Mode-``mode`` MTTKRP of the planned tensor with ``factors``."""
        ...

    def device_bytes(self) -> int:
        """Exact device bytes this plan holds resident (incl. bases arrays)."""
        ...

    def stats(self) -> EngineStats:
        """Execution counters accumulated by this plan."""
        ...

    def close(self) -> int:
        """Release device buffers; returns the bytes freed."""
        ...


@runtime_checkable
class MTTKRPEngine(Protocol):
    """Turns a tensor + budget into an ExecutionPlan (the regime decision)."""

    def plan(self, blco: BLCOTensor, *, device_budget_bytes: int, rank: int,
             dtype) -> ExecutionPlan:
        ...


def factor_bytes(dims, rank: int, dtype) -> int:
    """Device working-set bytes of a rank-R MTTKRP around the tensor itself:
    the N factor matrices plus the largest-mode output accumulator."""
    item = np.dtype(dtype).itemsize
    return (sum(int(d) for d in dims) + max(int(d) for d in dims)) \
        * rank * item


def in_memory_bytes(blco: BLCOTensor) -> int:
    """Predicted device footprint of an ``InMemoryPlan`` for ``blco``:
    the stacked launch cache's hi + lo + vals + bases — L launches padded
    to the lane-multiple reservation, exactly what ``DeviceBLCO`` holds."""
    from repro.core.launches import launch_cache_bytes
    return launch_cache_bytes(blco)
