"""The four ExecutionPlan backends behind the unified engine API.

  InMemoryPlan   device-resident BLCO (absorbs ``core.mttkrp.DeviceBLCO``):
                 the paper's in-memory regime — one upload, then every
                 MTTKRP is a single jitted dispatch.
  StreamedPlan   host-resident BLCO streamed through fixed reservations
                 (absorbs ``OOMExecutor``/``stream_mttkrp``): the paper's
                 out-of-memory regime.
  ShardedPlan    nnz-sharded MTTKRP over a device mesh (routes through
                 ``core.distributed``): the beyond-paper scale-out regime.
  BaselinePlan   COO / F-COO / CSF device formats from ``core.baselines``,
                 for benchmark parity under the same API.

Every plan owns one ``EngineStats`` and reports its exact resident device
bytes — including the per-element bases arrays — so admission control can
reason about *measured* footprints instead of padded worst cases.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.blco import BLCOTensor, decode_coords
from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace
from repro.core.mttkrp import DEFAULT_COPIES, DeviceBLCO, validate_kernel
from repro.core.streaming import (EngineStats, LaunchChunks, ReservationSpec,
                                  reservation_for, stream_mttkrp)
from repro.core.tensor import SparseTensor, from_coo

from .api import in_memory_bytes


class InMemoryPlan:
    """Device-resident plan: the whole BLCO tensor lives in device memory.

    The launch cache is built once at plan creation; every ``mttkrp`` call
    afterwards is exactly ONE jitted dispatch (``kernel="xla"``: a
    ``lax.scan`` over the stacked launches; ``kernel="pallas"``: the fused
    single-``pallas_call`` pipeline) with zero host-side work.  Calls are
    fenced (``block_until_ready``) so ``EngineStats`` records the same
    dispatch-vs-device timing split the streamed plan does.
    """

    backend = "in_memory"

    def __init__(self, blco: BLCOTensor, *, resolution: str = "auto",
                 copies: int = DEFAULT_COPIES, device: DeviceBLCO | None = None,
                 owns_device: bool = True, kernel: str = "xla",
                 interpret: bool = True):
        validate_kernel(kernel)
        self.dims = blco.dims
        self.resolution = resolution
        self.copies = copies
        self.kernel = kernel
        self._owns_device = owns_device if device is not None else True
        self._dev: DeviceBLCO | None = device if device is not None \
            else DeviceBLCO(blco, kernel=kernel, interpret=interpret)
        self._stats = EngineStats(backend=self.backend)
        # kept for the analytic device-traffic model the ledger attributes
        self._nnz = blco.nnz
        self._order = blco.order
        self._value_itemsize = np.dtype(blco.values.dtype).itemsize
        if device is None:
            # the one H2D transfer of this regime: the initial upload
            self._stats.h2d_bytes += self._dev.device_bytes()
            if obs_ledger.LEDGER.enabled:
                # seconds=0.0 mirrors the stats exactly: the upload adds
                # bytes but no put_time_s in this regime
                obs_ledger.record(obs_ledger.HOST_DEVICE,
                                  self._dev.device_bytes(), 0.0,
                                  regime=self.backend)

    def mttkrp(self, factors, mode: int, *, resolution: str | None = None,
               copies: int | None = None):
        if self._dev is None:
            raise RuntimeError("plan is closed")
        with obs_trace.span("plan.mttkrp", "plan", backend=self.backend,
                            mode=mode):
            t0 = time.perf_counter()
            out = self._dev.mttkrp(
                factors, mode, kernel=self.kernel,
                resolution=resolution if resolution is not None
                else self.resolution,
                copies=copies if copies is not None else self.copies)
            # host wall time of the (async) dispatch vs the fenced device span
            t1 = time.perf_counter()
            self._stats.dispatch_time_s += t1 - t0
            self._stats.hist.dispatch_s.record(t1 - t0)
            if hasattr(out, "block_until_ready"):
                out.block_until_ready()
            t2 = time.perf_counter()
            self._stats.device_time_s += t2 - t0
            self._stats.total_time_s += t2 - t0
            self._stats.mttkrp_calls += 1
            self._stats.launches += 1        # one fused dispatch per call
            if obs_trace.TRACING.enabled:
                obs_trace.add_event("device.fence", "device", t0, t2,
                                    backend=self.backend)
            if obs_ledger.LEDGER.enabled:
                # fenced seconds (same t2 - t0 window as device_time_s);
                # HBM bytes attributed from the per-kernel model
                rank = factors[0].shape[1]
                obs_ledger.record(
                    obs_ledger.DEVICE_HBM,
                    obs_ledger.hbm_model_bytes(
                        self._nnz, order=self._order, rank=rank,
                        value_itemsize=self._value_itemsize,
                        factor_itemsize=np.dtype(factors[0].dtype).itemsize,
                        kernel=self.kernel),
                    t2 - t0, regime=self.backend,
                    flops=obs_ledger.mttkrp_flops(self._nnz,
                                                  order=self._order,
                                                  rank=rank))
        return out

    def device_bytes(self) -> int:
        return self._dev.device_bytes() if self._dev is not None else 0

    def stats(self) -> EngineStats:
        return self._stats

    def close(self) -> int:
        if self._dev is None:
            return 0
        freed = self._dev.device_bytes()
        if self._owns_device:
            self._dev.delete()
        self._dev = None
        return freed


class StreamedPlan:
    """Out-of-memory plan: host-resident tensor, fixed device reservations."""

    backend = "streamed"

    def __init__(self, blco: BLCOTensor, *, queues: int = 4,
                 reservation_nnz: int | None = None,
                 spec: ReservationSpec | None = None,
                 chunks: list | None = None,
                 resolution: str = "auto", copies: int = DEFAULT_COPIES,
                 kernel: str = "xla", interpret: bool = True):
        validate_kernel(kernel)
        self.blco = blco
        self.dims = blco.dims
        self.queues = queues
        self.resolution = resolution
        self.copies = copies
        self.kernel = kernel
        self.interpret = interpret
        self.spec = spec if spec is not None \
            else reservation_for(blco, reservation_nnz)
        # chunks are padded LAZILY, one launch per pull inside the streaming
        # loop: the host window is O(queues x reservation), never all
        # launches resident (the paper's out-of-memory premise)
        self._chunks = chunks if chunks is not None \
            else LaunchChunks(blco, self.spec.nnz)
        self._stats = EngineStats(backend=self.backend)
        self._closed = False

    def mttkrp(self, factors, mode: int, *, resolution: str | None = None,
               copies: int | None = None):
        if self._closed:
            raise RuntimeError("plan is closed")
        with obs_trace.span("plan.mttkrp", "plan", backend=self.backend,
                            mode=mode):
            return stream_mttkrp(
                self._chunks, self.blco, factors, mode, queues=self.queues,
                resolution=resolution if resolution is not None
                else self.resolution,
                copies=copies if copies is not None else self.copies,
                stats=self._stats, kernel=self.kernel,
                interpret=self.interpret)

    def device_bytes(self) -> int:
        """Reservation bytes in flight (the only device-resident state)."""
        return 0 if self._closed else self.spec.bytes_in_flight(self.queues)

    def host_window_bytes(self) -> int:
        """Padded host bytes the streaming loop holds at once (bounded by
        the queue depth — NOT the whole tensor's padded launches)."""
        return 0 if self._closed else \
            self.spec.bytes_per_launch * self.queues

    def stats(self) -> EngineStats:
        return self._stats

    def close(self) -> int:
        if self._closed:
            return 0
        freed = self.spec.bytes_in_flight(self.queues)
        self._chunks = None
        self._closed = True
        return freed


def sharded_bytes(blco: BLCOTensor, mesh, *, data_axis="data") -> int:
    """Predicted mesh-wide device bytes of a ShardedPlan for ``blco``.

    The nnz arrays are range-partitioned over the data axis but REPLICATED
    across the remaining mesh axes (``nnz_spec = P(data_axis)``), so the
    total resident footprint is the padded arrays times that replication
    factor.
    """
    data_size = 1
    for ax in (data_axis if isinstance(data_axis, tuple) else (data_axis,)):
        data_size *= mesh.shape[ax]
    per = -(-blco.nnz // data_size) if blco.nnz else 0
    padded = per * data_size
    replicas = mesh.size // data_size
    return padded * (4 + 4 + blco.values.dtype.itemsize
                     + 4 * blco.order) * replicas


class ShardedPlan:
    """Mesh-sharded plan: nnz range-partitioned over the data axis."""

    backend = "sharded"

    def __init__(self, blco: BLCOTensor, mesh, *, data_axis="data",
                 model_axis="model"):
        from repro.core.distributed import make_distributed_mttkrp
        self.dims = blco.dims
        self.mesh = mesh
        self._nnz = blco.nnz
        self._value_dtype = blco.values.dtype
        self._device_bytes = sharded_bytes(blco, mesh, data_axis=data_axis)
        self._run = make_distributed_mttkrp(
            blco, mesh, data_axis=data_axis, model_axis=model_axis) \
            if blco.nnz else None
        self._stats = EngineStats(backend=self.backend)
        self._stats.h2d_bytes += self._device_bytes
        if obs_ledger.LEDGER.enabled:
            obs_ledger.record(obs_ledger.HOST_DEVICE, self._device_bytes,
                              0.0, regime=self.backend)
        self._closed = False

    def mttkrp(self, factors, mode: int):
        if self._closed:
            raise RuntimeError("plan is closed")
        self._stats.mttkrp_calls += 1
        self._stats.launches += 1
        if self._run is None:
            rank = factors[0].shape[1]
            # empty-tensor case at the promoted precision, matching the
            # sharded compute path (result_type of values vs factors)
            out_dtype = jnp.result_type(
                jnp.asarray(np.zeros(0, self._value_dtype)), factors[0])
            return jnp.zeros((self.dims[mode], rank), out_dtype)
        return self._run(factors, mode)

    def device_bytes(self) -> int:
        return 0 if self._closed else self._device_bytes

    def stats(self) -> EngineStats:
        return self._stats

    def close(self) -> int:
        if self._closed:
            return 0
        freed = self._device_bytes
        self._run = None          # drops the closure holding device shards
        self._closed = True
        return freed


_BASELINE_BUILDERS = {
    "coo": (baselines.COOFormat, baselines.DeviceCOO),
    "fcoo": (baselines.FCOOFormat, baselines.DeviceFCOO),
    "csf": (baselines.CSFFormat, baselines.DeviceCSF),
}

BASELINE_KINDS = tuple(_BASELINE_BUILDERS)


class BaselinePlan:
    """Baseline-format plan (COO / F-COO / CSF) for benchmark parity."""

    def __init__(self, device_fmt, kind: str):
        if kind not in _BASELINE_BUILDERS:
            raise ValueError(f"unknown baseline kind {kind!r}; "
                             f"expected one of {BASELINE_KINDS}")
        self.backend = kind
        self.dims = device_fmt.dims
        self._dev = device_fmt
        self._stats = EngineStats(backend=kind)
        self._stats.h2d_bytes += device_fmt.device_bytes()
        if obs_ledger.LEDGER.enabled:
            obs_ledger.record(obs_ledger.HOST_DEVICE,
                              device_fmt.device_bytes(), 0.0,
                              regime=self.backend)

    @classmethod
    def from_tensor(cls, t: SparseTensor, kind: str = "coo") -> "BaselinePlan":
        host_cls, dev_cls = _BASELINE_BUILDERS[kind]
        return cls(dev_cls(host_cls.build(t)), kind)

    @classmethod
    def from_blco(cls, blco: BLCOTensor, kind: str = "coo") -> "BaselinePlan":
        """Decode the BLCO encoding back to COO and build the baseline —
        the single stored copy really does carry the full coordinates."""
        t = from_coo(decode_coords(blco), np.asarray(blco.values), blco.dims)
        return cls.from_tensor(t, kind)

    def mttkrp(self, factors, mode: int):
        if self._dev is None:
            raise RuntimeError("plan is closed")
        self._stats.mttkrp_calls += 1
        return self._dev.mttkrp(factors, mode)

    def device_bytes(self) -> int:
        return self._dev.device_bytes() if self._dev is not None else 0

    def stats(self) -> EngineStats:
        return self._stats

    def close(self) -> int:
        if self._dev is None:
            return 0
        freed = self._dev.device_bytes()
        self._dev = None
        return freed


__all__ = ["InMemoryPlan", "StreamedPlan", "ShardedPlan", "BaselinePlan",
           "BASELINE_KINDS", "in_memory_bytes", "sharded_bytes"]
