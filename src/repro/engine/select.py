"""``plan_for``: the paper's regime decision as a one-call auto-selector.

    sharded        a mesh context is active (repro.dist.context) or passed
                   in — multi-device capacity, route through
                   core.distributed;
    in_memory      the tensor's true device footprint (hi + lo + vals +
                   bases, padded) plus the rank-R factor working set fits
                   the budget — the paper's in-memory regime, zero
                   per-iteration H2D;
    disk_streamed  the tensor exceeds the HOST budget
                   (``host_budget_bytes``) — spill it to the persistent
                   store and stream mmap'd reservation chunks straight to
                   the device (one tier below the paper's OOM regime);
    streamed       otherwise — fixed reservations stream the host-resident
                   tensor (the paper's out-of-memory regime), provided the
                   in-flight reservation + factor working set fits;
    baselines      never auto-selected; request ``backend="coo"|"fcoo"|
                   "csf"`` explicitly for benchmark parity.

``DefaultEngine`` wraps the same decision behind the ``MTTKRPEngine``
protocol for callers that hold an engine rather than call ``plan_for``.
"""
from __future__ import annotations

import os
import tempfile

import jax.numpy as jnp

from repro.core.blco import BLCOTensor, format_bytes
from repro.core.mttkrp import DEFAULT_COPIES, validate_kernel
from repro.core.streaming import reservation_for
from repro.dist.context import get_mesh
from repro.faults import inject as faults
from repro.obs import trace as obs_trace

from .api import factor_bytes, in_memory_bytes
from .plans import (BASELINE_KINDS, BaselinePlan, InMemoryPlan, ShardedPlan,
                    StreamedPlan, sharded_bytes)

AUTO_BACKENDS = ("auto", "in_memory", "streamed", "disk_streamed",
                 "sharded") + BASELINE_KINDS


def plan_for(blco: BLCOTensor, device_budget_bytes: int, *, rank: int,
             dtype=jnp.float32, backend: str = "auto", mesh=None,
             queues: int = 4, reservation_nnz: int | None = None,
             tensor=None, resolution: str = "auto",
             copies: int = DEFAULT_COPIES, kernel: str = "xla",
             interpret: bool = True, host_budget_bytes: int | None = None,
             store_path: str | None = None, sanitize: bool | None = None):
    """Build the ExecutionPlan for ``blco`` under ``device_budget_bytes``.

    ``tensor`` (the original SparseTensor) is only consulted for baseline
    backends; without it the coordinates are decoded from the BLCO copy.
    ``kernel`` selects the compute path for the in-memory and streamed
    regimes: ``"xla"`` (reference dataflow, scan over the launch cache) or
    ``"pallas"`` (fused single-``pallas_call`` pipeline; ``interpret=False``
    on a real TPU).

    ``host_budget_bytes`` extends the regime decision one memory tier
    down: when the tensor's host footprint (``format_bytes``) exceeds it,
    the tensor is spilled to the persistent store at ``store_path`` (an
    anonymous temp file, deleted on ``plan.close()``, when not given) and
    a ``DiskStreamedPlan`` feeds the device from mmap'd chunks with an
    O(queues x reservation) host window.  Raises ValueError when no
    regime fits the budget.

    ``sanitize`` wraps the plan in the runtime sanitizer's contract
    checker (:mod:`repro.analysis.sanitize`): ``True``/``False`` force it
    on/off, ``None`` (default) follows ``REPRO_SANITIZE``.  Sanitized
    plans are bit-identical to plain ones — the wrapper only inspects
    inputs and outputs.
    """
    from repro.analysis.sanitize import wrap_plan
    with obs_trace.span("engine.plan_for", "plan", nnz=blco.nnz,
                        requested=backend) as sp:
        plan = _plan_for_impl(
            blco, device_budget_bytes, rank=rank, dtype=dtype,
            backend=backend, mesh=mesh, queues=queues,
            reservation_nnz=reservation_nnz, tensor=tensor,
            resolution=resolution, copies=copies, kernel=kernel,
            interpret=interpret, host_budget_bytes=host_budget_bytes,
            store_path=store_path)
        sp.set(backend=plan.backend)
        return wrap_plan(plan, enable=sanitize)


def _plan_for_impl(blco: BLCOTensor, device_budget_bytes: int, *, rank: int,
                   dtype, backend: str, mesh, queues: int,
                   reservation_nnz: int | None, tensor, resolution: str,
                   copies: int, kernel: str, interpret: bool,
                   host_budget_bytes: int | None, store_path: str | None):
    if backend not in AUTO_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected one of {AUTO_BACKENDS}")
    validate_kernel(kernel)
    if backend in BASELINE_KINDS:
        if kernel != "xla":
            raise ValueError(f"kernel={kernel!r} is not supported on "
                             f"baseline backends; use kernel='xla'")
        return BaselinePlan.from_tensor(tensor, backend) \
            if tensor is not None else BaselinePlan.from_blco(blco, backend)

    working = factor_bytes(blco.dims, rank, dtype)
    mesh = mesh if mesh is not None else get_mesh()
    if backend == "sharded" or (backend == "auto" and mesh is not None):
        if mesh is None:
            raise ValueError("backend='sharded' requires an active mesh "
                             "(repro.dist.context.set_mesh) or mesh=...")
        if kernel != "xla":
            raise ValueError("kernel='pallas' is not supported on the "
                             "sharded backend yet; use kernel='xla'")
        need = sharded_bytes(blco, mesh) + working
        if need > device_budget_bytes:
            raise ValueError(
                f"sharded plan needs {need} B across the mesh "
                f"(tensor shards x replicas + factors) but the device "
                f"budget is {device_budget_bytes} B")
        return ShardedPlan(blco, mesh)

    # ------------------------------------------------------- regime builders
    # The three single-device regimes as closures over one kernel argument,
    # so the degradation ladder below can retry a rung with kernel="xla"
    # (pallas fallback) or fall one memory tier down on allocation failure.
    demotions: list[str] = []

    def _done(plan):
        if demotions:
            plan.stats().demotions += len(demotions)
        return plan

    def _build_in_memory(k):
        # (the plan.alloc fault probe fires inside LaunchCache.from_blco —
        # the regime's actual device-allocation moment)
        return InMemoryPlan(blco, resolution=resolution, copies=copies,
                            kernel=k, interpret=interpret)

    def _build_streamed(k):
        faults.maybe_fail("plan.alloc")
        spec = reservation_for(blco, reservation_nnz)
        if spec.bytes_in_flight(queues) + working > device_budget_bytes:
            raise ValueError(
                f"no regime fits the budget: streaming needs "
                f"{spec.bytes_in_flight(queues) + working} B in flight "
                f"(reservation {spec.nnz} nnz x {queues} queues + factors) "
                f"but the device budget is {device_budget_bytes} B")
        return StreamedPlan(blco, queues=queues, spec=spec,
                            resolution=resolution, copies=copies,
                            kernel=k, interpret=interpret)

    def _build_disk(k):
        from repro.store import DiskStreamedPlan
        spec = reservation_for(blco, reservation_nnz)
        if spec.bytes_in_flight(queues) + working > device_budget_bytes:
            raise ValueError(
                f"disk-streamed plan needs "
                f"{spec.bytes_in_flight(queues) + working} B in flight "
                f"(reservation {spec.nnz} nnz x {queues} queues + factors) "
                f"but the device budget is {device_budget_bytes} B")
        if store_path is None:
            fd, path = tempfile.mkstemp(suffix=".blco")
            os.close(fd)
            delete = True
        else:
            path, delete = store_path, False
        try:
            return DiskStreamedPlan.spill(
                blco, path, reservation_nnz=spec.nnz, delete_on_close=delete,
                queues=queues, resolution=resolution, copies=copies,
                kernel=k, interpret=interpret)
        except BaseException:
            if delete:              # don't orphan the anonymous spill file
                try:
                    os.unlink(path)
                except OSError:
                    pass
            raise

    if backend == "disk_streamed" or (
            backend == "auto" and host_budget_bytes is not None
            and format_bytes(blco) > host_budget_bytes):
        return _done(_kernel_fallback(_build_disk, kernel, demotions))

    # ---------------------------------------------------- degradation ladder
    # auto mode falls one memory tier per allocation failure:
    # in_memory -> streamed -> disk_streamed.  Explicit backends keep the
    # kernel fallback (pallas -> xla) but never change regime — the caller
    # asked for that tier by name.
    auto = backend == "auto"
    if backend == "in_memory" or (auto and in_memory_bytes(blco) + working
                                  <= device_budget_bytes):
        if in_memory_bytes(blco) + working > device_budget_bytes:
            raise ValueError(
                f"in-memory plan needs {in_memory_bytes(blco) + working} B "
                f"resident (tensor + factors) but the device budget is "
                f"{device_budget_bytes} B")
        try:
            return _done(_kernel_fallback(_build_in_memory, kernel,
                                          demotions))
        except Exception as exc:    # noqa: BLE001 — classified right below
            if not (auto and _is_alloc_failure(exc)):
                raise
            _note_demotion(demotions, "in_memory->streamed", exc)

    try:
        return _done(_kernel_fallback(_build_streamed, kernel, demotions))
    except Exception as exc:        # noqa: BLE001 — classified right below
        if not (auto and _is_alloc_failure(exc)):
            raise
        _note_demotion(demotions, "streamed->disk_streamed", exc)
    return _done(_kernel_fallback(_build_disk, kernel, demotions))


_is_alloc_failure = faults.is_alloc_failure


def _kernel_fallback(build, kernel: str, demotions: list):
    """``build(kernel)`` with the pallas -> xla rung of the ladder."""
    try:
        return build(kernel)
    except faults.KernelFailure as exc:
        if kernel != "pallas":
            raise
        _note_demotion(demotions, "pallas->xla", exc)
        return build("xla")


def _note_demotion(demotions: list, what: str, exc: BaseException) -> None:
    demotions.append(what)
    with obs_trace.span("engine.demote", "plan", demote=what,
                        error=repr(exc)):
        pass


class DefaultEngine:
    """MTTKRPEngine over ``plan_for`` with fixed streaming configuration."""

    def __init__(self, *, queues: int = 4, mesh=None, backend: str = "auto",
                 reservation_nnz: int | None = None, kernel: str = "xla",
                 interpret: bool = True,
                 host_budget_bytes: int | None = None):
        self.queues = queues
        self.mesh = mesh
        self.backend = backend
        self.reservation_nnz = reservation_nnz
        self.kernel = kernel
        self.interpret = interpret
        self.host_budget_bytes = host_budget_bytes

    def plan(self, blco: BLCOTensor, *, device_budget_bytes: int, rank: int,
             dtype=jnp.float32):
        return plan_for(blco, device_budget_bytes, rank=rank, dtype=dtype,
                        backend=self.backend, mesh=self.mesh,
                        queues=self.queues,
                        reservation_nnz=self.reservation_nnz,
                        kernel=self.kernel, interpret=self.interpret,
                        host_budget_bytes=self.host_budget_bytes)
