"""Fault injection + typed retry: the service's robustness toolkit.

``inject`` produces deterministic, seeded faults at named pipeline sites
(``REPRO_FAULTS=<seed>:<spec>`` or a programmatic :class:`FaultPlan`);
``retry`` is the hardening that makes the transient ones survivable.
Both are zero-cost when disabled (one flag check, mirroring
``repro.obs.trace``).
"""
from .inject import (ENV_VAR, FAULTS, SITES, AllocationError, FaultPlan,
                     FaultRule, FaultSpecError, FaultState, KernelFailure,
                     WorkerCrashError, active, exception_for, fire, install,
                     is_alloc_failure, maybe_fail, reload_from_env, uninstall)
from .retry import (DEFAULT_POLICY, TRANSIENT_TYPES, Permanent, RetryPolicy,
                    Transient, is_transient, retry_call)

__all__ = [
    "ENV_VAR", "FAULTS", "SITES", "AllocationError", "FaultPlan",
    "FaultRule", "FaultSpecError", "FaultState", "KernelFailure",
    "WorkerCrashError", "active", "exception_for", "fire", "install",
    "is_alloc_failure", "maybe_fail", "reload_from_env", "uninstall",
    "DEFAULT_POLICY", "TRANSIENT_TYPES", "Permanent", "RetryPolicy",
    "Transient", "is_transient", "retry_call",
]
