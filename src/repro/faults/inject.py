"""Deterministic, seeded fault injection at named pipeline sites.

The service's robustness claims (retry on transient I/O, degradation
ladder on allocation failure, job quarantine, watchdog restart) are only
testable if faults can be *produced* on demand, reproducibly.  This
module is the switchboard: hardened code paths call
:func:`fire`/:func:`maybe_fail` with a site name, and an installed
:class:`FaultPlan` decides — deterministically, from a seed — whether
that particular call fails and how.

Mirrors ``repro.obs.trace``'s zero-cost-disabled design: the module-level
:data:`FAULTS` singleton carries one ``enabled`` flag; with no plan
installed every probe is a single attribute check and no allocation, so
production paths pay nothing (<1% on the in-memory benchmark, enforced by
the chaos test suite).

Enable via the environment::

    REPRO_FAULTS="<seed>:<rule>(;<rule>)*"
    rule  = <site>[@<qual>(,<qual>)*][:<kind>]
    qual  = p=<float>   probabilistic: each call fails with probability p
          | n=<int>     nth-call: the n-th probe at this site fails (1-based)
          | times=<int> at most this many firings for the rule

    REPRO_FAULTS="7:store.read@p=0.3:transient;plan.alloc@n=1"

or programmatically (tests)::

    plan = FaultPlan(seed=7, rules=[FaultRule("stream.h2d", nth=2)])
    with active(plan):
        ...

Sites and their fault kinds (the registry the ``fault-site-hygiene`` lint
pass checks probe calls against):

    store.read       transient (OSError, retried) | corrupt | truncate
                     (StoreCorruptionError, permanent)
    plan.alloc       alloc (AllocationError -> degradation ladder) |
                     kernel (KernelFailure -> pallas->xla fallback)
    stream.h2d       transient (OSError on the device_put, retried)
    runtime.quantum  exception (RuntimeError inside the sweep -> job
                     quarantined FAILED) | crash (WorkerCrashError, a
                     BaseException that escapes job isolation -> worker
                     death -> watchdog restart)
    factors.nan      nan (caller poisons the factor matrices; caught by
                     the always-on finite-fit guard -> quarantine)
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading

ENV_VAR = "REPRO_FAULTS"

# site -> allowed kinds; the FIRST kind is the default when a rule names
# none.  This is the single source of truth the hygiene lint pass reads.
SITES: dict[str, tuple[str, ...]] = {
    "store.read": ("transient", "corrupt", "truncate"),
    "plan.alloc": ("alloc", "kernel"),
    "stream.h2d": ("transient",),
    "runtime.quantum": ("exception", "crash"),
    "factors.nan": ("nan",),
}


class AllocationError(RuntimeError):
    """Simulated device-memory allocation failure (``plan.alloc``)."""


class KernelFailure(RuntimeError):
    """Simulated kernel compilation/launch failure (``plan.alloc:kernel``)."""


class WorkerCrashError(BaseException):
    """Simulated worker-thread death (``runtime.quantum:crash``).

    Deliberately a ``BaseException``: the scheduler's job-isolation
    ``except Exception`` must NOT catch it — it models the whole worker
    dying mid-quantum (segfault, OOM-kill), the scenario the runtime
    watchdog exists for.
    """


class FaultSpecError(ValueError):
    """A ``REPRO_FAULTS`` spec (or FaultRule) failed validation."""


def is_alloc_failure(exc: BaseException) -> bool:
    """Device-memory exhaustion, injected or genuine (XLA's OOM spellings).

    The predicate the degradation ladders (``plan_for`` and the service
    engine) demote on: only allocation failures fall a memory tier;
    anything else propagates.
    """
    if isinstance(exc, AllocationError):
        return True
    text = str(exc)
    return "RESOURCE_EXHAUSTED" in text or "out of memory" in text.lower()


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injection rule: when probes at ``site`` fail, and how.

    Exactly one of ``p`` (probabilistic) or ``nth`` (the nth probe at the
    site, 1-based) selects calls; ``times`` caps total firings (defaults:
    1 for nth rules — fail once, let the retry succeed — unlimited for
    probabilistic rules).
    """
    site: str
    kind: str | None = None
    p: float | None = None
    nth: int | None = None
    times: int | None = None

    def __post_init__(self):
        if self.site not in SITES:
            raise FaultSpecError(
                f"unknown fault site {self.site!r}; declared sites: "
                f"{sorted(SITES)}")
        kind = self.kind if self.kind is not None else SITES[self.site][0]
        if kind not in SITES[self.site]:
            raise FaultSpecError(
                f"site {self.site!r} has no fault kind {kind!r}; "
                f"expected one of {SITES[self.site]}")
        object.__setattr__(self, "kind", kind)
        if (self.p is None) == (self.nth is None):
            raise FaultSpecError(
                f"rule for {self.site!r} must set exactly one of p= "
                f"(probabilistic) or n= (nth call)")
        if self.p is not None and not 0.0 < self.p <= 1.0:
            raise FaultSpecError(f"p must be in (0, 1], got {self.p!r}")
        if self.nth is not None and self.nth < 1:
            raise FaultSpecError(f"n must be >= 1, got {self.nth!r}")
        if self.times is None:
            object.__setattr__(self, "times",
                               1 if self.nth is not None else None)


class FaultPlan:
    """A seeded set of rules; thread-safe per-site call counting.

    Determinism: nth-call rules are exact regardless of threading; with
    probabilistic rules the *sequence* of random draws is fixed by the
    seed, so a single-threaded replay is exact and a threaded one varies
    only in which call receives each (fixed) draw.
    """

    def __init__(self, seed: int, rules=()):
        self.seed = int(seed)
        self.rules = tuple(rules)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._calls: dict[str, int] = {}
        self._fired: dict[int, int] = {}      # rule index -> firing count
        self.fired_log: list[tuple[str, str, int]] = []  # (site, kind, call#)
        self._by_site: dict[str, list[tuple[int, FaultRule]]] = {}
        for idx, rule in enumerate(self.rules):
            self._by_site.setdefault(rule.site, []).append((idx, rule))

    @classmethod
    def from_spec(cls, text: str) -> "FaultPlan":
        """Parse the ``REPRO_FAULTS`` grammar (see module docstring)."""
        seed_text, sep, spec = text.partition(":")
        if not sep:
            raise FaultSpecError(
                f"fault spec {text!r} missing '<seed>:' prefix")
        try:
            seed = int(seed_text)
        except ValueError as exc:
            raise FaultSpecError(
                f"fault spec seed {seed_text!r} is not an int") from exc
        rules = [_parse_rule(part) for part in spec.split(";") if part.strip()]
        if not rules:
            raise FaultSpecError(f"fault spec {text!r} declares no rules")
        return cls(seed, rules)

    def fire(self, site: str) -> str | None:
        """Count one probe at ``site``; the fault kind to inject, or None."""
        if site not in SITES:
            raise FaultSpecError(
                f"probe at undeclared fault site {site!r}; declared "
                f"sites: {sorted(SITES)}")
        with self._lock:
            call = self._calls.get(site, 0) + 1
            self._calls[site] = call
            for idx, rule in self._by_site.get(site, ()):
                fired = self._fired.get(idx, 0)
                if rule.times is not None and fired >= rule.times:
                    continue
                hit = (call == rule.nth) if rule.nth is not None \
                    else (self._rng.random() < rule.p)
                if hit:
                    self._fired[idx] = fired + 1
                    self.fired_log.append((site, rule.kind, call))
                    return rule.kind
        return None

    def calls(self, site: str) -> int:
        """Probes seen at ``site`` so far."""
        with self._lock:
            return self._calls.get(site, 0)

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, rules={list(self.rules)!r})"


def _parse_rule(text: str) -> FaultRule:
    head, sep, kind = text.strip().partition(":")
    site, qsep, quals = head.partition("@")
    kwargs: dict = {"site": site.strip(),
                    "kind": kind.strip() if sep else None}
    if qsep:
        for qual in quals.split(","):
            key, eq, value = qual.partition("=")
            key = key.strip()
            if not eq:
                raise FaultSpecError(f"malformed qualifier {qual!r} in "
                                     f"fault rule {text!r}")
            if key not in ("p", "n", "times"):
                raise FaultSpecError(
                    f"unknown qualifier {key!r} in fault rule {text!r}; "
                    f"expected p=, n=, or times=")
            try:
                if key == "p":
                    kwargs["p"] = float(value)
                elif key == "n":
                    kwargs["nth"] = int(value)
                else:
                    kwargs["times"] = int(value)
            except ValueError as exc:
                raise FaultSpecError(
                    f"bad value {value!r} for {key}= in fault rule "
                    f"{text!r}") from exc
    return FaultRule(**kwargs)


# --------------------------------------------------------------- singleton
class FaultState:
    """Module-level switch: hot paths read ``FAULTS.enabled`` once."""

    def __init__(self):
        self.enabled = False
        self.plan: FaultPlan | None = None
        self.lock = threading.Lock()


FAULTS = FaultState()


def install(plan: FaultPlan | str | None) -> FaultPlan | None:
    """Install a plan (or spec string) as THE active fault plan."""
    if isinstance(plan, str):
        plan = FaultPlan.from_spec(plan)
    with FAULTS.lock:
        FAULTS.plan = plan
        FAULTS.enabled = plan is not None
    return plan


def uninstall() -> None:
    install(None)


def reload_from_env() -> FaultPlan | None:
    """(Re-)install from ``REPRO_FAULTS``; uninstalls when unset/empty."""
    text = os.environ.get(ENV_VAR, "").strip()
    return install(text if text else None)


class active:
    """``with active(plan): ...`` — scoped installation for tests."""

    def __init__(self, plan: FaultPlan | str | None):
        self.plan = plan
        self._prev: FaultPlan | None = None

    def __enter__(self) -> FaultPlan | None:
        self._prev = FAULTS.plan
        return install(self.plan)

    def __exit__(self, *exc) -> bool:
        install(self._prev)
        return False


# ------------------------------------------------------------------ probes
def fire(site: str) -> str | None:
    """Probe ``site``: the fault kind to inject at this call, or None.

    The disabled path is one flag read — no locks, no allocation.
    """
    if not FAULTS.enabled:
        return None
    plan = FAULTS.plan
    return plan.fire(site) if plan is not None else None


def maybe_fail(site: str) -> None:
    """Probe ``site`` and raise the mapped exception when a rule fires."""
    if not FAULTS.enabled:
        return
    kind = fire(site)
    if kind is not None:
        raise exception_for(site, kind)


def exception_for(site: str, kind: str) -> BaseException:
    """The concrete exception an injected (site, kind) fault raises.

    Types are the REAL ones the hardened code paths classify on —
    ``OSError`` for transients (so the retry layer treats injected and
    genuine I/O failures identically), the store's typed corruption
    error for permanent damage, and so on.
    """
    msg = f"[fault-injection] {kind} fault at {site}"
    if site == "store.read":
        if kind == "transient":
            return OSError(msg)
        from repro.store import StoreCorruptionError   # lazy: no import cycle
        return StoreCorruptionError(msg)
    if site == "plan.alloc":
        if kind == "kernel":
            return KernelFailure(msg)
        return AllocationError(msg)
    if site == "stream.h2d":
        return OSError(msg)
    if site == "runtime.quantum":
        if kind == "crash":
            return WorkerCrashError(msg)
        return RuntimeError(msg)
    raise FaultSpecError(f"no exception mapping for site {site!r} "
                         f"kind {kind!r} (probe with fire() instead)")


# Honour REPRO_FAULTS from process start, matching REPRO_SANITIZE's
# behaviour of being active without code changes.
reload_from_env()
