"""Typed retry with exponential backoff + jitter.

The hardening counterpart of ``inject``: pipeline stages that touch
unreliable media (disk reads feeding the stream, H2D puts) wrap their
fallible call in :func:`retry_call`.  Errors are classified by a small
taxonomy:

* **transient** — worth retrying: ``OSError`` / ``ConnectionError`` /
  ``TimeoutError`` (real or injected I/O flake) and anything raised as
  :class:`Transient`;
* **permanent** — re-raised immediately: everything else, including the
  store's typed ``StoreCorruptionError`` (corrupt bytes do not get better
  on re-read; the registry's self-heal owns that path) and anything
  raised as :class:`Permanent`.

Every retry increments ``stats.retries`` (an ``EngineStats`` field, rolled
up into the service's ``retries_total``) and records a ``retry.attempt``
obs span; exhausting the policy increments ``stats.giveups`` and re-raises
the last error.
"""
from __future__ import annotations

import dataclasses
import random
import time

from repro.obs import trace as obs_trace


class Transient(Exception):
    """An explicitly-retryable failure (wrap a cause to force retries)."""


class Permanent(Exception):
    """An explicitly-permanent failure (never retried, even if it wraps
    an otherwise-transient type)."""


TRANSIENT_TYPES = (Transient, OSError, ConnectionError, TimeoutError)


def is_transient(exc: BaseException) -> bool:
    """True when ``exc`` is worth retrying under the taxonomy above."""
    if isinstance(exc, Permanent):
        return False
    return isinstance(exc, TRANSIENT_TYPES)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff: base * 2^(attempt-1), capped, jittered.

    ``attempts`` counts TOTAL tries (first call included).  Delays are
    deliberately tiny — the media being retried (page cache, PCIe put)
    recovers in microseconds, and the streaming hot loop must not stall
    a quantum for human-scale seconds.
    """
    attempts: int = 4
    base_delay_s: float = 0.002
    max_delay_s: float = 0.05
    jitter: float = 0.5        # delay *= 1 + jitter * U[0,1)

    def delay_s(self, attempt: int) -> float:
        delay = min(self.max_delay_s,
                    self.base_delay_s * (2 ** (attempt - 1)))
        return delay * (1.0 + self.jitter * random.random())


DEFAULT_POLICY = RetryPolicy()


def retry_call(fn, *, site: str, policy: RetryPolicy = DEFAULT_POLICY,
               stats=None, sleep=time.sleep):
    """Call ``fn()`` until it succeeds, a permanent error is raised, or
    the policy is exhausted.

    ``site`` labels the ``retry.attempt`` spans and error messages (use
    the fault-site name of the operation being retried).  ``stats`` is an
    ``EngineStats`` (or anything with ``retries``/``giveups`` ints).
    """
    attempt = 1
    while True:
        try:
            if attempt == 1:
                return fn()
            with obs_trace.span("retry.attempt", "retry",
                                site=site, attempt=attempt):
                return fn()
        except Exception as exc:        # noqa: BLE001 — classified below
            if not is_transient(exc):
                raise
            if attempt >= policy.attempts:
                if stats is not None:
                    stats.giveups += 1
                raise
            if stats is not None:
                stats.retries += 1
            sleep(policy.delay_s(attempt))
            attempt += 1
