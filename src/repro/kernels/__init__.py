"""Pallas TPU kernels for the BLCO MTTKRP hot path (validated in interpret
mode on CPU; TARGET is TPU v5e).

``pallas_mttkrp`` / ``fused_cache_mttkrp`` run the whole pipeline as one
fused ``pallas_call`` per tile in a single jitted dispatch, driven by the
device-resident launch cache; ``pallas_mttkrp_phases`` keeps the three-
dispatch PR-2 pipeline as the benchmark reference."""
from .ops import (pallas_mttkrp, pallas_mttkrp_phases, fused_mttkrp_flat,
                  fused_cache_mttkrp)
from .delinearize import delinearize, extract_field_words
from .blco_mttkrp import mttkrp_segments, mttkrp_stash

__all__ = ["pallas_mttkrp", "pallas_mttkrp_phases", "fused_mttkrp_flat",
           "fused_cache_mttkrp", "delinearize", "extract_field_words",
           "mttkrp_segments", "mttkrp_stash"]
