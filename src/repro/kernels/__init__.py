"""Pallas TPU kernels for the BLCO MTTKRP hot path (validated in interpret
mode on CPU; TARGET is TPU v5e)."""
from .ops import pallas_mttkrp
from .delinearize import delinearize
from .blco_mttkrp import mttkrp_segments, mttkrp_stash

__all__ = ["pallas_mttkrp", "delinearize", "mttkrp_segments", "mttkrp_stash"]
