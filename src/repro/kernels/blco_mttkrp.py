"""Pallas kernels: the BLCO MTTKRP computing phase (paper §5.1.2, §5.2).

Two variants, mirroring the paper's two conflict-resolution mechanisms, both
re-thought for the TPU memory hierarchy (DESIGN.md §2):

``segment`` (register-based analogue, paper §5.2)
    Per VMEM tile of T non-zeros: partial = value x hadamard(gathered rows);
    segment boundaries discovered on the fly by comparing adjacent target
    indices; the segmented reduction is performed as **one-hot @ partials on
    the MXU** — the systolic array plays the role of the GPU's warp shuffles.
    Output: per-tile compressed (seg_tgt, seg_sums); the caller issues ONE
    update per discovered segment (vs per nnz), the paper's atomic reduction.

``stash`` (hierarchical, paper §5.1 steps 5-7)
    For short target modes (the §5.3 contention regime) the entire (I, R)
    output lives in VMEM as a revisited output block; every grid step
    accumulates its tile directly via a (I x T) one-hot matmul. The TPU grid
    is sequential on a core, so the revisited block is the local-memory
    stash; the C partial copies + final merge happen across cores at the XLA
    level (see ops.py / core.mttkrp hierarchical path).

No scatter, no atomics, no mode-specific data — one kernel for every mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hadamard(vals, gathered):
    partial = vals[:, None].astype(jnp.result_type(vals, gathered[0]))
    for u in gathered:
        partial = partial * u
    return partial


def _onthefly_segments(tgt):
    """Segment ids within a tile: boundary at row 0 and wherever tgt changes."""
    t = tgt.shape[0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
    prev = jnp.roll(tgt, 1)
    flags = jnp.where((pos == 0) | (tgt != prev), 1, 0).astype(jnp.int32)
    return jnp.cumsum(flags) - 1        # (t,), values in [0, #segments)


def _segment_kernel(vals_ref, tgt_ref, *rest):
    *g_refs, seg_tgt_ref, seg_sums_ref = rest
    vals = vals_ref[...]
    tgt = tgt_ref[...]
    t = vals.shape[0]
    partial = _hadamard(vals, [g[...] for g in g_refs])

    seg_id = _onthefly_segments(tgt)
    # one-hot segmented reduction on the MXU: [T, T] @ [T, R]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    onehot = (rows == seg_id[None, :]).astype(partial.dtype)
    seg_sums_ref[...] = jax.lax.dot(onehot, partial,
                                    preferred_element_type=partial.dtype)
    # segment target index; padding rows (no segment) -> -1
    seg_tgt = jnp.max(jnp.where(rows == seg_id[None, :], tgt[None, :] + 1, 0),
                      axis=1) - 1
    seg_tgt_ref[...] = seg_tgt


@functools.partial(jax.jit,
                   static_argnames=("tile", "interpret"))
def mttkrp_segments(vals, tgt, gathered, *, tile: int = 256,
                    interpret: bool = True):
    """Fused hadamard + on-the-fly segmented reduction, per VMEM tile.

    vals: (T,) float; tgt: (T,) int32 (ALTO order, NOT sorted); gathered:
    tuple of (T, R) non-target factor rows. T % tile == 0.
    Returns (seg_tgt (T,) int32 [-1 padded], seg_sums (T, R)).
    """
    t = vals.shape[0]
    r = gathered[0].shape[1]
    assert t % tile == 0, (t, tile)
    grid = (t // tile,)
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    mat = pl.BlockSpec((tile, r), lambda i: (i, 0))
    seg_tgt, seg_sums = pl.pallas_call(
        _segment_kernel,
        grid=grid,
        in_specs=[vec, vec] + [mat] * len(gathered),
        out_specs=(vec, mat),
        out_shape=(jax.ShapeDtypeStruct((t,), jnp.int32),
                   jax.ShapeDtypeStruct((t, r), jnp.result_type(vals, gathered[0]))),
        interpret=interpret,
    )(vals, tgt, *gathered)
    return seg_tgt, seg_sums


def _stash_kernel(vals_ref, tgt_ref, *rest, out_rows):
    *g_refs, out_ref = rest
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...]
    tgt = tgt_ref[...]
    t = vals.shape[0]
    partial = _hadamard(vals, [g[...] for g in g_refs])
    # direct (I x T) one-hot accumulation into the VMEM-resident stash
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_rows, t), 0)
    onehot = (rows == tgt[None, :]).astype(partial.dtype)
    out_ref[...] += jax.lax.dot(onehot, partial,
                                preferred_element_type=partial.dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_rows", "tile", "interpret"))
def mttkrp_stash(vals, tgt, gathered, *, out_rows: int, tile: int = 256,
                 interpret: bool = True):
    """Hierarchical small-mode variant: full (out_rows, R) accumulated in a
    revisited VMEM output block across the sequential TPU grid.

    Only for short target modes (out_rows <= ~1024) per the §5.3 heuristic —
    the stash must fit VMEM alongside the tile.
    """
    t = vals.shape[0]
    r = gathered[0].shape[1]
    assert t % tile == 0, (t, tile)
    grid = (t // tile,)
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    mat = pl.BlockSpec((tile, r), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_stash_kernel, out_rows=out_rows),
        grid=grid,
        in_specs=[vec, vec] + [mat] * len(gathered),
        out_specs=pl.BlockSpec((out_rows, r), lambda i: (0, 0)),  # revisited
        out_shape=jax.ShapeDtypeStruct((out_rows, r),
                                   jnp.result_type(vals, gathered[0])),
        interpret=interpret,
    )(vals, tgt, *gathered)
