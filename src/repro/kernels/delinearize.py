"""Pallas kernel: BLCO de-linearization (the paper's processing phase, §5.1.1).

Each grid step loads a VMEM tile of stored (hi, lo) uint32 index words and the
per-element block bases, and recovers every mode's coordinate with the
shift+mask extraction the BLCO re-encoding was designed for — 32-bit ops only
(TPU VPU is a 32-bit machine; DESIGN.md §2). Each coordinate is computed
independently, exposing ILP exactly as the paper notes.

Fields that straddle the 32-bit word boundary are stitched from both words —
the price of the 2x-uint32 adaptation, two extra bitwise ops for at most one
mode per tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def extract_field_words(hi, lo, shift: int, width: int):
    """One mode's field from (hi, lo) uint32 word pairs — 32-bit ops only.

    Shared by the standalone delinearize kernel and the fused MTTKRP
    pipeline (``repro.kernels.fused``); shift/width are static per mode.
    """
    if width == 0:
        return jnp.zeros_like(lo)
    if shift >= 32:                        # entirely in hi word
        mask = jnp.uint32((1 << width) - 1) if width < 32 else jnp.uint32(0xFFFFFFFF)
        return (hi >> jnp.uint32(shift - 32)) & mask
    if shift + width <= 32:                # entirely in lo word
        mask = jnp.uint32((1 << width) - 1) if width < 32 else jnp.uint32(0xFFFFFFFF)
        return (lo >> jnp.uint32(shift)) & mask
    # straddles: stitch both words
    lo_bits = 32 - shift
    lo_part = lo >> jnp.uint32(shift)
    hi_part = hi & jnp.uint32((1 << (shift + width - 32)) - 1)
    field = lo_part | (hi_part << jnp.uint32(lo_bits))
    return field & jnp.uint32((1 << width) - 1)


def _kernel(hi_ref, lo_ref, bases_ref, out_ref, *, field_bits, field_shifts):
    hi = hi_ref[...]
    lo = lo_ref[...]
    cols = []
    for n, (shift, width) in enumerate(zip(field_shifts, field_bits)):
        field = extract_field_words(hi, lo, shift, width)
        cols.append(field.astype(jnp.int32) + bases_ref[:, n])
    out_ref[...] = jnp.stack(cols, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("field_bits", "field_shifts", "tile",
                                    "interpret"))
def delinearize(idx_hi, idx_lo, bases, *, field_bits: tuple,
                field_shifts: tuple, tile: int = 1024, interpret: bool = True):
    """(T,) uint32 words + (T, N) int32 bases -> (T, N) int32 coordinates.

    T must be a multiple of ``tile`` (callers pad launches to power-of-two
    sizes already). interpret=True validates on CPU; on TPU pass False.
    """
    t = idx_hi.shape[0]
    n_modes = len(field_bits)
    assert t % tile == 0, (t, tile)
    grid = (t // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, field_bits=field_bits,
                          field_shifts=field_shifts),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, n_modes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n_modes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n_modes), jnp.int32),
        interpret=interpret,
    )(idx_hi, idx_lo, bases)
