"""Fused single-``pallas_call`` BLCO MTTKRP: the whole per-tile pipeline.

PR 2 ran the Pallas path as THREE device round-trips per launch —
delinearize kernel -> HBM coords -> XLA gather -> HBM gathered rows ->
compute kernel.  Here the paper's two phases fuse into ONE kernel per tile:

  per VMEM tile of T non-zeros:
    1. processing (§5.1.1): shift+mask de-linearization of the (hi, lo)
       stored index words + per-element block bases — in registers;
    2. computing (§5.1.2): gather the non-target factor rows from the
       VMEM-resident factor matrices, hadamard with the values, discover
       segment boundaries on the fly, and segment-reduce with a one-hot
       matmul on the MXU.

Coordinates and gathered rows never touch HBM.  Two conflict-resolution
variants, as in the paper:

``segment`` (register analogue, §5.2): per-tile compressed (seg_tgt,
    seg_sums) outputs; ONE update per discovered segment is applied by a
    masked scatter-add that XLA fuses into the same dispatch.
``stash`` (hierarchical, §5.1 steps 5-7): the full (I, R) output lives in
    VMEM as a revisited block accumulated across the sequential TPU grid —
    for short target modes (the §5.3 contention regime).

Inputs come straight from the device-resident launch cache
(``repro.core.launches.LaunchCache.flat()``): no per-call host padding, and
the host issues exactly ONE jitted dispatch per MTTKRP call.

``interpret`` defaults to True (CPU validation container); pass False on a
real TPU/GPU backend.  The factor matrices are passed as whole revisited
blocks, so on TPU they must fit VMEM alongside one tile — the same
constraint the paper's shared-memory gather stage has.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.counters import record_dispatch
from repro.core.mttkrp import CONTENTION_THRESHOLD, choose_resolution

from .delinearize import extract_field_words

# VMEM budget for the stash variant: the revisited (out_rows, R) block must
# fit alongside one tile (same bound ops.py used for the 3-dispatch path).
STASH_MAX_ROWS = 4 * CONTENTION_THRESHOLD


def _delinearize_tile(hi, lo, bases, field_bits, field_shifts):
    """All mode coordinates of one tile, in registers. Returns list of (t,)."""
    coords = []
    for n, (shift, width) in enumerate(zip(field_shifts, field_bits)):
        field = extract_field_words(hi, lo, shift, width)
        coords.append(field.astype(jnp.int32) + bases[:, n])
    return coords


def _partial_and_target(hi_ref, lo_ref, vals_ref, bases_ref, f_refs, *,
                        mode, field_bits, field_shifts):
    """Phases 1+2 shared by both variants: delinearize, gather, hadamard."""
    hi = hi_ref[...]
    lo = lo_ref[...]
    vals = vals_ref[...]
    coords = _delinearize_tile(hi, lo, bases_ref[...], field_bits,
                               field_shifts)
    # promote, never downcast (dtype parity with the XLA scan path)
    partial = vals[:, None].astype(jnp.result_type(vals, f_refs[0]))
    j = 0
    for m in range(len(field_bits)):
        if m == mode:
            continue
        rows = jnp.take(f_refs[j][...], coords[m], axis=0)
        partial = partial * rows
        j += 1
    return partial, coords[mode]


def _fused_segment_kernel(hi_ref, lo_ref, vals_ref, bases_ref, *rest,
                          mode, field_bits, field_shifts):
    *f_refs, seg_tgt_ref, seg_sums_ref = rest
    partial, tgt = _partial_and_target(hi_ref, lo_ref, vals_ref, bases_ref,
                                       f_refs, mode=mode,
                                       field_bits=field_bits,
                                       field_shifts=field_shifts)
    t = tgt.shape[0]
    # on-the-fly segment ids: boundary at row 0 and wherever tgt changes
    pos = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
    prev = jnp.roll(tgt, 1)
    flags = jnp.where((pos == 0) | (tgt != prev), 1, 0).astype(jnp.int32)
    seg_id = jnp.cumsum(flags) - 1
    # one-hot segmented reduction on the MXU: [T, T] @ [T, R]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    onehot = (rows == seg_id[None, :]).astype(partial.dtype)
    seg_sums_ref[...] = jax.lax.dot(onehot, partial,
                                    preferred_element_type=partial.dtype)
    # segment target index; padding rows (no segment) -> -1
    seg_tgt = jnp.max(jnp.where(rows == seg_id[None, :], tgt[None, :] + 1, 0),
                      axis=1) - 1
    seg_tgt_ref[...] = seg_tgt


def _fused_stash_kernel(hi_ref, lo_ref, vals_ref, bases_ref, *rest,
                        mode, field_bits, field_shifts, out_rows):
    *f_refs, out_ref = rest
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    partial, tgt = _partial_and_target(hi_ref, lo_ref, vals_ref, bases_ref,
                                       f_refs, mode=mode,
                                       field_bits=field_bits,
                                       field_shifts=field_shifts)
    t = tgt.shape[0]
    # direct (I x T) one-hot accumulation into the VMEM-resident stash
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_rows, t), 0)
    onehot = (rows == tgt[None, :]).astype(partial.dtype)
    out_ref[...] += jax.lax.dot(onehot, partial,
                                preferred_element_type=partial.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("field_bits", "field_shifts", "mode", "out_rows",
                     "variant", "tile", "interpret"))
def _fused_flat(hi, lo, vals, bases, factors, *, field_bits: tuple,
                field_shifts: tuple, mode: int, out_rows: int, variant: str,
                tile: int, interpret: bool):
    """One jitted dispatch: fused pallas_call (+ fused per-segment scatter)."""
    t = hi.shape[0]
    n_modes = len(field_bits)
    others = tuple(factors[m] for m in range(n_modes) if m != mode)
    r = others[0].shape[1]
    out_dtype = jnp.result_type(vals, others[0])
    grid = (t // tile,)
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    basespec = pl.BlockSpec((tile, n_modes), lambda i: (i, 0))
    # factor matrices ride along as whole revisited blocks (VMEM-resident)
    fspecs = [pl.BlockSpec(f.shape, lambda i: (0, 0)) for f in others]

    if variant == "stash":
        return pl.pallas_call(
            functools.partial(_fused_stash_kernel, mode=mode,
                              field_bits=field_bits,
                              field_shifts=field_shifts, out_rows=out_rows),
            grid=grid,
            in_specs=[vec, vec, vec, basespec] + fspecs,
            out_specs=pl.BlockSpec((out_rows, r), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((out_rows, r), out_dtype),
            interpret=interpret,
        )(hi, lo, vals, bases, *others)

    seg_tgt, seg_sums = pl.pallas_call(
        functools.partial(_fused_segment_kernel, mode=mode,
                          field_bits=field_bits, field_shifts=field_shifts),
        grid=grid,
        in_specs=[vec, vec, vec, basespec] + fspecs,
        out_specs=(vec, pl.BlockSpec((tile, r), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((t,), jnp.int32),
                   jax.ShapeDtypeStruct((t, r), out_dtype)),
        interpret=interpret,
    )(hi, lo, vals, bases, *others)
    # ONE update per discovered segment (paper's atomic reduction), fused by
    # XLA into the same dispatch; -1 rows are padding
    out = jnp.zeros((out_rows, r), seg_sums.dtype)
    return out.at[jnp.maximum(seg_tgt, 0)].add(
        jnp.where(seg_tgt[:, None] >= 0, seg_sums, 0))


def _variant_for(resolution: str, out_rows: int) -> str:
    if resolution == "hierarchical" and out_rows <= STASH_MAX_ROWS:
        return "stash"
    return "segment"


def fused_mttkrp_flat(hi, lo, vals, bases, factors, *, field_bits: tuple,
                      field_shifts: tuple, mode: int, out_rows: int,
                      resolution: str = "auto", tile: int = 256,
                      interpret: bool = True):
    """Fused MTTKRP over a flat reservation-padded nnz stream.

    hi/lo: (T,) uint32; vals: (T,); bases: (T, N) int32; T is the padded
    stream length (launch boundaries are irrelevant: per-element bases carry
    the block offsets, and segments are discovered per tile).  Exactly one
    recorded dispatch.
    """
    factors = tuple(jnp.asarray(f) for f in factors)
    if resolution == "auto":
        resolution = choose_resolution(out_rows)
    t = int(hi.shape[0])
    # largest tile <= the requested one that divides the stream (LANE-
    # multiple reservations keep this at the requested tile; odd custom
    # reservations degrade the tile rather than crash)
    tile = math.gcd(t, max(1, min(tile, t)))
    record_dispatch()
    return _fused_flat(hi, lo, vals, bases, factors, field_bits=field_bits,
                       field_shifts=field_shifts, mode=mode,
                       out_rows=out_rows,
                       variant=_variant_for(resolution, out_rows),
                       tile=tile, interpret=interpret)


def fused_cache_mttkrp(cache, factors, mode: int, *,
                       resolution: str = "auto", tile: int = 256,
                       interpret: bool = True):
    """Fused MTTKRP straight from a device-resident ``LaunchCache``.

    Zero per-call host work: the cache's stacked ``(L, reservation)`` arrays
    are reshaped on device into one flat stream and tiled by the fused
    kernel — one dispatch per call regardless of launch count.
    """
    if cache.closed:
        raise RuntimeError("launch cache is closed")
    factors = tuple(jnp.asarray(f) for f in factors)
    if cache.num_launches == 0:
        rank = factors[0].shape[1]
        return jnp.zeros((cache.dims[mode], rank),
                         jnp.result_type(cache.vals, factors[0]))
    hi, lo, vals, bases = cache.flat()
    return fused_mttkrp_flat(hi, lo, vals, bases, factors,
                             field_bits=cache.re_fields,
                             field_shifts=cache.re_shifts, mode=mode,
                             out_rows=cache.dims[mode],
                             resolution=resolution, tile=tile,
                             interpret=interpret)
