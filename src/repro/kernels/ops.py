"""Jit'd public wrappers around the Pallas BLCO-MTTKRP kernels.

``pallas_mttkrp`` is a drop-in replacement for ``repro.core.mttkrp.mttkrp``:
same BLCOTensor in, same (I_mode, R) out, validated against the same dense
oracle. The pipeline per launch is the paper's two phases:

  1. processing: ``delinearize`` kernel (shift+mask on uint32 word pairs);
  2. gather:     non-target factor rows via XLA's native gather (on TPU this
                 is the hardware-optimized path; the GPU paper's coalesced
                 loads have no direct Pallas analogue — DESIGN.md §2);
  3. computing:  fused hadamard + on-the-fly segmented reduction kernel —
                 ``stash`` variant when the target mode is short (the §5.3
                 heuristic), ``segment`` variant + one-update-per-segment
                 scatter otherwise.

``interpret`` defaults to True (CPU validation container); pass False on TPU.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core.blco import BLCOTensor
from repro.core.mttkrp import choose_resolution, CONTENTION_THRESHOLD

from .delinearize import delinearize
from .blco_mttkrp import mttkrp_segments, mttkrp_stash
from .ref import scatter_segments_ref


def _pad_pow2(n: int, floor: int) -> int:
    return max(floor, 1 << math.ceil(math.log2(max(1, n))))


def pallas_mttkrp(blco: BLCOTensor, factors, mode: int, *,
                  tile: int = 256, interpret: bool = True,
                  resolution: str = "auto"):
    """Full mode-n MTTKRP over all launches, Pallas path."""
    assert 0 <= mode < blco.order
    factors = tuple(jnp.asarray(f) for f in factors)
    rank = factors[0].shape[1]
    out = jnp.zeros((blco.dims[mode], rank), factors[0].dtype)
    if resolution == "auto":
        resolution = choose_resolution(blco.dims[mode])
    use_stash = (resolution == "hierarchical"
                 and blco.dims[mode] <= 4 * CONTENTION_THRESHOLD)

    bases_all = blco.block_upper_bases()
    block_ids = blco.element_block_ids()
    re = blco.re
    for launch in blco.launches:
        s, e = launch.start, launch.end
        n = e - s
        padded = _pad_pow2(n, tile)
        hi = np.zeros(padded, np.uint32); hi[:n] = blco.idx_hi[s:e]
        lo = np.zeros(padded, np.uint32); lo[:n] = blco.idx_lo[s:e]
        vals = np.zeros(padded, np.float32); vals[:n] = blco.values[s:e]
        bases = np.zeros((padded, blco.order), np.int32)
        bases[:n] = bases_all[block_ids[s:e]]

        # phase 1: processing (Pallas delinearize kernel)
        coords = delinearize(jnp.asarray(hi), jnp.asarray(lo),
                             jnp.asarray(bases),
                             field_bits=re.field_bits,
                             field_shifts=re.field_shift,
                             tile=min(1024, padded), interpret=interpret)
        # phase 2: gather non-target rows (XLA native gather)
        gathered = tuple(jnp.take(factors[m], coords[:, m], axis=0)
                         for m in range(blco.order) if m != mode)
        tgt = coords[:, mode]
        v = jnp.asarray(vals)

        # phase 3: computing (fused Pallas kernel)
        if use_stash:
            out = out + mttkrp_stash(v, tgt, gathered,
                                     out_rows=blco.dims[mode],
                                     tile=tile, interpret=interpret)
        else:
            seg_tgt, seg_sums = mttkrp_segments(v, tgt, gathered,
                                                tile=tile, interpret=interpret)
            out = out + scatter_segments_ref(seg_tgt, seg_sums,
                                             blco.dims[mode])
    return out
