"""Public wrappers around the Pallas BLCO-MTTKRP kernels.

``pallas_mttkrp`` is a drop-in replacement for ``repro.core.mttkrp.mttkrp``:
same BLCOTensor in, same (I_mode, R) out, validated against the same dense
oracle.  It is driven by the device-resident launch cache
(``repro.core.launches.LaunchCache``) and executes the ENTIRE pipeline —
delinearize -> factor-row gather -> hadamard -> on-the-fly segmented
reduction — as one fused ``pallas_call`` per tile inside a single jitted
dispatch (``repro.kernels.fused``): zero per-call host padding, no
HBM-materialized intermediates.

``pallas_mttkrp_phases`` keeps the PR-2 three-phase pipeline (standalone
delinearize kernel -> XLA gather -> compute kernel, each phase round-
tripping through HBM) as the benchmark reference the fused path is
measured against in ``BENCH_3.json``.  It too is cache-driven — the host
numpy padding it used to redo every call is gone.

``interpret`` defaults to True (CPU validation container); pass False on TPU.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.blco import BLCOTensor
from repro.core.counters import record_dispatch
from repro.core.mttkrp import choose_resolution, launch_cache_for

from .delinearize import delinearize
from .blco_mttkrp import mttkrp_segments, mttkrp_stash
from .fused import (STASH_MAX_ROWS, fused_cache_mttkrp, fused_mttkrp_flat)
from .ref import scatter_segments_ref


def pallas_mttkrp(blco: BLCOTensor, factors, mode: int, *,
                  tile: int = 256, interpret: bool = True,
                  resolution: str = "auto", cache=None):
    """Full mode-n MTTKRP, fused single-dispatch Pallas path.

    The launch cache is built once (attached to ``blco``, or passed in);
    every call afterwards is one jitted dispatch over the cached stream.
    """
    assert 0 <= mode < blco.order
    cache = cache if cache is not None else launch_cache_for(blco)
    return fused_cache_mttkrp(cache, factors, mode, resolution=resolution,
                              tile=tile, interpret=interpret)


def pallas_mttkrp_phases(blco: BLCOTensor, factors, mode: int, *,
                         tile: int = 256, interpret: bool = True,
                         resolution: str = "auto", cache=None):
    """The PR-2 three-phase Pallas pipeline (benchmark reference).

    Per call: delinearize kernel -> HBM coords -> XLA gather -> HBM rows ->
    compute kernel -> per-segment scatter.  Cache-driven (no host numpy),
    but the intermediates still round-trip through device memory and the
    phases are separate dispatches — exactly what the fused path removes.
    """
    assert 0 <= mode < blco.order
    cache = cache if cache is not None else launch_cache_for(blco)
    factors = tuple(jnp.asarray(f) for f in factors)
    rank = factors[0].shape[1]
    if resolution == "auto":
        resolution = choose_resolution(blco.dims[mode])
    use_stash = (resolution == "hierarchical"
                 and blco.dims[mode] <= STASH_MAX_ROWS)
    if cache.num_launches == 0:
        return jnp.zeros((blco.dims[mode], rank),
                         jnp.result_type(cache.vals, factors[0]))

    hi, lo, vals, bases = cache.flat()
    t = int(hi.shape[0])
    tile = math.gcd(t, max(1, min(tile, t)))   # largest dividing tile
    record_dispatch(3)          # three separate device phases per call

    # phase 1: processing (standalone Pallas delinearize kernel)
    coords = delinearize(hi, lo, bases, field_bits=cache.re_fields,
                         field_shifts=cache.re_shifts, tile=tile,
                         interpret=interpret)
    # phase 2: gather non-target rows (XLA native gather, HBM round-trip)
    gathered = tuple(jnp.take(factors[m], coords[:, m], axis=0)
                     for m in range(blco.order) if m != mode)
    tgt = coords[:, mode]

    # phase 3: computing (Pallas kernel) + final update
    if use_stash:
        return mttkrp_stash(vals, tgt, gathered, out_rows=blco.dims[mode],
                            tile=tile, interpret=interpret)
    seg_tgt, seg_sums = mttkrp_segments(vals, tgt, gathered, tile=tile,
                                        interpret=interpret)
    return scatter_segments_ref(seg_tgt, seg_sums, blco.dims[mode])


__all__ = ["pallas_mttkrp", "pallas_mttkrp_phases", "fused_mttkrp_flat",
           "fused_cache_mttkrp"]
