"""Pure-jnp oracles for the Pallas kernels (shape-for-shape identical outputs).

Every kernel in this package is validated against these references across
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import u64


def delinearize_ref(idx_hi, idx_lo, bases, *, field_bits, field_shifts):
    """(T,) hi/lo uint32 + (T, N) int32 bases -> (T, N) int32 coordinates."""
    cols = []
    for n, (shift, width) in enumerate(zip(field_shifts, field_bits)):
        f = u64.extract_field(idx_hi, idx_lo, shift, width).astype(jnp.int32)
        cols.append(f + bases[:, n])
    return jnp.stack(cols, axis=1)


def _tile_segments(tgt, tile: int):
    """Per-tile on-the-fly segment ids: a new segment starts at every tile
    boundary and wherever the target index changes (paper §5.1 step 3)."""
    t = tgt.shape[0]
    pos = jnp.arange(t, dtype=jnp.int32)
    prev = jnp.roll(tgt, 1)
    flags = jnp.where((pos % tile == 0) | (tgt != prev), 1, 0).astype(jnp.int32)
    # segment ids restart per tile so they match the per-tile kernel outputs
    seg_global = jnp.cumsum(flags) - 1
    tile_id = pos // tile
    tile_first_seg = seg_global.reshape(-1, tile)[:, 0]
    return seg_global - tile_first_seg[tile_id], tile_id


def mttkrp_segments_ref(vals, tgt, gathered, *, tile: int):
    """Oracle for the fused compute kernel (segment-output variant).

    vals: (T,) values; tgt: (T,) int32 target-mode coords (ALTO order);
    gathered: tuple of (T, R) non-target factor rows.
    Returns (seg_tgt, seg_sums): (T,) int32 with -1 padding, (T, R).
    Row k of tile j corresponds to the k-th discovered segment of that tile.
    """
    t = vals.shape[0]
    r = gathered[0].shape[1]
    assert t % tile == 0
    partial = vals[:, None].astype(jnp.result_type(vals, gathered[0]))
    for u in gathered:
        partial = partial * u
    seg_in_tile, tile_id = _tile_segments(tgt, tile)
    flat_seg = tile_id * tile + seg_in_tile
    seg_sums = jax.ops.segment_sum(partial, flat_seg, num_segments=t)
    seg_tgt = jnp.full((t,), -1, jnp.int32).at[flat_seg].max(tgt)
    return seg_tgt, seg_sums


def mttkrp_stash_ref(vals, tgt, gathered, *, out_rows: int):
    """Oracle for the stash (hierarchical small-mode) variant: full (I, R)
    accumulation — equivalent to a plain scatter-add of all partials."""
    partial = vals[:, None].astype(jnp.result_type(vals, gathered[0]))
    for u in gathered:
        partial = partial * u
    out = jnp.zeros((out_rows, partial.shape[1]), partial.dtype)
    return out.at[tgt].add(partial)


def scatter_segments_ref(seg_tgt, seg_sums, out_rows: int):
    """Final per-segment update (one update per segment, not per nnz)."""
    out = jnp.zeros((out_rows, seg_sums.shape[1]), seg_sums.dtype)
    return out.at[jnp.maximum(seg_tgt, 0)].add(
        jnp.where(seg_tgt[:, None] >= 0, seg_sums, 0))
