import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA flag above must precede any jax
initialization — do not import this module from a live jax session).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k --mesh single --out results/qwen_train_single.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --out-dir results/

Per cell it records: lower+compile wall time, per-device memory analysis,
cost analysis (flops/bytes), the collective schedule (op counts + payload +
ring wire bytes), and the three roofline terms.
"""
import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402


def _compile_once(cfg, shape, mesh, opt_cfg):
    """Lower + compile one step; return (record, compiled)."""
    import jax
    from repro.dist import context as dist_context
    from repro.launch import steps as steps_mod

    rec: dict = {}
    t0 = time.perf_counter()
    with mesh:
        dist_context.set_mesh(mesh)
        try:
            fn, arg_sds, in_sh, out_sh = steps_mod.build_cell(
                cfg, shape, mesh, opt_cfg=opt_cfg)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*arg_sds)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1
        finally:
            dist_context.set_mesh(None)
    return rec, compiled


def _analyse(compiled) -> dict:
    from repro.launch import roofline as rl
    out: dict = {}
    try:
        ca = compiled.cost_analysis()
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        out["transcendentals"] = float(ca.get("transcendentals", 0.0))
    except Exception as exc:
        out["cost_analysis_error"] = repr(exc)
        out["flops"] = out["bytes_accessed"] = 0.0
    hlo = compiled.as_text()
    out["hlo_bytes"] = len(hlo)
    coll = rl.parse_collectives(hlo)
    out["collectives"] = coll.summary()
    out["wire_bytes"] = coll.wire_bytes
    return out


def _memory(compiled) -> dict:
    rec: dict = {}
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes") if hasattr(ma, k)}
        arg_b = rec["memory_analysis"].get("argument_size_in_bytes", 0)
        alias_b = rec["memory_analysis"].get("alias_size_in_bytes", 0)
        out_b = rec["memory_analysis"].get("output_size_in_bytes", 0)
        tmp_b = rec["memory_analysis"].get("temp_size_in_bytes", 0)
        rec["hbm_per_device_bytes"] = arg_b + tmp_b + max(0, out_b - alias_b)
    except Exception as exc:
        rec["memory_analysis_error"] = repr(exc)
    return rec


def _depth_points(cfg) -> tuple[int, int]:
    """Two reduced depths for the unrolled cost-model compiles."""
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return k, 2 * k
    if cfg.moe and cfg.first_dense_layers:
        return cfg.first_dense_layers + 2, cfg.first_dense_layers + 4
    return 2, 4


def _with_depth(cfg, layers: int):
    kw = {"num_layers": layers, "unroll_layers": True}
    if cfg.is_encdec:
        kw["encoder_layers"] = layers
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             overrides: dict | None = None,
             skip_cost_model: bool = False) -> dict:
    """One (arch x shape x mesh) cell.

    Three compiles:
      1. FULL config, rolled scans  -> the deliverable compile proof +
         memory analysis (deployment peak) + schedule sanity;
      2/3. depth La / Lb, unrolled  -> exact per-layer flops / bytes /
         collective wire bytes; linear extrapolation to full depth (XLA's
         cost analysis counts while bodies once, so rolled numbers are
         depth-independent; see EXPERIMENTS.md §Dry-run methodology).
    """
    import jax
    from repro.configs import get_config
    from repro.launch import roofline as rl
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.optim import adamw

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "overrides": overrides or {}}

    skip = steps_mod.shape_skip_reason(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    rec["chips"] = n_chips
    opt_cfg = adamw.AdamWConfig(
        quantize_v="int8" if cfg.name.startswith("deepseek") else "none")

    # ---- 1. full-config rolled compile (the dry-run deliverable) ----------
    crec, compiled = _compile_once(cfg, shape, mesh, opt_cfg)
    rec.update(crec)
    rec.update(_memory(compiled))
    rolled = _analyse(compiled)
    rec["rolled_analysis"] = {k: rolled[k] for k in
                              ("flops", "bytes_accessed", "wire_bytes",
                               "collectives", "hlo_bytes")}
    del compiled

    # ---- 2/3. unrolled depth points -> extrapolated exact cost ------------
    if skip_cost_model:
        flops_dev = rolled["flops"]
        bytes_dev = rolled["bytes_accessed"]
        wire_dev = rolled["wire_bytes"]
    else:
        la, lb = _depth_points(cfg)
        pts = {}
        for L in (la, lb):
            _, c = _compile_once(_with_depth(cfg, L), shape, mesh, opt_cfg)
            pts[L] = _analyse(c)
            del c
        rec["depth_points"] = {str(L): {k: pts[L][k] for k in
                                        ("flops", "bytes_accessed",
                                         "wire_bytes")} for L in (la, lb)}

        def extrap(key):
            slope = (pts[lb][key] - pts[la][key]) / (lb - la)
            return pts[la][key] + (cfg.num_layers - la) * slope

        flops_dev = extrap("flops")
        bytes_dev = extrap("bytes_accessed")
        wire_dev = extrap("wire_bytes")
        # collective op counts extrapolated the same way, per kind
        counts = {}
        for kind in set(pts[la]["collectives"]["counts"]) | \
                set(pts[lb]["collectives"]["counts"]):
            ca_ = pts[la]["collectives"]["counts"].get(kind, 0)
            cb_ = pts[lb]["collectives"]["counts"].get(kind, 0)
            counts[kind] = int(ca_ + (cfg.num_layers - la) *
                               (cb_ - ca_) / (lb - la))
        rec["collective_counts_extrapolated"] = counts

    rec["cost_analysis"] = {"flops": flops_dev, "bytes_accessed": bytes_dev}
    roof = rl.roofline_terms(max(0.0, flops_dev), max(0.0, bytes_dev),
                             max(0.0, wire_dev))
    rec["roofline"] = roof.as_dict()

    # ---- model flops (6ND) -------------------------------------------------
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    total, embed, moe_expert = 0, 0, 0
    from repro.dist.sharding import tree_paths
    for path, leaf in tree_paths(params_sds).items():
        n = int(leaf.size)
        total += n
        if path.startswith("embed/") or path.startswith("lm_head/"):
            embed += n
        if "/moe/w" in path and "/shared" not in path:
            moe_expert += n
    nonembed = total - embed
    active = nonembed - moe_expert + (moe_expert * cfg.top_k
                                      // max(1, cfg.num_experts))
    info = steps_mod.SHAPES[shape]
    n_tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    mf = rl.model_flops(cfg, n_tokens, params_nonembed=nonembed,
                        params_active_nonembed=active)
    if info["kind"] != "train":
        mf /= 3.0                   # forward only: 2ND
    rec["params_total"] = total
    rec["params_nonembed"] = nonembed
    rec["params_active_nonembed"] = active
    rec["model_flops_global"] = mf
    hlo_flops_global = flops_dev * n_chips
    rec["useful_flops_ratio"] = (mf / hlo_flops_global
                                 if hlo_flops_global > 0 else None)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--out")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (e.g. remat_policy=dots_saveable)")
    ap.add_argument("--skip-cost-model", action="store_true",
                    help="only the full rolled compile (multi-pod pass)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    try:
        rec = run_cell(args.arch, args.shape, args.mesh,
                       overrides=overrides or None,
                       skip_cost_model=args.skip_cost_model)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "traceback": traceback.format_exc()}
    text = json.dumps(rec, indent=1, default=str)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text)
    print(text)
    if rec.get("status") == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
