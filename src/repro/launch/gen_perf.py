"""Generate the EXPERIMENTS.md §Perf log from recorded dry-run/perf JSONs.

    PYTHONPATH=src python -m repro.launch.gen_perf >> section.md
"""
from __future__ import annotations

import json
import os


def _load(path):
    if not os.path.exists(path):
        return None
    r = json.load(open(path))
    return r if r.get("status") == "ok" else None


def row(tag, rec):
    if rec is None:
        return f"| {tag} | - | - | - | - | - | - |"
    rf = rec["roofline"]
    hbm = rec.get("hbm_per_device_bytes", 0) / 2**30
    return (f"| {tag} | {rf['compute_s']:.2f} | {rf['memory_s']:.2f} | "
            f"{rf['collective_s']:.2f} | **{rf['step_time_s']:.2f}** "
            f"({rf['dominant']}) | {hbm:.1f} | "
            f"{rec.get('useful_flops_ratio', 0) or 0:.2f} |")


HEADER = ("| config | compute(s) | memory(s) | collective(s) | step bound | "
          "HBM GiB/dev | useful |\n|---|---|---|---|---|---|---|")


def cell_table(arch, shape, tags):
    lines = [HEADER]
    base = _load(f"results/dryrun_baseline/{arch}.{shape}.single.json")
    cur = _load(f"results/dryrun/{arch}.{shape}.single.json")
    lines.append(row("iter-0 paper-faithful baseline (naive GSPMD)", base))
    lines.append(row("iter-1..3 global fixes (see narrative)", cur))
    for tag, label in tags:
        rec = _load(f"results/perf/{arch}.{shape}.{tag}.json")
        lines.append(row(label, rec))
    return "\n".join(lines)


def main():
    print("### Pair A — zamba2-1.2b x train_4k (worst roofline fraction; "
          "memory-bound)\n")
    print(cell_table("zamba2-1.2b", "train_4k", [
        ("chunk128", "iter-A1 ssd_chunk 256->128"),
        ("chunk64", "iter-A2 ssd_chunk 256->64"),
        ("split", "iter-A3 split z/x/B/C/dt projections"),
        ("chunk64split", "iter-A4 chunk64 + split"),
        ("chunk64split_bf16", "iter-A5 chunk64 + split + bf16 params"),
    ]))
    print("\n### Pair B — stablelm-12b x train_4k (most collective-bound)\n")
    print(cell_table("stablelm-12b", "train_4k", [
        ("bf16params", "iter-B2 bf16 param storage (halves AG/RS wire)"),
        ("dots_remat", "iter-B3 remat dots_saveable (less recompute)"),
        ("bf16_dots", "iter-B4 bf16 + dots_saveable"),
    ]))
    print("\n### Pair C — minicpm-2b x train_4k (paper-technique cell)\n")
    print(cell_table("minicpm-2b", "train_4k", [
        ("scatter", "iter-C1 embed_grad=scatter (naive baseline)"),
        ("segment_bf16", "iter-C2 segment + bf16 params"),
    ]))


if __name__ == "__main__":
    main()
