"""Production meshes.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the `pod`
axis carries only data parallelism + ZeRO sharding (cross-pod traffic is
gradient reduce-scatter/all-gather only, which tolerates the slower
inter-pod links).

Defined as functions, not module constants, so importing this module never
touches jax device state (dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return compat.make_mesh(shape, axes)
