"""Perf hillclimb runner: A/B config overrides against a dry-run cell.

    PYTHONPATH=src python -m repro.launch.perf --arch mamba2-370m \
        --shape train_4k --tag chunk64 --override ssd_chunk=64

Each run is a subprocess (clean XLA state); results accumulate in
results/perf/<arch>.<shape>.<tag>.json for the EXPERIMENTS.md §Perf log.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def run(arch, shape, tag, overrides, out_dir="results/perf", mesh="single",
        timeout=3600):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}.{shape}.{tag}.json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", path]
    for ov in overrides:
        cmd += ["--override", ov]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
    if not os.path.exists(path):
        raise RuntimeError(r.stderr[-2000:])
    rec = json.load(open(path))
    return rec


def summarize(rec):
    if rec.get("status") != "ok":
        return rec.get("status"), rec.get("traceback", "")[-500:]
    rf = rec["roofline"]
    return {
        "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"], "dominant": rf["dominant"],
        "step_s": rf["step_time_s"],
        "hbm_gib": rec.get("hbm_per_device_bytes", 0) / 2**30,
        "useful": rec.get("useful_flops_ratio"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args()
    rec = run(args.arch, args.shape, args.tag, args.override, mesh=args.mesh)
    print(json.dumps(summarize(rec), indent=1, default=str))


if __name__ == "__main__":
    main()
