"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from results JSONs.

    PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = ["stablelm-12b", "qwen2.5-14b", "minicpm-2b", "h2o-danube-3-4b",
              "mamba2-370m", "internvl2-2b", "seamless-m4t-large-v2",
              "zamba2-1.2b", "dbrx-132b", "deepseek-v2-236b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    cells = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        try:
            r = json.load(open(p))
        except Exception:
            continue
        cells[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(cells) -> str:
    rows = ["| arch | shape | 16x16 | 2x16x16 | HBM/dev (GiB) | compile(s) "
            "| collectives (single-pod) |",
            "|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            s = cells.get((arch, shape, "single"))
            m = cells.get((arch, shape, "multi"))

            def stat(r):
                if r is None:
                    return "PENDING"
                return {"ok": "PASS", "skipped": "SKIP",
                        "error": "FAIL", "timeout": "TIMEOUT"}.get(
                            r.get("status"), "?")
            hbm = fmt_bytes(s.get("hbm_per_device_bytes")) if s else "-"
            comp = f"{s.get('compile_s', 0):.0f}" if s and s.get("compile_s") \
                else "-"
            coll = "-"
            if s and s.get("status") == "ok":
                c = (s.get("rolled_analysis") or {}).get("collectives", {})
                coll = " ".join(f"{k}:{v}" for k, v in
                                sorted(c.get("counts", {}).items())) or "none"
            if s and s.get("status") == "skipped":
                coll = s.get("reason", "")[:60]
            rows.append(f"| {arch} | {shape} | {stat(s)} | {stat(m)} | "
                        f"{hbm} | {comp} | {coll} |")
    return "\n".join(rows)


def roofline_table(cells) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "step lower-bound | useful flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, "single"))
            if r is None or r.get("status") != "ok":
                if r is not None and r.get("status") == "skipped":
                    rows.append(f"| {arch} | {shape} | SKIP (full-attention; "
                                f"assignment rule) ||||||||")
                continue
            rf = r.get("roofline", {})
            ufr = r.get("useful_flops_ratio")
            # roofline fraction: useful compute time / step lower bound
            mf = r.get("model_flops_global", 0.0)
            chips = r.get("chips", 256)
            useful_compute_s = mf / chips / 197e12
            frac = useful_compute_s / rf["step_time_s"] if rf.get(
                "step_time_s") else None
            rows.append(
                f"| {arch} | {shape} | {fmt_s(rf.get('compute_s'))} | "
                f"{fmt_s(rf.get('memory_s'))} | "
                f"{fmt_s(rf.get('collective_s'))} | {rf.get('dominant')} | "
                f"{fmt_s(rf.get('step_time_s'))} | "
                f"{ufr:.2f} | {frac*100:.1f}% |" if frac is not None else
                f"| {arch} | {shape} | - | - | - | - | - | - | - |")
    return "\n".join(rows)


def _replace_block(text: str, marker: str, table: str) -> str:
    """Replace everything between ``marker`` and the next blank-line-followed
    non-table line with the fresh table (idempotent regeneration)."""
    import re
    pattern = re.compile(
        re.escape(marker) + r"(?:\n+(?:\|[^\n]*\n)+)?", re.M)
    return pattern.sub(marker + "\n\n" + table + "\n", text, count=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--experiments", default="EXPERIMENTS.md")
    args = ap.parse_args()
    cells = load(args.dir)
    dt = dryrun_table(cells)
    rt = roofline_table(cells)
    text = open(args.experiments).read()
    text = _replace_block(text, "<!-- DRYRUN_TABLE -->", dt)
    text = _replace_block(text, "<!-- ROOFLINE_TABLE -->", rt)
    open(args.experiments, "w").write(text)
    ok = sum(1 for r in cells.values() if r.get("status") == "ok")
    sk = sum(1 for r in cells.values() if r.get("status") == "skipped")
    er = sum(1 for r in cells.values()
             if r.get("status") in ("error", "timeout"))
    print(f"cells: {ok} ok, {sk} skipped, {er} failed, "
          f"{len(cells)} total recorded")


if __name__ == "__main__":
    main()
