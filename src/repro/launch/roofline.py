"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment constants).

Sources:
  * ``compiled.cost_analysis()``  -> HLO flops / bytes (PER DEVICE: the
    compiled module is the SPMD-partitioned per-device program).
  * ``compiled.as_text()``        -> collective ops with per-device shapes;
    wire bytes modeled per ring algorithm (all-reduce 2x payload,
    reduce-scatter/all-gather 1x, all-to-all 1x, collective-permute 1x).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_ARRAY_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|s4|u4|pred)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^=]*?\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _array_bytes(shape_text: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(shape_text):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    payload_bytes: dict       # per-device payload per op kind
    wire_bytes: int           # ring-model bytes crossing links, per device

    def summary(self) -> dict:
        return {"counts": self.counts, "payload_bytes": self.payload_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    payload: dict[str, int] = {}
    wire = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, op, start = m.group(1), m.group(2), m.group(3)
        if start and op in ("all-gather", "all-reduce", "reduce-scatter",
                            "collective-permute", "all-to-all"):
            # async start: tuple (operand, result) — use the LAST array
            arrays = _ARRAY_RE.findall(shape_text)
            if arrays:
                dt, dims = arrays[-1]
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                size = n * _DTYPE_BYTES[dt]
            else:
                size = 0
        else:
            size = _array_bytes(shape_text)

        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))

        counts[op] = counts.get(op, 0) + 1
        payload[op] = payload.get(op, 0) + size
        frac = (g - 1) / g if g > 1 else 0.0
        if op == "all-reduce":
            wire += int(2 * size * frac)
        elif op == "reduce-scatter":
            # HLO output is the scattered shard; ring wire = input*(g-1)/g
            wire += int(size * g * frac)
        elif op == "all-gather":
            wire += int(size * frac)          # output-sized payload
        else:                                  # all-to-all, permute
            wire += int(size * frac if g > 1 else size)
    return CollectiveStats(counts, payload, wire)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "step_time_s": self.step_time_s}


def roofline_terms(flops_per_device: float, hbm_bytes: float,
                   wire_bytes: float) -> Roofline:
    return Roofline(
        flops_per_device=flops_per_device,
        hbm_bytes_per_device=hbm_bytes,
        wire_bytes_per_device=wire_bytes,
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=hbm_bytes / HBM_BW,
        collective_s=wire_bytes / LINK_BW,
    )


def model_flops(cfg, n_tokens: int, *, params_nonembed: int,
                params_active_nonembed: int | None = None) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE)."""
    n = params_active_nonembed if params_active_nonembed is not None \
        else params_nonembed
    return 6.0 * n * n_tokens
