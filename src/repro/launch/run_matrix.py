"""Drive the full dry-run matrix as sequential subprocesses (resumable).

Each cell runs in its own process (XLA device-count flag must precede jax
init). Existing ok/skipped results are not recomputed, so the matrix can be
re-driven after fixes. Multi-pod cells skip the depth-point cost-model
compiles (§Roofline is single-pod only); they still do the full
lower+compile pass that the multi-pod dry-run requires.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ["mamba2-370m", "zamba2-1.2b", "minicpm-2b", "internvl2-2b",
         "h2o-danube-3-4b", "seamless-m4t-large-v2", "stablelm-12b",
         "qwen2.5-14b", "dbrx-132b", "deepseek-v2-236b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_path(out_dir, arch, shape, mesh):
    return os.path.join(out_dir, f"{arch}.{shape}.{mesh}.json")


def done(path):
    if not os.path.exists(path):
        return False
    try:
        return json.load(open(path)).get("status") in ("ok", "skipped")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--only-arch")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cells = []
    for mesh in args.meshes.split(","):
        for arch in ARCHS:
            if args.only_arch and arch != args.only_arch:
                continue
            for shape in SHAPES:
                cells.append((arch, shape, mesh))

    for i, (arch, shape, mesh) in enumerate(cells):
        path = cell_path(args.out_dir, arch, shape, mesh)
        if done(path):
            print(f"[{i+1}/{len(cells)}] skip-done {arch} {shape} {mesh}",
                  flush=True)
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", path]
        if mesh == "multi":
            cmd.append("--skip-cost-model")
        print(f"[{i+1}/{len(cells)}] run {arch} {shape} {mesh} ...",
              flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            status = json.load(open(path)).get("status") \
                if os.path.exists(path) else f"rc={r.returncode}"
        except subprocess.TimeoutExpired:
            status = "timeout"
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "timeout"}, f)
        print(f"    -> {status} in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
