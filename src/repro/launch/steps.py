"""Step builders + abstract input specs for every (arch x shape) dry-run cell.

Shapes (assigned):
  train_4k    seq 4096,   global_batch 256   -> train_step (fwd+bwd+AdamW)
  prefill_32k seq 32768,  global_batch 32    -> prefill (fwd, last-pos logits)
  decode_32k  kv 32768,   global_batch 128   -> serve_step (1 new token)
  long_500k   kv 524288,  global_batch 1     -> serve_step; sub-quadratic
                                                archs only (DESIGN.md §5)

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation). Batch dims shard over (pod,)+data when divisible,
else stay replicated (long_500k's batch=1) and the KV length dim takes the
data sharding instead (decode sequence parallelism).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import build_model
from repro.models import encdec as encdec_mod
from repro.optim import adamw

SHAPES = {
    "train_4k":    dict(kind="train",   seq=4096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524288, batch=1),
}

ENC_LEN = 4096          # encoder memory length for enc-dec decode shapes


def shape_skip_reason(cfg, shape_name: str) -> str | None:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k-token decode KV does not meet the "
                "sub-quadratic requirement (DESIGN.md §5)")
    return None


def _dp(mesh):
    f = shd.fsdp_axes(mesh)
    return f if len(f) > 1 else f[0]


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in shd.fsdp_axes(mesh)]))


def _batch_spec(mesh, b: int, ndim: int):
    dp = _dp(mesh)
    spec = [None] * ndim
    if b % _dp_size(mesh) == 0:
        spec[0] = dp
    return NamedSharding(mesh, P(*spec))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# --------------------------------------------------------------- batch specs
def batch_specs(cfg, shape_name: str, mesh):
    """(ShapeDtypeStruct tree, sharding tree) for the step's batch argument."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    fd = cfg.frontend_dim or cfg.d_model

    if kind in ("train", "prefill"):
        batch, shard = {}, {}
        if cfg.is_encdec:
            batch["embeds"] = _sds((b, s, fd), jnp.bfloat16)
            shard["embeds"] = _batch_spec(mesh, b, 3)
            batch["tokens"] = _sds((b, s), jnp.int32)
            shard["tokens"] = _batch_spec(mesh, b, 2)
        elif cfg.input_mode == "embeddings":
            batch["embeds"] = _sds((b, s, fd), jnp.bfloat16)
            shard["embeds"] = _batch_spec(mesh, b, 3)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
            shard["tokens"] = _batch_spec(mesh, b, 2)
        if kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
            shard["labels"] = _batch_spec(mesh, b, 2)
        return batch, shard

    # decode: one new token against a KV cache of length s
    if cfg.input_mode == "embeddings" and not cfg.is_encdec:
        tok = _sds((b, 1, fd), jnp.bfloat16)
        tok_shard = _batch_spec(mesh, b, 3)
    else:
        tok = _sds((b, 1), jnp.int32)
        tok_shard = _batch_spec(mesh, b, 2)
    pos = _sds((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    return {"tokens": tok, "pos": pos}, {"tokens": tok_shard, "pos": pos_shard}


def cache_specs(cfg, shape_name: str, mesh):
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    model = build_model(cfg)
    if cfg.is_encdec:
        cache = jax.eval_shape(
            lambda: model.init_cache(b, s, enc_len=ENC_LEN))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    shards = _cache_shardings(mesh, cache, b)
    return cache, shards


def _cache_shardings(mesh, cache_tree, batch: int):
    dp = _dp(mesh)
    dps = _dp_size(mesh)
    tp = mesh.shape["model"]

    def one(kp, leaf):
        key = str(kp[-1].key) if hasattr(kp[-1], "key") else ""
        nd = leaf.ndim
        shape = leaf.shape
        spec = [None] * nd

        def put(i, ax, size):
            if spec[i] is None and shape[i] % size == 0 and ax not in spec:
                spec[i] = ax

        if key in ("k", "v", "attn_k", "attn_v", "mem_k", "mem_v") and nd == 5:
            # (L|G, B, S, KV, hd)
            if shape[1] % dps == 0:
                put(1, dp, dps)
            else:
                put(2, dp, dps)          # tiny batch: sequence-parallel cache
            if shape[3] % tp == 0:
                put(3, "model", tp)
            elif shape[4] % tp == 0:
                put(4, "model", tp)      # kv < tp: shard head_dim instead
            else:
                put(2, "model", tp)      # neither divides: KV-length shard
        elif key in ("ckv", "kr") and nd == 4:
            # (L, B, S, lora|rope)
            if shape[1] % dps == 0:
                put(1, dp, dps)
            else:
                put(2, dp, dps)
            put(3, "model", tp)
        elif key == "ssm":
            if nd == 5:                   # (L, B, H, N, P)
                put(1, dp, dps)
                put(2, "model", tp)
            elif nd == 6:                 # (G, per, B, H, N, P)
                put(2, dp, dps)
                put(3, "model", tp)
        elif key == "conv":
            if nd == 4:                   # (L, B, W, C)
                put(1, dp, dps)
                put(3, "model", tp)
            elif nd == 5:                 # (G, per, B, W, C)
                put(2, dp, dps)
                put(4, "model", tp)
        else:
            if nd >= 2 and shape[1] == batch:
                put(1, dp, dps)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------- state specs
def abstract_train_state(cfg, opt_cfg: adamw.AdamWConfig):
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt = jax.eval_shape(functools.partial(adamw.init_state, cfg=opt_cfg),
                         params)
    return {"params": params, "opt": opt}


def train_state_shardings(mesh, state_sds):
    p_sh = shd.param_shardings(mesh, state_sds["params"])
    opt_sh: dict[str, Any] = {}
    for k, v in state_sds["opt"].items():
        if k == "step":
            opt_sh[k] = NamedSharding(mesh, P())
        elif k == "v_scale":
            opt_sh[k] = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), v)
        else:
            opt_sh[k] = shd.param_shardings(mesh, v)
    return {"params": p_sh, "opt": opt_sh}


# ----------------------------------------------------------------- the steps
def make_train_step(cfg, opt_cfg: adamw.AdamWConfig):
    model = build_model(cfg)

    def train_step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt, om = adamw.apply_updates(
            state["params"], grads, state["opt"], opt_cfg)
        out_metrics = {"loss": loss, **metrics, **om}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_prefill_step(cfg):
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits[:, -1]          # next-token logits

    return prefill_step


def make_serve_step(cfg):
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return logits[:, -1], cache

    return serve_step


# ------------------------------------------------------------- cell assembly
def build_cell(cfg, shape_name: str, mesh, *,
               opt_cfg: adamw.AdamWConfig | None = None):
    """Returns (fn, arg_sds tuple, in_shardings tuple, out_shardings)."""
    info = SHAPES[shape_name]
    kind = info["kind"]
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    repl = NamedSharding(mesh, P())

    if kind == "train":
        state_sds = abstract_train_state(cfg, opt_cfg)
        state_sh = train_state_shardings(mesh, state_sds)
        batch_sds, batch_sh = batch_specs(cfg, shape_name, mesh)
        fn = make_train_step(cfg, opt_cfg)
        metrics_sh = {k: repl for k in
                      ("loss", "nll", "aux", "lr", "grad_norm")}
        return (fn, (state_sds, batch_sds), (state_sh, batch_sh),
                (state_sh, metrics_sh))

    if kind == "prefill":
        model = build_model(cfg)
        params_sds = jax.eval_shape(model.init, jax.random.key(0))
        params_sh = shd.param_shardings(mesh, params_sds, mode="serve")
        batch_sds, batch_sh = batch_specs(cfg, shape_name, mesh)
        fn = make_prefill_step(cfg)
        b = info["batch"]
        out_sh = _batch_spec(mesh, b, 2)
        return fn, (params_sds, batch_sds), (params_sh, batch_sh), out_sh

    # decode
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    params_sh = shd.param_shardings(mesh, params_sds, mode="serve")
    cache_sds, cache_sh = cache_specs(cfg, shape_name, mesh)
    io_sds, io_sh = batch_specs(cfg, shape_name, mesh)
    fn = make_serve_step(cfg)
    b = info["batch"]
    logits_sh = _batch_spec(mesh, b, 2)
    return (fn,
            (params_sds, cache_sds, io_sds["tokens"], io_sds["pos"]),
            (params_sh, cache_sh, io_sh["tokens"], io_sh["pos"]),
            (logits_sh, cache_sh))
