"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires config -> model -> sharded train_step -> fault-tolerant Trainer.
On the CPU container run with a reduced config (--reduced) and a tiny mesh;
on a real pod drop --reduced and set --mesh single|multi.
"""
from __future__ import annotations

import argparse
import dataclasses
import signal

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist import context as dist_context
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", choices=("none", "test", "single", "multi"),
                    default="none")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--embed-grad", choices=("segment", "scatter"),
                    default="segment")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, embed_grad=args.embed_grad)

    mesh = None
    state_sh = None
    if args.mesh == "test":
        mesh = make_test_mesh()
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(peak_lr=args.peak_lr, total_steps=args.steps,
                                schedule=cfg.schedule)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.global_batch,
        seq_len=args.seq_len, input_mode=cfg.input_mode,
        frontend_dim=cfg.frontend_dim or cfg.d_model,
        encdec=cfg.is_encdec))

    if mesh is not None:
        dist_context.set_mesh(mesh)
        state_sds = steps_mod.abstract_train_state(cfg, opt_cfg)
        state_sh = steps_mod.train_state_shardings(mesh, state_sds)

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every),
        model, opt_cfg, steps_mod.make_train_step(cfg, opt_cfg), data,
        mesh=mesh, state_shardings=state_sh)
    signal.signal(signal.SIGTERM, trainer.request_stop)
    signal.signal(signal.SIGINT, trainer.request_stop)

    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:>6}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  {h['step_time_s']*1e3:.0f} ms")
    print(f"final step {out['final_step']}  "
          f"stragglers {len(out['stragglers'])}  "
          f"nan-skipped {len(out['nan_skipped'])}")


if __name__ == "__main__":
    main()
