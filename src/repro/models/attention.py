"""Attention: GQA (with optional QKV bias / sliding window) and MLA
(DeepSeek compressed-KV, absorbed decode path).

Train/prefill attention has two implementations:

* ``full``    — materialized (S x S) scores; fine to 8k.
* ``chunked`` — online-softmax over KV chunks via ``lax.scan`` (flash-style at
  the XLA level): O(S x chunk) live memory, required for the 32k prefill
  shapes and the memory-term hillclimb in EXPERIMENTS.md §Perf.

GQA uses grouped einsums (no materialized KV repetition) so HBM traffic
reflects the true KV volume — this matters for the roofline memory term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .modules import linear, linear_init, rmsnorm, rmsnorm_init, Rng, \
    rope_angles, apply_rope

NEG_INF = -1e30


# ----------------------------------------------------------------------- GQA
def gqa_init(rng: Rng, cfg, dtype):
    h, kv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": linear_init(rng, d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": linear_init(rng, d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": linear_init(rng, d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": linear_init(rng, h * hd, d, dtype=dtype,
                          scale=(h * hd) ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _causal_mask(sq, skv, offset, window):
    """(sq, skv) bool mask; offset = absolute position of query row 0."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    m = qi >= kj
    if window is not None:
        m = m & (qi - kj < window)
    return m


def _full_attn(q, k, v, mask):
    """q: (B,Sq,KV,G,hd)  k,v: (B,Skv,KV,hd)  mask: (Sq,Skv) bool."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _attn_constrain(q5, k, v):
    """Shard attention activations (DESIGN.md §4).

    Prefer kv-head sharding over `model`; when the head count does not divide
    the axis (e.g. 36-head minicpm, kv=8 GQA on tp=16), fall back to
    query-sequence sharding (context parallelism): scores shard over Sq, K/V
    replicate across the model axis (one small all-gather per layer instead
    of fully replicated O(S^2) score tensors).
    """
    from repro.dist import context as dist_context
    mesh = dist_context.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q5, k, v
    tp = mesh.shape["model"]
    if q5.shape[2] % tp == 0:
        q5 = dist_context.constrain(q5, "dp", None, "tp", None, None)
        k = dist_context.constrain(k, "dp", None, "tp", None)
        v = dist_context.constrain(v, "dp", None, "tp", None)
    elif q5.shape[1] % tp == 0:
        q5 = dist_context.constrain(q5, "dp", "tp", None, None, None)
        k = dist_context.constrain(k, "dp", None, None, None)
        v = dist_context.constrain(v, "dp", None, None, None)
    return q5, k, v


def _chunked_attn(q, k, v, *, offset, window, chunk: int = 1024,
                  unroll: bool = False, causal: bool = True):
    """Online-softmax attention over KV chunks (flash-style, XLA level).

    q: (B,Sq,KV,G,hd); k,v: (B,Skv,KV,hd). Causal with optional window.
    """
    b, sq, kvh, g, hd = q.shape
    vd = v.shape[-1]                       # may differ from hd (MLA)
    skv = k.shape[1]
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    nchunks = skv // chunk
    scale = hd ** -0.5
    kc = k.reshape(b, nchunks, chunk, kvh, hd)
    vc = v.reshape(b, nchunks, chunk, kvh, vd)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, ci = xs
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q, kb).astype(jnp.float32) * scale
        if causal:
            mask = _causal_mask(sq, chunk, offset - ci * chunk, window)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(scores - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, sq, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nchunks)),
        unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).transpose(0, 3, 1, 2, 4)   # (B,Sq,KV,G,hd)


def gqa_apply(p, cfg, x, *, positions, impl: str = "full", chunk: int = 1024):
    """Training/prefill self-attention. x: (B,S,D); positions: (S,) int32."""
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    b, s, _ = x.shape
    q = _split_heads(linear(p["wq"], x), h, hd)
    k = _split_heads(linear(p["wk"], x), kvh, hd)
    v = _split_heads(linear(p["wv"], x), kvh, hd)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos[None, :, None], sin[None, :, None])
    k = apply_rope(k, cos[None, :, None], sin[None, :, None])
    q = q.reshape(b, s, kvh, g, hd)
    q, k, v = _attn_constrain(q, k, v)
    if impl == "chunked":
        out = _chunked_attn(q, k, v, offset=0, window=cfg.sliding_window,
                            chunk=chunk, unroll=cfg.unroll_layers)
    else:
        mask = _causal_mask(s, s, 0, cfg.sliding_window)
        out = _full_attn(q, k, v, mask)
    out = out.reshape(b, s, h * hd)
    return linear(p["wo"], out)


def gqa_decode(p, cfg, x, cache_k, cache_v, pos):
    """Single-token decode. x: (B,1,D); cache_k/v: (B,S,KV,hd); pos: scalar
    int32 (current length, also the write index). Returns (out, k, v updated).
    """
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    b = x.shape[0]
    s = cache_k.shape[1]
    q = _split_heads(linear(p["wq"], x), h, hd)        # (B,1,H,hd)
    k = _split_heads(linear(p["wk"], x), kvh, hd)
    v = _split_heads(linear(p["wv"], x), kvh, hd)
    cos, sin = rope_angles(jnp.asarray(pos)[None], hd, cfg.rope_theta)
    q = apply_rope(q, cos[None, :, None], sin[None, :, None])
    k = apply_rope(k, cos[None, :, None], sin[None, :, None])
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1)
    q = q.reshape(b, 1, kvh, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, cache_k).astype(jnp.float32)
    scores = scores * scale
    kj = jnp.arange(s)[None, None, None, None, :]
    valid = kj <= pos
    if cfg.sliding_window is not None:
        valid = valid & (pos - kj < cfg.sliding_window)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, cache_v)
    out = out.reshape(b, 1, h * hd)
    return linear(p["wo"], out), cache_k, cache_v


# ----------------------------------------------------------------------- MLA
def mla_init(rng: Rng, cfg, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    qk_nope, qk_rope, vh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    p = {}
    if cfg.q_lora_rank:
        p["wdq"] = linear_init(rng, d, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wuq"] = linear_init(rng, cfg.q_lora_rank, h * (qk_nope + qk_rope),
                               dtype=dtype)
    else:
        p["wq"] = linear_init(rng, d, h * (qk_nope + qk_rope), dtype=dtype)
    p["wdkv"] = linear_init(rng, d, cfg.kv_lora_rank, dtype=dtype)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["wuk"] = linear_init(rng, cfg.kv_lora_rank, h * qk_nope, dtype=dtype)
    p["wuv"] = linear_init(rng, cfg.kv_lora_rank, h * vh, dtype=dtype)
    p["wkr"] = linear_init(rng, d, qk_rope, dtype=dtype)   # shared-head k_rope
    p["wo"] = linear_init(rng, h * vh, d, dtype=dtype,
                          scale=(h * vh) ** -0.5 / (2 * cfg.num_layers) ** 0.5)
    return p


def _mla_q(p, cfg, x):
    h = cfg.num_heads
    qk_nope, qk_rope = cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], linear(p["wdq"], x), cfg.norm_eps)
        q = linear(p["wuq"], cq)
    else:
        q = linear(p["wq"], x)
    q = q.reshape(x.shape[:-1] + (h, qk_nope + qk_rope))
    return q[..., :qk_nope], q[..., qk_nope:]


def mla_apply(p, cfg, x, *, positions, impl: str = "full", chunk: int = 1024):
    """MLA train/prefill: decompress K/V for all positions (non-absorbed)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_nope, qk_rope, vh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x)
    cos, sin = rope_angles(positions, qk_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None, :, None], sin[None, :, None])

    ckv = rmsnorm(p["kv_norm"], linear(p["wdkv"], x), cfg.norm_eps)
    k_nope = linear(p["wuk"], ckv).reshape(b, s, h, qk_nope)
    v = linear(p["wuv"], ckv).reshape(b, s, h, vh)
    k_rope = apply_rope(linear(p["wkr"], x), cos, sin)      # (B,S,rope) shared

    q = jnp.concatenate([q_nope, q_rope], axis=-1)          # (B,S,H,nope+rope)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, qk_rope))],
        axis=-1)
    # MLA is MHA (kv heads == heads): reuse grouped kernels with G=1
    q5 = q.reshape(b, s, h, 1, qk_nope + qk_rope)
    q5, k, v = _attn_constrain(q5, k, v)
    if impl == "chunked":
        out = _chunked_attn(q5, k, v, offset=0, window=None, chunk=chunk,
                            unroll=cfg.unroll_layers)
    else:
        out = _full_attn(q5, k, v, _causal_mask(s, s, 0, None))
    out = out.reshape(b, s, h * vh)
    return linear(p["wo"], out)


def mla_decode(p, cfg, x, cache_ckv, cache_kr, pos):
    """Absorbed-matrices MLA decode (DeepSeek-V2 inference optimization):
    attend directly in the kv_lora latent space; cache is (B,S,kv_lora) +
    (B,S,rope) — 64x smaller than materialized K/V for 128 heads."""
    b = x.shape[0]
    h = cfg.num_heads
    qk_nope, qk_rope, vh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    s = cache_ckv.shape[1]

    q_nope, q_rope = _mla_q(p, cfg, x)                  # (B,1,H,*)
    cos, sin = rope_angles(jnp.asarray(pos)[None], qk_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None, :, None], sin[None, :, None])

    ckv = rmsnorm(p["kv_norm"], linear(p["wdkv"], x), cfg.norm_eps)  # (B,1,lora)
    kr = apply_rope(linear(p["wkr"], x), cos, sin)                    # (B,1,rope)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv.astype(cache_ckv.dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr.astype(cache_kr.dtype), pos, axis=1)

    # absorb W_uk into q: q_eff (B,1,H,lora)
    wuk = p["wuk"]["w"].reshape(lora, h, qk_nope)
    q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scores = (jnp.einsum("bqhl,bsl->bhqs", q_eff,
                         cache_ckv.astype(jnp.float32))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                           cache_kr.astype(jnp.float32)))
    scores = scores * ((qk_nope + qk_rope) ** -0.5)
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bqhl", probs, cache_ckv.astype(jnp.float32))
    wuv = p["wuv"]["w"].reshape(lora, h, vh)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, wuv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, h * vh)
    return linear(p["wo"], out), cache_ckv, cache_kr
