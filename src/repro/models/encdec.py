"""Encoder-decoder stack (seamless-m4t-large-v2 backbone).

Encoder: bidirectional self-attention over precomputed frame embeddings (the
speech frontend is a stub per the assignment). Decoder: causal self-attention
+ cross-attention over encoder memory. Decode caches: self-attn K/V per layer
plus cross-attn K/V precomputed once from the encoder memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from .modules import (Rng, dtype_of, embedding_init, linear, linear_init,
                      rmsnorm, rmsnorm_init)
from .transformer import mlp_init, mlp_apply, _remat
from repro.core.embed_grad import embedding_lookup


def _xattn_init(rng: Rng, cfg, dtype):
    h, kv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {"wq": linear_init(rng, d, h * hd, dtype=dtype),
            "wk": linear_init(rng, d, kv * hd, dtype=dtype),
            "wv": linear_init(rng, d, kv * hd, dtype=dtype),
            "wo": linear_init(rng, h * hd, d, dtype=dtype,
                              scale=(h * hd) ** -0.5 / (2 * cfg.num_layers) ** 0.5)}


def enc_layer_init(rng: Rng, cfg, dtype):
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.gqa_init(rng, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(rng, cfg, dtype, cfg.d_ff)}


def dec_layer_init(rng: Rng, cfg, dtype):
    p = enc_layer_init(rng, cfg, dtype)
    p["ln_x"] = rmsnorm_init(cfg.d_model, dtype)
    p["xattn"] = _xattn_init(rng, cfg, dtype)
    return p


def _bidir_attn(p, cfg, x, positions):
    """Encoder self-attention (no causal mask)."""
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b, s, _ = x.shape
    q = linear(p["wq"], x).reshape(b, s, h, hd)
    k = linear(p["wk"], x).reshape(b, s, kvh, hd)
    v = linear(p["wv"], x).reshape(b, s, kvh, hd)
    cos, sin = attn.rope_angles(positions, hd, cfg.rope_theta)
    q = attn.apply_rope(q, cos[None, :, None], sin[None, :, None])
    k = attn.apply_rope(k, cos[None, :, None], sin[None, :, None])
    q = q.reshape(b, s, kvh, h // kvh, hd)
    q, k, v = attn._attn_constrain(q, k, v)
    if s > 8192:
        out = attn._chunked_attn(q, k, v, offset=0, window=None,
                                 causal=False,
                                 unroll=getattr(cfg, "unroll_layers", False))
    else:
        out = attn._full_attn(q, k, v, jnp.ones((s, s), bool))
    return linear(p["wo"], out.reshape(b, s, h * hd))


def _cross_attn(p, cfg, x, mem_k, mem_v):
    """x: (B,Sq,D); mem_k/v: (B,Skv,KV,hd) precomputed from encoder memory."""
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b, sq, _ = x.shape
    skv = mem_k.shape[1]
    q = linear(p["wq"], x).reshape(b, sq, kvh, h // kvh, hd)
    q, mem_k, mem_v = attn._attn_constrain(q, mem_k, mem_v)
    if max(sq, skv) > 8192 and sq > 1:
        out = attn._chunked_attn(q, mem_k, mem_v, offset=0, window=None,
                                 causal=False,
                                 unroll=getattr(cfg, "unroll_layers", False))
    else:
        out = attn._full_attn(q, mem_k, mem_v, jnp.ones((sq, skv), bool))
    return linear(p["wo"], out.reshape(b, sq, h * hd))


def _mem_kv(p, cfg, memory):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    b, s, _ = memory.shape
    k = linear(p["wk"], memory).reshape(b, s, kvh, hd)
    v = linear(p["wv"], memory).reshape(b, s, kvh, hd)
    return k, v


def init_params(cfg, key):
    dtype = dtype_of(cfg.param_dtype)
    rng = Rng(key)
    fd = cfg.frontend_dim or cfg.d_model
    p = {"embed": embedding_init(rng, cfg.padded_vocab, cfg.d_model, dtype),
         "frontend_proj": linear_init(rng, fd, cfg.d_model, dtype=dtype)}
    ekeys = jax.random.split(rng.next(), cfg.encoder_layers)
    p["enc_layers"] = jax.vmap(
        lambda k: enc_layer_init(Rng(k), cfg, dtype))(ekeys)
    dkeys = jax.random.split(rng.next(), cfg.num_layers)
    p["dec_layers"] = jax.vmap(
        lambda k: dec_layer_init(Rng(k), cfg, dtype))(dkeys)
    p["ln_enc"] = rmsnorm_init(cfg.d_model, dtype)
    p["ln_f"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(rng, cfg.d_model, cfg.padded_vocab, dtype=dtype)
    return p


def encode(params, cfg, embeds):
    """embeds: (B,Senc,Fd) precomputed frame embeddings -> memory (B,Senc,D)."""
    cd = dtype_of(cfg.compute_dtype)
    x = linear(params["frontend_proj"], embeds.astype(cd))
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(h, lp):
        hh = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        h = h + _bidir_attn(lp["attn"], cfg, hh, positions)
        hh = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        return h + mlp_apply(lp["mlp"], cfg, hh), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_layers"], unroll=cfg.unroll_layers)
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def forward(params, cfg, batch, *, impl: str | None = None):
    """batch: {"embeds": (B,Senc,Fd), "tokens": (B,Sdec)}. Teacher-forced."""
    cd = dtype_of(cfg.compute_dtype)
    memory = encode(params, cfg, batch["embeds"])
    x = embedding_lookup(params["embed"]["table"], batch["tokens"],
                         cfg.embed_grad).astype(cd) * (cfg.d_model ** 0.5)
    s = x.shape[1]
    if impl is None:
        impl = "chunked" if s > 8192 else "full"
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(h, lp):
        hh = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        h = h + attn.gqa_apply(lp["attn"], cfg, hh, positions=positions,
                               impl=impl)
        mk, mv = _mem_kv(lp["xattn"], cfg, memory)
        hh = rmsnorm(lp["ln_x"], h, cfg.norm_eps)
        h = h + _cross_attn(lp["xattn"], cfg, hh, mk, mv)
        hh = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        return h + mlp_apply(lp["mlp"], cfg, hh), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_layers"], unroll=cfg.unroll_layers)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["table"].astype(x.dtype))
    else:
        logits = linear(params["lm_head"], x)
    from repro.dist.context import constrain
    logits = constrain(logits.astype(jnp.float32), "dp", None, "tp")
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16):
    L = cfg.num_layers
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((L, batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, kv, hd), dtype),
            "mem_k": jnp.zeros((L, batch, enc_len, kv, hd), dtype),
            "mem_v": jnp.zeros((L, batch, enc_len, kv, hd), dtype)}


def prefill_memory(params, cfg, cache, embeds):
    """Run the encoder once and fill the cross-attn K/V cache."""
    memory = encode(params, cfg, embeds)

    def body(_, lp):
        mk, mv = _mem_kv(lp["xattn"], cfg, memory)
        return None, (mk, mv)

    _, (mk, mv) = jax.lax.scan(body, None, params["dec_layers"])
    cache = dict(cache)
    cache["mem_k"] = mk.astype(cache["mem_k"].dtype)
    cache["mem_v"] = mv.astype(cache["mem_v"].dtype)
    return cache


def decode_step(params, cfg, cache, tokens, pos):
    """tokens: (B,1) int32. Returns (logits, cache)."""
    cd = dtype_of(cfg.compute_dtype)
    x = embedding_lookup(params["embed"]["table"], tokens,
                         cfg.embed_grad).astype(cd) * (cfg.d_model ** 0.5)

    def body(h, xs):
        lp, ck, cv, mk, mv = xs
        hh = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        a, ck, cv = attn.gqa_decode(lp["attn"], cfg, hh, ck, cv, pos)
        h = h + a
        hh = rmsnorm(lp["ln_x"], h, cfg.norm_eps)
        h = h + _cross_attn(lp["xattn"], cfg, hh,
                            mk.astype(cd), mv.astype(cd))
        hh = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        return h + mlp_apply(lp["mlp"], cfg, hh), (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["mem_k"], cache["mem_v"]), unroll=cfg.unroll_layers)
    cache = dict(cache)
    cache["k"], cache["v"] = nk, nv
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["table"].astype(x.dtype))
    else:
        logits = linear(params["lm_head"], x)
    return logits.astype(jnp.float32), cache
