"""Family dispatch: one ``Model`` facade over all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable                 # (key) -> params
    loss: Callable                 # (params, batch) -> (loss, metrics)
    forward: Callable              # (params, batch) -> (logits, aux)
    init_cache: Callable           # (batch, max_len, **kw) -> cache
    decode_step: Callable          # (params, cache, tokens, pos) -> (logits, cache)


def build_model(cfg) -> Model:
    if cfg.is_encdec:
        def loss(params, batch):
            logits, aux = encdec.forward(params, cfg, batch)
            nll = transformer.parallel_cross_entropy(logits, batch["labels"])
            return nll.mean(), {"nll": nll.mean(), "aux": aux}

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            loss=loss,
            forward=lambda params, batch: encdec.forward(params, cfg, batch),
            init_cache=lambda batch, max_len, enc_len=1024, dtype=jnp.bfloat16:
                encdec.init_cache(cfg, batch, max_len, enc_len, dtype),
            decode_step=lambda params, cache, tokens, pos:
                encdec.decode_step(params, cfg, cache, tokens, pos),
        )

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        loss=lambda params, batch: transformer.loss_fn(params, cfg, batch),
        forward=lambda params, batch: transformer.forward(params, cfg, batch),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
            transformer.init_cache(cfg, batch, max_len, dtype),
        decode_step=lambda params, cache, tokens, pos:
            transformer.decode_step(params, cfg, cache, tokens, pos),
    )


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
