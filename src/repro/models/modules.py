"""Minimal pure-JAX module system: param dicts + apply functions.

No flax/haiku dependency (not installed offline). Conventions:

* params are nested dicts of jnp arrays; names are stable and meaningful —
  dist/sharding.py maps (path, shape) -> PartitionSpec from these names.
* init functions take an ``Rng`` helper (deterministic fold_in counter) so the
  same code runs under ``jax.eval_shape`` for the dry-run's allocation-free
  parameter ShapeDtypeStructs.
* compute dtype is applied at use (params stored in param_dtype, matmuls in
  compute_dtype, softmax/norms in fp32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class Rng:
    """Deterministic rng stream: each draw folds a fresh counter into root."""

    def __init__(self, key):
        self.key = key
        self.n = 0

    def next(self):
        self.n += 1
        return jax.random.fold_in(self.key, self.n)


def normal(rng: Rng, shape, dtype, scale: float):
    return (scale * jax.random.truncated_normal(
        rng.next(), -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def linear_init(rng: Rng, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": normal(rng, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    # compute dtype follows the activations (set once at the embedding);
    # params are cast at use so they can be stored in fp32.
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(rng: Rng, vocab: int, d: int, dtype=jnp.float32):
    # d^-0.5 scale keeps tied-head logits O(1) (inputs are re-scaled by
    # sqrt(d) at lookup time).
    return {"table": normal(rng, (vocab, d), dtype, d ** -0.5)}


# ------------------------------------------------------------------- rotary
def rope_angles(positions, dim: int, theta: float):
    """positions: (...,) int32 -> (cos, sin) of shape (..., dim//2), fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) * 2.0 / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., dim); cos/sin broadcastable to (..., dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
