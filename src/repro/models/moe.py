"""Mixture-of-Experts with sort-based, capacity-bounded dispatch.

The dispatch is the same machinery as the paper's conflict resolution
(DESIGN.md §5): tokens are *sorted by expert id* (linearization ordering),
per-expert runs become segments, and each expert processes a fixed-capacity
contiguous slab. FLOPs scale with E x C x d x ff = active-expert FLOPs x
capacity_factor — so the roofline "useful compute" ratio stays honest (a
dense-dispatch einsum would inflate HLO FLOPs by num_experts/top_k).

Experts shard over the ``model`` mesh axis (expert parallelism); the dispatch
gather/scatter lowers to all-to-all-free intra-shard ops because the slab dim
is sharded with the experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .modules import linear_init, Rng


def moe_init(rng: Rng, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    scale_in = d ** -0.5
    scale_out = ff ** -0.5 / (2 * cfg.num_layers) ** 0.5
    p = {
        "router": linear_init(rng, d, e, dtype=dtype),
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "wi": {"w": _expert_w(rng, e, d, ff, dtype, scale_in)},
        "wg": {"w": _expert_w(rng, e, d, ff, dtype, scale_in)},
        "wo": {"w": _expert_w(rng, e, ff, d, dtype, scale_out)},
    }
    if cfg.num_shared_experts:
        sff = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared_wi"] = linear_init(rng, d, sff, dtype=dtype)
        p["shared_wg"] = linear_init(rng, d, sff, dtype=dtype)
        p["shared_wo"] = linear_init(rng, sff, d, dtype=dtype, scale=scale_out)
    return p


def _expert_w(rng: Rng, e, a, b, dtype, scale):
    from .modules import normal
    return normal(rng, (e, a, b), dtype, scale)


def moe_apply(p, cfg, x):
    """x: (B,S,D) -> (B,S,D). Top-k routing, capacity-bounded sort dispatch."""
    compute_dtype = x.dtype
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                  # (T,k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch (the BLCO sort+segment pattern) ----------------
    flat_e = top_e.reshape(-1)                              # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_g = top_g.reshape(-1)
    order = jnp.argsort(flat_e)                             # group by expert
    se, stok, sg = flat_e[order], flat_tok[order], flat_g[order]

    cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
    # position of each routed token within its expert's slab
    ones = jnp.ones_like(se)
    pos_in_e = jnp.cumsum(ones) - 1
    first_of_e = jnp.full((e,), t * k, pos_in_e.dtype).at[se].min(pos_in_e)
    slot = pos_in_e - first_of_e[se]
    keep = slot < cap                                       # overflow drops

    # dispatch: (E, C, D) slabs
    slabs = jnp.zeros((e, cap, d), compute_dtype)
    safe_slot = jnp.where(keep, slot, cap - 1)
    slabs = slabs.at[se, safe_slot].add(
        jnp.where(keep[:, None], xt[stok].astype(compute_dtype), 0))

    # expert FFN (swiglu) on slabs: E x C x d x ff
    wi = p["wi"]["w"].astype(compute_dtype)
    wg = p["wg"]["w"].astype(compute_dtype)
    wo = p["wo"]["w"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", slabs, wg)) * \
        jnp.einsum("ecd,edf->ecf", slabs, wi)
    out_slabs = jnp.einsum("ecf,efd->ecd", h, wo)

    # combine: gather back + gate weight, one scatter-add per routed token
    gathered = out_slabs[se, safe_slot]
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = jnp.zeros((t, d), compute_dtype).at[stok].add(
        gathered * sg[:, None].astype(compute_dtype))

    if cfg.num_shared_experts:
        from .modules import linear
        sh = jax.nn.silu(linear(p["shared_wg"], xt)) * linear(p["shared_wi"], xt)
        combined = combined + linear(p["shared_wo"], sh)

    # router z-loss / aux load-balancing loss (returned for the trainer)
    me = gates.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    aux_loss = e * jnp.sum(me * ce)
    return combined.reshape(b, s, d).astype(x.dtype), aux_loss


# ------------------------------------------------------- SPMD (shard_map) path
def moe_apply_sharded(p, cfg, x, mesh):
    """Expert-parallel MoE for the production mesh (DESIGN.md §4).

    Layout: tokens manual over (pod, data) (batch dim); experts OWNED along
    ``model`` (each model shard holds E/tp experts, full d x ff each — no TP
    inside an expert). Activations entering the block are replicated across
    the model axis (post-TP-all-reduce), so each model shard can locally
    gate + select the tokens routed to *its* experts, run them, and the
    per-token combine is a single psum over ``model`` — no all-to-all at all.
    Expert weights stay ZeRO-sharded over data outside; GSPMD all-gathers
    them at entry (that is the FSDP all-gather, visible in the dry-run).
    """
    from jax.sharding import PartitionSpec as P

    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    tp_size = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.top_k
    assert e % tp_size == 0, (e, tp_size)
    e_local = e // tp_size

    xspec = P(dp, None, None)                  # batch manual over data axes
    wspec_in = P("model", None, None)          # experts owned along model
    wspec_out = P("model", None, None)
    rspec = P()                                # router replicated

    def block(xl, router_w, wi, wg, wo):
        # xl: (B_local, S, D); wi/wg/wo: (E_local, ., .)
        bl, s, d = xl.shape
        t = bl * s
        xt = xl.reshape(t, d)
        my_col = jax.lax.axis_index("model")

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

        cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
        flat_e = top_e.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        flat_g = top_g.reshape(-1)
        # tokens routed to experts owned by this model column
        local_e = flat_e - my_col * e_local
        mine = (local_e >= 0) & (local_e < e_local)
        order = jnp.argsort(jnp.where(mine, local_e, e_local))
        se = jnp.where(mine, local_e, e_local)[order]
        stok = flat_tok[order]
        sg = flat_g[order]
        pos = jnp.cumsum(jnp.ones_like(se)) - 1
        first = jnp.full((e_local + 1,), t * k, pos.dtype).at[se].min(pos)
        slot = pos - first[se]
        keep = (slot < cap) & (se < e_local)
        safe_e = jnp.minimum(se, e_local - 1)
        safe_slot = jnp.where(keep, slot, cap - 1)

        slabs = jnp.zeros((e_local, cap, d), xl.dtype)
        slabs = slabs.at[safe_e, safe_slot].add(
            jnp.where(keep[:, None], xt[stok], 0))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", slabs,
                                   wg.astype(xl.dtype))) * \
            jnp.einsum("ecd,edf->ecf", slabs, wi.astype(xl.dtype))
        out_slabs = jnp.einsum("ecf,efd->ecd", h, wo.astype(xl.dtype))

        gathered = jnp.where(keep[:, None], out_slabs[safe_e, safe_slot], 0)
        combined = jnp.zeros((t, d), xl.dtype).at[stok].add(
            gathered * sg[:, None].astype(xl.dtype))
        combined = jax.lax.psum(combined, "model")   # one collective

        me_ = gates.mean(axis=0)
        ce_ = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
        aux = e * jnp.sum(me_ * ce_)
        aux = jax.lax.pmean(aux, dp)                 # replicate for out_spec
        return combined.reshape(bl, s, d), aux

    from repro.dist.compat import shard_map
    fn = shard_map(
        block, mesh=mesh,
        in_specs=(xspec, rspec, wspec_in, wspec_in, wspec_out),
        out_specs=(xspec, P()),
        check_vma=False)
    out, aux = fn(x, p["router"]["w"], p["wi"]["w"], p["wg"]["w"], p["wo"]["w"])

    if cfg.num_shared_experts:
        from .modules import linear
        sh = jax.nn.silu(linear(p["shared_wg"], x)) * linear(p["shared_wi"], x)
        out = out + linear(p["shared_wo"], sh)
    return out.astype(x.dtype), aux.astype(jnp.float32)
