"""Mamba2 block via the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060], pure JAX.

Training/prefill runs the chunked SSD decomposition: quadratic attention-like
compute *within* chunks (MXU-friendly matmuls) + a linear inter-chunk state
recurrence (lax.scan over n_chunks steps). Decode is the O(1) recurrent state
update. Both paths share parameters; decode state is (conv cache, SSM state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .modules import linear, linear_init, rmsnorm, rmsnorm_init, Rng, normal


def mamba2_init(rng: Rng, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    p = {
        "A_log": jnp.zeros((h,), dtype),          # A = -exp(A_log) in (-1, 0]
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": linear_init(rng, di, d, dtype=dtype,
                                scale=di ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }
    if getattr(cfg, "ssm_split_proj", False):
        # separate projections: every weight TP-shards cleanly, no sliced
        # sharded dims (EXPERIMENTS.md §Perf hillclimb B)
        p.update({
            "z_proj": linear_init(rng, d, di, dtype=dtype),
            "x_proj": linear_init(rng, d, di, dtype=dtype),
            "b_proj": linear_init(rng, d, g * n, dtype=dtype),
            "c_proj": linear_init(rng, d, g * n, dtype=dtype),
            "dt_proj": linear_init(rng, d, h, dtype=dtype),
            "conv_wx": normal(rng, (cfg.conv_width, di), dtype,
                              cfg.conv_width ** -0.5),
            "conv_bx": jnp.zeros((di,), dtype),
            "conv_wb": normal(rng, (cfg.conv_width, g * n), dtype,
                              cfg.conv_width ** -0.5),
            "conv_bb": jnp.zeros((g * n,), dtype),
            "conv_wc": normal(rng, (cfg.conv_width, g * n), dtype,
                              cfg.conv_width ** -0.5),
            "conv_bc": jnp.zeros((g * n,), dtype),
        })
    else:
        # fused in_proj -> [z (di), x (di), B (g*n), C (g*n), dt (h)]
        d_in_proj = 2 * di + 2 * g * n + h
        p.update({
            "in_proj": linear_init(rng, d, d_in_proj, dtype=dtype),
            "conv_w": normal(rng, (cfg.conv_width, di + 2 * g * n), dtype,
                             cfg.conv_width ** -0.5),
            "conv_b": jnp.zeros((di + 2 * g * n,), dtype),
        })
    return p


def _split_proj(cfg, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d. xbc: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _projections(p, cfg, u):
    """(z, x, B, C, dt) with causal conv applied; fused or split weights."""
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    if "in_proj" in p:
        z, xbc, dt = _split_proj(cfg, linear(p["in_proj"], u))
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        return (z, xbc[..., :di], xbc[..., di:di + g * n],
                xbc[..., di + g * n:], dt)
    z = linear(p["z_proj"], u)
    dt = linear(p["dt_proj"], u)
    x = _causal_conv(linear(p["x_proj"], u), p["conv_wx"], p["conv_bx"])
    bm = _causal_conv(linear(p["b_proj"], u), p["conv_wb"], p["conv_bb"])
    cm = _causal_conv(linear(p["c_proj"], u), p["conv_wc"], p["conv_bc"])
    return z, x, bm, cm, dt


def mamba2_apply(p, cfg, u):
    """Train/prefill. u: (B,S,D) -> (B,S,D) via chunked SSD."""
    b, s, _ = u.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    q = min(cfg.ssd_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    z, x, bmat, cmat, dt = _projections(p, cfg, u)
    x = x.reshape(b, s, h, hd)
    bmat = bmat.reshape(b, s, g, n)
    cmat = cmat.reshape(b, s, g, n)
    # broadcast groups to heads
    rep = h // g
    bmat = jnp.repeat(bmat, rep, axis=2)                     # (B,S,H,N)
    cmat = jnp.repeat(cmat, rep, axis=2)
    # SSD head parallelism: shard the head dim over `model` so the O(q^2)
    # intra-chunk tensors shard with it (TPU adaptation; DESIGN.md §4)
    from repro.dist.context import constrain
    x = constrain(x, "dp", None, "tp", None)
    bmat = constrain(bmat, "dp", None, "tp", None)
    cmat = constrain(cmat, "dp", None, "tp", None)
    dt = constrain(dt, "dp", None, "tp")

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    da = dt * a[None, None, :]                                # (B,S,H) decay log

    # chunk reshape; B/C in fp32 by default, bf16 under cfg.ssd_bf16
    ssd_dt = u.dtype if getattr(cfg, "ssd_bf16", False) else jnp.float32
    xq = x.reshape(b, nc, q, h, hd)
    bq = bmat.reshape(b, nc, q, h, n).astype(ssd_dt)
    cq = cmat.reshape(b, nc, q, h, n).astype(ssd_dt)
    dtq = dt.reshape(b, nc, q, h)
    daq = da.reshape(b, nc, q, h)
    da_cs = jnp.cumsum(daq, axis=2)                           # within-chunk cumsum
    da_tot = da_cs[:, :, -1]                                  # (B,nc,H)

    # --- intra-chunk (quadratic within chunk, like masked attention) --------
    # L[b,c,h,i,j] = exp(da_cs_i - da_cs_j) * dt_j   for j <= i
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]  # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cq, bq).astype(jnp.float32) \
        * decay * dtq[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(u.dtype), xq)

    # --- chunk states + inter-chunk recurrence ------------------------------
    # state contribution of chunk c: sum_j exp(da_tot - da_cs_j) dt_j B_j x_j
    w = jnp.exp(da_tot[:, :, None, :] - da_cs) * dtq          # (B,nc,q,H)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp",
                        bq.astype(jnp.float32), w,
                        xq.astype(jnp.float32))               # (B,nc,H,N,P)

    def scan_fn(s_prev, xs):
        st, tot = xs                                          # (B,H,N,P),(B,H)
        s_new = s_prev * jnp.exp(tot)[..., None, None] + st
        return s_new, s_prev                                  # emit state BEFORE chunk

    s0 = jnp.zeros((b, h, n, hd), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, s0, (states.swapaxes(0, 1), da_tot.swapaxes(0, 1)),
        unroll=getattr(cfg, "unroll_layers", False))
    prev_states = prev_states.swapaxes(0, 1)                  # (B,nc,H,N,P)

    # y_inter[i] = C_i . S_prev * exp(da_cs_i)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         cq.astype(jnp.float32) * jnp.exp(da_cs)[..., None],
                         prev_states).astype(u.dtype)

    y = (y_intra + y_inter).reshape(b, s, h, hd)
    y = y + x * p["D"].astype(u.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                cfg.norm_eps)
    return linear(p["out_proj"], y)


def mamba2_decode_init(cfg, batch, dtype=jnp.float32):
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, hd = cfg.ssm_heads, cfg.ssm_head_dim
    conv_c = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_c), dtype),
        "ssm": jnp.zeros((batch, h, n, hd), jnp.float32),
    }


def mamba2_decode(p, cfg, u, state):
    """One-token decode. u: (B,1,D); state: dict(conv, ssm). O(1) per token."""
    b = u.shape[0]
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim

    if "in_proj" in p:
        zxbcdt = linear(p["in_proj"], u)
        conv_w_full, conv_b_full = p["conv_w"], p["conv_b"]
    else:   # split projections: materialize the fused layout for the cache
        zxbcdt = jnp.concatenate(
            [linear(p["z_proj"], u), linear(p["x_proj"], u),
             linear(p["b_proj"], u), linear(p["c_proj"], u),
             linear(p["dt_proj"], u)], axis=-1)
        conv_w_full = jnp.concatenate(
            [p["conv_wx"], p["conv_wb"], p["conv_wc"]], axis=1)
        conv_b_full = jnp.concatenate(
            [p["conv_bx"], p["conv_bb"], p["conv_bc"]])
    z, xbc, dt = _split_proj(cfg, zxbcdt)                     # (B,1,*)
    # rolling conv cache
    conv_in = jnp.concatenate([state["conv"],
                               xbc.astype(state["conv"].dtype)], axis=1)
    new_conv = conv_in[:, 1:]
    w = conv_w_full.astype(jnp.float32)
    acc = (conv_in.astype(jnp.float32) * w[None]).sum(axis=1) \
        + conv_b_full.astype(jnp.float32)
    xbc1 = jax.nn.silu(acc).astype(u.dtype)                    # (B,C)

    x = xbc1[:, :di].reshape(b, h, hd)
    bvec = xbc1[:, di:di + g * n].reshape(b, g, n)
    cvec = xbc1[:, di + g * n:].reshape(b, g, n)
    rep = h // g
    bvec = jnp.repeat(bvec, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    cvec = jnp.repeat(cvec, rep, axis=1).astype(jnp.float32)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a[None])                             # (B,H)

    ssm = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", bvec, dt1, x.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", cvec, ssm).astype(u.dtype)
    y = y + x * p["D"].astype(u.dtype)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rmsnorm(p["norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                cfg.norm_eps)
    return linear(p["out_proj"], y), {"conv": new_conv, "ssm": ssm}
