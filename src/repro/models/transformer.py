"""Decoder-LM spine for dense / MoE / SSM / hybrid families.

Layers run under ``lax.scan`` over stacked parameters with ``jax.checkpoint``
(remat) around the body, so the lowered HLO is O(1) in depth — essential both
for 512-device dry-run compiles and for real-TPU compile times at 40-60 layers.

Families:
  dense  — [attn, mlp] x L                     (stablelm, qwen, minicpm, danube,
                                                internvl2 backbone)
  moe    — [attn, moe] x L (+ leading dense)   (dbrx, deepseek-v2/MLA)
  ssm    — [mamba2] x L                        (mamba2-370m)
  hybrid — mamba2 spine + one SHARED attention block applied every k layers
           with per-site LoRA                  (zamba2)

The token embedding uses ``repro.core.embedding_lookup`` — the paper's
sort+segment conflict resolution on the embedding-gradient MTTKRP
(cfg.embed_grad selects it; DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.embed_grad import embedding_lookup

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .modules import (Rng, dtype_of, embedding_init, linear, linear_init,
                      normal, rmsnorm, rmsnorm_init)


# ------------------------------------------------------------------------ MLP
def mlp_init(rng: Rng, cfg, dtype, d_ff: int):
    d = cfg.d_model
    scale_out = d_ff ** -0.5 / (2 * max(1, cfg.num_layers)) ** 0.5
    if cfg.mlp_type == "swiglu":
        return {"wi": linear_init(rng, d, d_ff, dtype=dtype),
                "wg": linear_init(rng, d, d_ff, dtype=dtype),
                "wo": linear_init(rng, d_ff, d, dtype=dtype, scale=scale_out)}
    return {"wi": linear_init(rng, d, d_ff, dtype=dtype),
            "wo": linear_init(rng, d_ff, d, dtype=dtype, scale=scale_out)}


def mlp_apply(p, cfg, x):
    from repro.dist.context import constrain
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x)
    else:
        h = jax.nn.gelu(linear(p["wi"], x))
    h = constrain(h, "dp", None, "tp")     # ff-sharded hidden anchor
    return linear(p["wo"], h)


# ------------------------------------------------------------------- layers
def _attn_init(rng, cfg, dtype):
    return attn.mla_init(rng, cfg, dtype) if cfg.attention == "mla" \
        else attn.gqa_init(rng, cfg, dtype)


def dense_layer_init(rng: Rng, cfg, dtype, *, use_moe: bool):
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype),
         "attn": _attn_init(rng, cfg, dtype),
         "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if use_moe:
        p["moe"] = moe_mod.moe_init(rng, cfg, dtype)
    else:
        p["mlp"] = mlp_init(rng, cfg, dtype, cfg.d_ff)
    return p


def dense_layer_apply(p, cfg, x, positions, *, impl):
    from repro.dist.context import constrain
    x = constrain(x, "dp", None, None)     # residual-stream anchor
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        h = attn.mla_apply(p["attn"], cfg, h, positions=positions, impl=impl)
    else:
        h = attn.gqa_apply(p["attn"], cfg, h, positions=positions, impl=impl)
    x = x + h
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        from repro.dist import context as dist_context
        mesh = dist_context.get_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            h, aux = moe_mod.moe_apply_sharded(p["moe"], cfg, h, mesh)
        else:
            h, aux = moe_mod.moe_apply(p["moe"], cfg, h)
    else:
        h, aux = mlp_apply(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    return x + h, aux


def ssm_layer_init(rng: Rng, cfg, dtype):
    return {"ln": rmsnorm_init(cfg.d_model, dtype),
            "mamba": ssm_mod.mamba2_init(rng, cfg, dtype)}


def ssm_layer_apply(p, cfg, x):
    return x + ssm_mod.mamba2_apply(p["mamba"], cfg,
                                    rmsnorm(p["ln"], x, cfg.norm_eps))


# ------------------------------------------------------ hybrid (zamba2-like)
def _lora_init(rng: Rng, d_in, d_out, rank, dtype):
    return {"a": normal(rng, (d_in, rank), dtype, d_in ** -0.5),
            "b": jnp.zeros((rank, d_out), dtype)}


def _lora_apply(p, x):
    return jnp.einsum("...r,rf->...f",
                      jnp.einsum("...d,dr->...r", x, p["a"].astype(x.dtype)),
                      p["b"].astype(x.dtype))


def shared_attn_init(rng: Rng, cfg, dtype):
    """The one shared transformer block of zamba2 (attn + mlp)."""
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.gqa_init(rng, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(rng, cfg, dtype, cfg.d_ff)}


def site_lora_init(rng: Rng, cfg, dtype):
    h, kv, hd, d, r = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                       cfg.d_model, cfg.shared_attn_lora_rank)
    return {"q": _lora_init(rng, d, h * hd, r, dtype),
            "k": _lora_init(rng, d, kv * hd, r, dtype),
            "v": _lora_init(rng, d, kv * hd, r, dtype)}


def shared_attn_apply(shared, lora, cfg, x, positions, *, impl):
    """Shared block with per-site LoRA deltas on q/k/v projections."""
    h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
    p = shared["attn"]
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b, s, _ = h.shape
    q = (linear(p["wq"], h) + _lora_apply(lora["q"], h)).reshape(b, s, nh, hd)
    k = (linear(p["wk"], h) + _lora_apply(lora["k"], h)).reshape(b, s, kvh, hd)
    v = (linear(p["wv"], h) + _lora_apply(lora["v"], h)).reshape(b, s, kvh, hd)
    cos, sin = attn.rope_angles(positions, hd, cfg.rope_theta)
    q = attn.apply_rope(q, cos[None, :, None], sin[None, :, None])
    k = attn.apply_rope(k, cos[None, :, None], sin[None, :, None])
    q = q.reshape(b, s, kvh, nh // kvh, hd)
    q, k, v = attn._attn_constrain(q, k, v)
    if impl == "chunked":
        out = attn._chunked_attn(q, k, v, offset=0, window=None, unroll=cfg.unroll_layers)
    else:
        out = attn._full_attn(q, k, v, attn._causal_mask(s, s, 0, None))
    x = x + linear(p["wo"], out.reshape(b, s, nh * hd))
    h2 = rmsnorm(shared["ln2"], x, cfg.norm_eps)
    return x + mlp_apply(shared["mlp"], cfg, h2)


# ----------------------------------------------------------------- the model
def hybrid_group_counts(cfg) -> tuple[int, int, int]:
    """(pre_layers, groups, layers_per_group) covering cfg.num_layers."""
    k = cfg.shared_attn_every
    groups = cfg.num_layers // k
    pre = cfg.num_layers - groups * k
    return pre, groups, k


def init_params(cfg, key):
    """Full parameter pytree (run under jax.eval_shape for the dry-run)."""
    dtype = dtype_of(cfg.param_dtype)
    rng = Rng(key)
    p: dict[str, Any] = {"embed": embedding_init(rng, cfg.padded_vocab,
                                                 cfg.d_model, dtype)}
    if cfg.input_mode == "embeddings":
        fd = cfg.frontend_dim or cfg.d_model
        p["frontend_proj"] = linear_init(rng, fd, cfg.d_model, dtype=dtype)

    if cfg.family in ("dense", "moe", "vlm"):
        n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.moe else 0
        n_dense = cfg.num_layers - n_moe
        if n_dense:
            keys = jax.random.split(rng.next(), n_dense)
            p["dense_layers"] = jax.vmap(
                lambda k: dense_layer_init(Rng(k), cfg, dtype, use_moe=False)
            )(keys)
        if n_moe:
            keys = jax.random.split(rng.next(), n_moe)
            p["moe_layers"] = jax.vmap(
                lambda k: dense_layer_init(Rng(k), cfg, dtype, use_moe=True)
            )(keys)
    elif cfg.family == "ssm":
        keys = jax.random.split(rng.next(), cfg.num_layers)
        p["ssm_layers"] = jax.vmap(
            lambda k: ssm_layer_init(Rng(k), cfg, dtype))(keys)
    elif cfg.family == "hybrid":
        pre, groups, per = hybrid_group_counts(cfg)
        if pre:
            keys = jax.random.split(rng.next(), pre)
            p["pre_layers"] = jax.vmap(
                lambda k: ssm_layer_init(Rng(k), cfg, dtype))(keys)
        gkeys = jax.random.split(rng.next(), groups * per).reshape(groups, per)
        p["group_layers"] = jax.vmap(jax.vmap(
            lambda k: ssm_layer_init(Rng(k), cfg, dtype)))(gkeys)
        p["shared_attn"] = shared_attn_init(rng, cfg, dtype)
        lkeys = jax.random.split(rng.next(), groups)
        p["site_lora"] = jax.vmap(
            lambda k: site_lora_init(Rng(k), cfg, dtype))(lkeys)
    else:
        raise ValueError(cfg.family)

    p["ln_f"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(rng, cfg.d_model, cfg.padded_vocab,
                                   dtype=dtype)
    return p


def _remat(fn, cfg):
    policy = {"nothing_saveable": jax.checkpoint_policies.nothing_saveable,
              "dots_saveable": jax.checkpoint_policies.dots_saveable,
              "dots_with_no_batch_dims_saveable":
                  jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
              }[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy)


def _embed_in(p, cfg, batch, compute_dtype):
    if cfg.input_mode == "embeddings":
        x = linear(p["frontend_proj"], batch["embeds"].astype(compute_dtype))
    else:
        x = embedding_lookup(p["embed"]["table"], batch["tokens"],
                             cfg.embed_grad).astype(compute_dtype)
        x = x * (cfg.d_model ** 0.5)
    return x


def forward(params, cfg, batch, *, impl: str | None = None):
    """batch: {"tokens": (B,S)} or {"embeds": (B,S,Fd)}. Returns (logits, aux)."""
    cd = dtype_of(cfg.compute_dtype)
    x = _embed_in(params, cfg, batch, cd)
    s = x.shape[1]
    if impl is None:
        impl = "chunked" if s > 8192 else "full"
    positions = jnp.arange(s, dtype=jnp.int32)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, lp):
            h, aux = dense_layer_apply(lp, cfg, h, positions, impl=impl)
            return h, aux
        if "dense_layers" in params:
            x, aux = jax.lax.scan(_remat(body, cfg), x, params["dense_layers"], unroll=cfg.unroll_layers)
            aux_total += aux.sum()
        if "moe_layers" in params:
            x, aux = jax.lax.scan(_remat(body, cfg), x, params["moe_layers"], unroll=cfg.unroll_layers)
            aux_total += aux.sum()
    elif cfg.family == "ssm":
        def body(h, lp):
            return ssm_layer_apply(lp, cfg, h), None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["ssm_layers"], unroll=cfg.unroll_layers)
    elif cfg.family == "hybrid":
        def body(h, lp):
            return ssm_layer_apply(lp, cfg, h), None
        if "pre_layers" in params:
            x, _ = jax.lax.scan(_remat(body, cfg), x, params["pre_layers"], unroll=cfg.unroll_layers)

        def group_body(h, xs):
            glayers, lora = xs
            h, _ = jax.lax.scan(_remat(body, cfg), h, glayers, unroll=cfg.unroll_layers)
            h = shared_attn_apply(params["shared_attn"], lora, cfg, h,
                                  positions, impl=impl)
            return h, None
        x, _ = jax.lax.scan(_remat(group_body, cfg), x,
                            (params["group_layers"], params["site_lora"]),
                            unroll=cfg.unroll_layers)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["table"].astype(x.dtype))
    else:
        logits = linear(params["lm_head"], x)
    logits = logits.astype(jnp.float32)
    from repro.dist.context import constrain
    logits = constrain(logits, "dp", None, "tp")   # keep vocab sharded
    return logits, aux_total


def parallel_cross_entropy(logits, labels):
    """CE over a vocab-SHARDED logits tensor (Megatron-style parallel CE).

    No take_along_axis: a gather over the sharded vocab dim forces a
    full-logits all-gather (26 GB/device fp32 at train_4k shapes — see
    EXPERIMENTS.md §Perf iteration B1). With reductions only, every vocab
    contraction stays local + one tiny (B,S) all-reduce from GSPMD.
    """
    from repro.dist.context import constrain
    lse = jax.nn.logsumexp(logits, axis=-1)                     # (B,S)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    vocab_iota = constrain(vocab_iota, "dp", None, "tp")        # shard w/ logits
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                     axis=-1)                                    # (B,S)
    return lse - picked


def loss_fn(params, cfg, batch):
    """Next-token CE + MoE aux loss. batch needs "labels": (B,S) int32."""
    logits, aux = forward(params, cfg, batch)
    nll = parallel_cross_entropy(logits, batch["labels"])
    loss = nll.mean() + 0.01 * aux
    return loss, {"nll": nll.mean(), "aux": aux}


# ------------------------------------------------------------------- decode
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode cache pytree (abstract-able with jax.eval_shape)."""
    if cfg.family in ("dense", "moe", "vlm"):
        L = cfg.num_layers
        if cfg.attention == "mla":
            return {"ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
                    "kr": jnp.zeros((L, batch, max_len, cfg.rope_head_dim), dtype)}
        return {"k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype)}
    if cfg.family == "ssm":
        st = ssm_mod.mamba2_decode_init(cfg, batch)
        return {"ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), st)}
    if cfg.family == "hybrid":
        pre, groups, per = hybrid_group_counts(cfg)
        st = ssm_mod.mamba2_decode_init(cfg, batch)
        cache = {
            "pre": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (pre,) + a.shape), st) if pre else {},
            "groups": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (groups, per) + a.shape), st),
            "attn_k": jnp.zeros((groups, batch, max_len, cfg.num_kv_heads,
                                 cfg.head_dim), dtype),
            "attn_v": jnp.zeros((groups, batch, max_len, cfg.num_kv_heads,
                                 cfg.head_dim), dtype),
        }
        return cache
    raise ValueError(cfg.family)


def decode_step(params, cfg, cache, tokens, pos):
    """One decode step. tokens: (B,1) int32 (or embeds (B,1,Fd)); pos: scalar
    int32 current position. Returns (logits (B,1,V), new cache)."""
    cd = dtype_of(cfg.compute_dtype)
    batch = {"tokens": tokens} if cfg.input_mode == "tokens" \
        else {"embeds": tokens}
    x = _embed_in(params, cfg, batch, cd)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, xs):
            if cfg.attention == "mla":
                lp, ckv, kr = xs
                hh = rmsnorm(lp["ln1"], h, cfg.norm_eps)
                a, ckv, kr = attn.mla_decode(lp["attn"], cfg, hh, ckv, kr, pos)
                new = (ckv, kr)
            else:
                lp, ck, cv = xs
                hh = rmsnorm(lp["ln1"], h, cfg.norm_eps)
                a, ck, cv = attn.gqa_decode(lp["attn"], cfg, hh, ck, cv, pos)
                new = (ck, cv)
            h = h + a
            hh = rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if "moe" in lp:
                from repro.dist import context as dist_context
                mesh = dist_context.get_mesh()
                if mesh is not None and "model" in mesh.axis_names:
                    m, _ = moe_mod.moe_apply_sharded(lp["moe"], cfg, hh, mesh)
                else:
                    m, _ = moe_mod.moe_apply(lp["moe"], cfg, hh)
            else:
                m = mlp_apply(lp["mlp"], cfg, hh)
            return h + m, new

        new_cache = dict(cache)
        off = 0
        for group in ("dense_layers", "moe_layers"):
            if group not in params:
                continue
            n = jax.tree.leaves(params[group])[0].shape[0]
            if cfg.attention == "mla":
                xs = (params[group], cache["ckv"][off:off + n],
                      cache["kr"][off:off + n])
            else:
                xs = (params[group], cache["k"][off:off + n],
                      cache["v"][off:off + n])
            x, ys = jax.lax.scan(body, x, xs, unroll=cfg.unroll_layers)
            if cfg.attention == "mla":
                new_cache["ckv"] = new_cache["ckv"].at[off:off + n].set(ys[0])
                new_cache["kr"] = new_cache["kr"].at[off:off + n].set(ys[1])
            else:
                new_cache["k"] = new_cache["k"].at[off:off + n].set(ys[0])
                new_cache["v"] = new_cache["v"].at[off:off + n].set(ys[1])
            off += n
        cache = new_cache

    elif cfg.family == "ssm":
        def body(h, xs):
            lp, st = xs
            y, st = ssm_mod.mamba2_decode(
                lp["mamba"], cfg, rmsnorm(lp["ln"], h, cfg.norm_eps), st)
            return h + y, st
        x, new_st = jax.lax.scan(body, x, (params["ssm_layers"], cache["ssm"]), unroll=cfg.unroll_layers)
        cache = {"ssm": new_st}

    elif cfg.family == "hybrid":
        def body(h, xs):
            lp, st = xs
            y, st = ssm_mod.mamba2_decode(
                lp["mamba"], cfg, rmsnorm(lp["ln"], h, cfg.norm_eps), st)
            return h + y, st

        new_cache = dict(cache)
        if "pre_layers" in params:
            x, st = jax.lax.scan(body, x, (params["pre_layers"], cache["pre"]), unroll=cfg.unroll_layers)
            new_cache["pre"] = st

        def group_body(h, xs):
            glayers, lora, gst, ck, cv = xs
            h, gst = jax.lax.scan(body, h, (glayers, gst), unroll=cfg.unroll_layers)
            hh = rmsnorm(params["shared_attn"]["ln1"], h, cfg.norm_eps)
            sp = dict(params["shared_attn"]["attn"])
            # fold LoRA deltas into the shared projections for this site
            b_, _, _ = hh.shape
            nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            q = (linear(sp["wq"], hh) + _lora_apply(lora["q"], hh))
            k = (linear(sp["wk"], hh) + _lora_apply(lora["k"], hh))
            v = (linear(sp["wv"], hh) + _lora_apply(lora["v"], hh))
            q = q.reshape(b_, 1, nh, hd)
            k = k.reshape(b_, 1, kvh, hd)
            v = v.reshape(b_, 1, kvh, hd)
            cos, sin = attn.rope_angles(jnp.asarray(pos)[None], hd,
                                        cfg.rope_theta)
            q = attn.apply_rope(q, cos[None, :, None], sin[None, :, None])
            k = attn.apply_rope(k, cos[None, :, None], sin[None, :, None])
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                     pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                     pos, axis=1)
            s_ = ck.shape[1]
            q5 = q.reshape(b_, 1, kvh, nh // kvh, hd)
            scores = jnp.einsum("bqkgh,bskh->bkgqs", q5, ck).astype(jnp.float32)
            scores = scores * (hd ** -0.5)
            valid = jnp.arange(s_)[None, None, None, None, :] <= pos
            scores = jnp.where(valid, scores, attn.NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
            o = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv).reshape(b_, 1, nh * hd)
            h = h + linear(sp["wo"], o)
            hh = rmsnorm(params["shared_attn"]["ln2"], h, cfg.norm_eps)
            h = h + mlp_apply(params["shared_attn"]["mlp"], cfg, hh)
            return h, (gst, ck, cv)

        x, ys = jax.lax.scan(group_body, x,
                             (params["group_layers"], params["site_lora"],
                              cache["groups"], cache["attn_k"],
                              cache["attn_v"]), unroll=cfg.unroll_layers)
        new_cache["groups"], new_cache["attn_k"], new_cache["attn_v"] = ys
        cache = new_cache
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["table"].astype(x.dtype))
    else:
        logits = linear(params["lm_head"], x)
    return logits.astype(jnp.float32), cache
