"""Observability: spans, histograms, bandwidth ledger, roofline, SLOs.

The cross-cutting layer every perf claim in this repo is measured
through.  Six small modules, zero hard dependencies beyond the stdlib:

    trace    context-manager spans + already-measured events into a
             thread-safe bounded ring buffer; zero-cost when disabled
             (one module-level flag check, no allocation)
    hist     fixed log2-bucket histograms (dispatch latency, H2D chunk
             time, disk read time, queue wait, per-launch nnz) threaded
             through ``EngineStats`` / ``JobMetrics`` / ``ServiceMetrics``;
             scheduler latencies additionally keyed per tenant
    ledger   memory-hierarchy bandwidth accounting: (bytes, seconds,
             ops, flops) per tier edge (disk->host, host->device,
             device HBM), per regime, and per (tenant, job); exact
             conservation against ``EngineStats`` by construction
    roofline achieved GB/s per edge + arithmetic intensity and
             memory/compute-bound classification per regime, from the
             ledger (``GetRoofline``, BENCH_7, ``scripts/obs_report.py``)
    slo      per-tenant latency objectives + burn rates over the
             scheduler hists, and the background ``TelemetryExporter``
             (JSONL / Prometheus-textfile push at an interval)
    export   Chrome trace-event JSON (one track per pipeline stage —
             load it in Perfetto to *see* H2D/compute overlap) and
             Prometheus text exposition (``render_prometheus``)

Quick use::

    from repro import obs
    obs.enable()                       # or: with obs.trace.enabled(): ...
    obs.ledger.enable()
    ... run a plan / service ...
    obs.write_chrome_trace("trace.json")
    print(obs.render_prometheus(service.metrics))
    report = obs.roofline_report()     # achieved GB/s per tier edge
"""
from . import ledger, roofline, slo, trace
from .export import (chrome_trace, render_prometheus, track_totals,
                     write_chrome_trace)
from .hist import EngineHists, Hist, ServiceHists, TenantHists
from .ledger import (DEVICE_HBM, DISK_HOST, EDGES, HOST_DEVICE, LEDGER,
                     hbm_model_bytes, job_scope, mttkrp_flops,
                     verify_conservation)
from .roofline import roofline_report
from .slo import DEFAULT_SLOS, SLO, TelemetryExporter, slo_report
from .trace import (TRACING, add_event, clear, disable, drain, enable,
                    is_enabled, span, spans)

__all__ = [
    "trace", "TRACING", "span", "add_event", "enable", "disable",
    "is_enabled", "clear", "spans", "drain",
    "Hist", "EngineHists", "ServiceHists", "TenantHists",
    "chrome_trace", "write_chrome_trace", "track_totals",
    "render_prometheus",
    "ledger", "LEDGER", "EDGES", "DISK_HOST", "HOST_DEVICE", "DEVICE_HBM",
    "job_scope", "hbm_model_bytes", "mttkrp_flops", "verify_conservation",
    "roofline", "roofline_report",
    "slo", "SLO", "DEFAULT_SLOS", "slo_report", "TelemetryExporter",
]
