"""Observability: span tracing, log2 histograms, Perfetto/Prometheus export.

The cross-cutting layer every perf claim in this repo is measured
through.  Three small modules, zero hard dependencies beyond the stdlib:

    trace    context-manager spans + already-measured events into a
             thread-safe bounded ring buffer; zero-cost when disabled
             (one module-level flag check, no allocation)
    hist     fixed log2-bucket histograms (dispatch latency, H2D chunk
             time, disk read time, queue wait, per-launch nnz) threaded
             through ``EngineStats`` / ``JobMetrics`` / ``ServiceMetrics``
    export   Chrome trace-event JSON (one track per pipeline stage —
             load it in Perfetto to *see* H2D/compute overlap) and
             Prometheus text exposition (``render_prometheus``)

Quick use::

    from repro import obs
    obs.enable()                       # or: with obs.trace.enabled(): ...
    ... run a plan / service ...
    obs.write_chrome_trace("trace.json")
    print(obs.render_prometheus(service.metrics))
"""
from . import trace
from .export import (chrome_trace, render_prometheus, track_totals,
                     write_chrome_trace)
from .hist import EngineHists, Hist, ServiceHists
from .trace import (TRACING, add_event, clear, disable, drain, enable,
                    is_enabled, span, spans)

__all__ = [
    "trace", "TRACING", "span", "add_event", "enable", "disable",
    "is_enabled", "clear", "spans", "drain",
    "Hist", "EngineHists", "ServiceHists",
    "chrome_trace", "write_chrome_trace", "track_totals",
    "render_prometheus",
]
