"""Exporters: Chrome trace-event JSON (Perfetto) + Prometheus text.

* :func:`chrome_trace` turns recorded spans into the Chrome trace-event
  format (https://ui.perfetto.dev loads it directly).  Each pipeline
  stage gets its own named track (``store`` / ``h2d`` / ``dispatch`` /
  ``device`` / ``scheduler`` / ...), so H2D-vs-compute overlap in the
  streamed regimes is visually inspectable: a healthy pipeline shows the
  ``h2d`` track's puts running *under* the ``device`` track's fenced
  span, a serialized one shows them alternating.

* :func:`render_prometheus` renders a ``ServiceMetrics`` in the
  Prometheus text exposition format (v0.0.4): counters and gauges become
  ``repro_*`` samples, per-tenant iteration counts become a labelled
  counter, and every :class:`~repro.obs.hist.Hist` becomes a native
  Prometheus histogram (cumulative ``_bucket{le=...}`` + ``_sum`` +
  ``_count``) — scrapeable by an off-the-shelf Prometheus without any
  adapter.
"""
from __future__ import annotations

import json
import math

from . import ledger as _ledger
from . import trace as _trace

# Stable track ordering for the Perfetto view: pipeline order, top-down.
_TRACK_ORDER = ("scheduler", "plan", "store", "h2d", "dispatch", "device",
                "registry", "main")


def _json_safe(v):
    if isinstance(v, (bool, int, str)) or v is None:
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else repr(v)
    return repr(v)


def chrome_trace(spans=None) -> dict:
    """Chrome trace-event JSON dict of ``spans`` (default: the ring buffer).

    One track (= trace "thread") per pipeline stage, named via metadata
    events; spans become complete ("X") events with microsecond
    timestamps relative to the tracer epoch and their attributes under
    ``args``.
    """
    if spans is None:
        spans = _trace.spans()
    epoch = _trace.TRACING.epoch_s
    tracks: dict[str, int] = {}

    def tid(track: str) -> int:
        if track not in tracks:
            # deterministic ids: known pipeline stages first, then arrival
            if track in _TRACK_ORDER:
                tracks[track] = _TRACK_ORDER.index(track) + 1
            else:
                tracks[track] = len(_TRACK_ORDER) + 1 + len(
                    [t for t in tracks if t not in _TRACK_ORDER])
        return tracks[track]

    events = []
    for s in spans:
        ev = {
            "ph": "X",
            "name": s.name,
            "cat": s.track,
            "pid": 1,
            "tid": tid(s.track),
            "ts": (s.start_s - epoch) * 1e6,
            "dur": s.duration_s * 1e6,
        }
        args = {k: _json_safe(v) for k, v in s.attrs.items()}
        if s.parent is not None:
            args["parent"] = s.parent
        if args:
            ev["args"] = args
        events.append(ev)

    meta = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "repro-blco"}}]
    for track, t in sorted(tracks.items(), key=lambda kv: kv[1]):
        meta.append({"ph": "M", "pid": 1, "tid": t, "name": "thread_name",
                     "args": {"name": track}})
        meta.append({"ph": "M", "pid": 1, "tid": t,
                     "name": "thread_sort_index",
                     "args": {"sort_index": t}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": _trace.TRACING.dropped}}


def write_chrome_trace(path: str, spans=None) -> dict:
    """Write :func:`chrome_trace` to ``path``; returns the trace dict."""
    doc = chrome_trace(spans)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


def track_totals(spans=None) -> dict:
    """Summed span duration (seconds) per track — the cross-check against
    ``EngineStats``/histogram totals (span sums must agree with the stats
    the same timestamps fed)."""
    if spans is None:
        spans = _trace.spans()
    totals: dict[str, float] = {}
    for s in spans:
        totals[s.track] = totals.get(s.track, 0.0) + s.duration_s
    return totals


# ----------------------------------------------------------------- prometheus
def _prom_num(v) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if isinstance(v, float) else str(int(v))


def _prom_hist_samples(name: str, hist, out: list,
                       labels: str = "") -> None:
    """Bucket/sum/count sample lines only (HELP/TYPE emitted by caller —
    labelled series share one metadata block per metric name)."""
    sep = f"{labels}," if labels else ""
    for le, cum in hist.cumulative():
        out.append(f'{name}_bucket{{{sep}le="{_prom_num(le)}"}} {cum}')
    suffix = f"{{{labels}}}" if labels else ""
    out.append(f"{name}_sum{suffix} {_prom_num(hist.sum)}")
    out.append(f"{name}_count{suffix} {hist.count}")


def _prom_hist(name: str, hist, help_text: str, out: list) -> None:
    out.append(f"# HELP {name} {help_text}")
    out.append(f"# TYPE {name} histogram")
    _prom_hist_samples(name, hist, out)


_COUNTER_KEYS = (
    "jobs_submitted", "jobs_admitted", "jobs_completed", "jobs_failed",
    "jobs_cancelled", "preemptions", "cancel_freed_bytes_total",
    "blco_cache_hits", "blco_cache_misses", "blco_disk_hits",
    "spills", "spill_bytes_total", "loads", "store_rebuilds",
    "jobs_restored", "retries_total", "giveups_total", "demotions_total",
    "watchdog_restarts", "iterations_total", "h2d_bytes_total",
    "disk_bytes_total", "disk_time_s_total", "launches_total",
)

_GAUGE_KEYS = (
    "queue_depth", "running_jobs", "host_budget_used_bytes",
    "admitted_reservation_bytes", "peak_admitted_reservation_bytes",
    "uptime_s", "busy_time_s",
)

_HIST_HELP = {
    "queue_wait_s": "Job wait from submission to admission (seconds)",
    "quantum_s": "Scheduler quantum duration: one ALS sweep (seconds)",
    "dispatch_s": "Per-launch host dispatch latency (seconds)",
    "put_chunk_s": "Per-chunk H2D transfer issue time (seconds)",
    "disk_read_s": "Per-chunk store fetch time (seconds)",
    "launch_nnz": "True nnz per executed launch",
}


def render_prometheus(metrics, *, prefix: str = "repro") -> str:
    """Prometheus text exposition of a ``ServiceMetrics``.

    ``metrics`` is the live ``ServiceMetrics`` object (histograms need
    their bucket arrays, which the JSON ``snapshot()`` flattens).
    """
    out: list[str] = []
    for key in _COUNTER_KEYS:
        out.append(f"# TYPE {prefix}_{key} counter")
        out.append(f"{prefix}_{key} {_prom_num(getattr(metrics, key))}")
    out.append(f"# TYPE {prefix}_tenant_iterations_total counter")
    for tenant, n in sorted(metrics.tenant_iterations.items()):
        out.append(f'{prefix}_tenant_iterations_total'
                   f'{{tenant="{tenant}"}} {n}')
    for key in _GAUGE_KEYS:
        value = getattr(metrics, key)
        out.append(f"# TYPE {prefix}_{key} gauge")
        out.append(f"{prefix}_{key} {_prom_num(value)}")
    out.append(f"# TYPE {prefix}_iterations_per_busy_sec gauge")
    out.append(f"{prefix}_iterations_per_busy_sec "
               f"{_prom_num(metrics.iterations_per_sec())}")
    for name, hist_obj in (("queue_wait_s", metrics.hist.queue_wait_s),
                           ("quantum_s", metrics.hist.quantum_s),
                           ("dispatch_s", metrics.hist.dispatch_s),
                           ("put_chunk_s", metrics.hist.put_chunk_s),
                           ("disk_read_s", metrics.hist.disk_read_s),
                           ("launch_nnz", metrics.hist.launch_nnz)):
        _prom_hist(f"{prefix}_{name}", hist_obj, _HIST_HELP[name], out)
    # per-tenant scheduler latency hists (bounded label cardinality; the
    # unlabelled series above are the lossless rollup)
    for name in ("queue_wait_s", "quantum_s"):
        tenants = sorted(metrics.hist.tenant)
        if not tenants:
            continue
        full = f"{prefix}_tenant_{name}"
        out.append(f"# HELP {full} {_HIST_HELP[name]}, per tenant")
        out.append(f"# TYPE {full} histogram")
        for tenant in tenants:
            _prom_hist_samples(full, getattr(metrics.hist.tenant[tenant],
                                             name),
                               out, labels=f'tenant="{tenant}"')
    # tracer ring-buffer state: drops were previously visible only on the
    # Python object; a scrape now sees buffer pressure and whether the
    # tracer (and its overhead) is live at all
    out.append(f"# TYPE {prefix}_trace_dropped_spans_total counter")
    out.append(f"{prefix}_trace_dropped_spans_total "
               f"{_trace.TRACING.dropped}")
    out.append(f"# TYPE {prefix}_trace_enabled gauge")
    out.append(f"{prefix}_trace_enabled "
               f"{1 if _trace.TRACING.enabled else 0}")
    out.append(f"# TYPE {prefix}_trace_buffered_spans gauge")
    out.append(f"{prefix}_trace_buffered_spans {len(_trace.TRACING.buf)}")
    out.append(f"# TYPE {prefix}_trace_capacity_spans gauge")
    out.append(f"{prefix}_trace_capacity_spans "
               f"{_trace.TRACING.buf.maxlen}")
    # bandwidth-ledger state + per-edge totals (labelled by tier edge)
    ledger_snap = _ledger.snapshot()
    out.append(f"# TYPE {prefix}_ledger_enabled gauge")
    out.append(f"{prefix}_ledger_enabled "
               f"{1 if ledger_snap['enabled'] else 0}")
    for metric, kind in (("bytes_total", "counter"),
                         ("seconds_total", "counter"),
                         ("ops_total", "counter"),
                         ("gb_per_s", "gauge")):
        field = metric.replace("_total", "")
        out.append(f"# TYPE {prefix}_ledger_{metric} {kind}")
        for edge in _ledger.EDGES:
            acct = ledger_snap["edges"].get(edge)
            if acct is None:
                continue
            out.append(f'{prefix}_ledger_{metric}{{edge="{edge}"}} '
                       f'{_prom_num(acct[field])}')
    return "\n".join(out) + "\n"


_ANALYSIS_COUNTER_KEYS = (
    "hot_paths_traced", "jaxpr_eqns_walked", "encodings_verified",
    "launches_analyzed", "findings_total", "findings_jaxpr_audit",
    "findings_cache_churn", "findings_encoding", "findings_conflicts",
)

_ANALYSIS_GAUGE_KEYS = (
    "runtime_jaxpr_audit_s", "runtime_cache_churn_s", "runtime_encoding_s",
    "runtime_conflicts_s", "runtime_total_s",
)


def render_prometheus_analysis(metrics, *,
                               prefix: str = "repro_analysis") -> str:
    """Prometheus text exposition of a trace-tier run's
    :class:`repro.analysis.trace.TraceVerifyMetrics` — per-family finding
    counts as counters, verifier runtimes as gauges, so CI scrapes give
    the static-analysis tier the same trend lines the service has.
    """
    out: list[str] = []
    for key in _ANALYSIS_COUNTER_KEYS:
        out.append(f"# TYPE {prefix}_{key} counter")
        out.append(f"{prefix}_{key} {_prom_num(getattr(metrics, key))}")
    for key in _ANALYSIS_GAUGE_KEYS:
        out.append(f"# TYPE {prefix}_{key} gauge")
        out.append(f"{prefix}_{key} {_prom_num(getattr(metrics, key))}")
    return "\n".join(out) + "\n"
