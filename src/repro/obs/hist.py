"""Fixed-bucket log2 histograms for latency / bytes / nnz distributions.

Scalar time totals (``dispatch_time_s``, ``put_time_s``) answer "how much"
but not "how" — a streamed MTTKRP whose dispatch total is dominated by one
straggler launch needs a different fix (nnz balancing, Nisa et al.) than
one whose launches are uniformly slow (per-launch overhead, the paper's
batching claim).  A :class:`Hist` keeps the whole distribution at O(64)
ints: power-of-two buckets (value ``v`` lands in the bucket whose upper
bound is the smallest ``2^k >= v``), plus exact ``count`` / ``sum`` /
``min`` / ``max``.  Recording is a ``math.frexp`` + two adds — cheap
enough for per-launch hot loops — and histograms merge losslessly, so
per-job distributions roll up into service-wide ones at retirement.

Bucket range: ``2^-31`` (~0.5 ns) through ``2^31`` (~2 Gi), values above
fall into a final +Inf bucket; non-positive values land in the lowest
bucket.  This one fixed layout serves seconds, bytes, and nnz counts, and
makes any two histograms mergeable by construction.

``EngineHists`` / ``ServiceHists`` are the named bundles threaded through
``EngineStats`` and ``ServiceMetrics``; their ``snapshot()`` dicts are
JSON-serializable (sparse: only non-empty buckets are emitted) and their
keys are covered by the schema-stability test.
"""
from __future__ import annotations

import dataclasses
import math

_LO_EXP = -31                 # lowest bucket upper bound: 2^-31
NBUCKETS = 64                 # last bucket is +Inf


def bucket_index(v: float) -> int:
    """Index of the bucket whose range contains ``v``.

    Bucket ``i < NBUCKETS - 1`` holds ``2^(i-1+_LO_EXP) < v <= 2^(i+_LO_EXP)``;
    the final bucket holds everything larger (+Inf upper bound).
    """
    if v <= 0.0:
        return 0
    # frexp: v = m * 2^e with 0.5 <= m < 1, so 2^(e-1) <= v < 2^e; exact
    # powers of two (m == 0.5) belong in the *lower* bucket (le is inclusive)
    m, e = math.frexp(v)
    ub = e - 1 if m == 0.5 else e
    return min(NBUCKETS - 1, max(0, ub - _LO_EXP))


def bucket_le(i: int) -> float:
    """Upper bound of bucket ``i`` (``math.inf`` for the final bucket)."""
    if i >= NBUCKETS - 1:
        return math.inf
    return 2.0 ** (i + _LO_EXP)


class Hist:
    """Log2-bucket histogram: 64 fixed buckets + count/sum/min/max."""

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * NBUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v) -> None:
        v = float(v)
        self.counts[bucket_index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def merge(self, other: "Hist") -> "Hist":
        """Add ``other``'s samples into this histogram (lossless)."""
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Upper bucket bound below which a fraction ``q`` of samples lie
        (a conservative log2-resolution estimate; 0.0 on empty)."""
        if not self.count:
            return 0.0
        need = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need:
                return min(bucket_le(i), self.max)
        return self.max

    def cumulative(self) -> list:
        """Prometheus-style cumulative buckets: [(le, cumulative_count)].

        Only buckets at or after the first sample are emitted (plus the
        mandatory +Inf bucket), keeping the exposition compact.
        """
        out = []
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if c:
                out.append((bucket_le(i), cum))
        if not out or out[-1][0] != math.inf:
            out.append((math.inf, cum))
        return out

    def snapshot(self) -> dict:
        """JSON-serializable summary (sparse non-empty buckets, by le)."""
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "buckets": {("+Inf" if math.isinf(le) else repr(le)): c
                        for le, c in zip(
                            (bucket_le(i) for i in range(NBUCKETS)),
                            self.counts) if c},
        }

    def __repr__(self) -> str:
        return (f"Hist(count={self.count}, sum={self.sum:.6g}, "
                f"mean={self.mean:.6g})")


def _hist_field():
    return dataclasses.field(default_factory=Hist)


@dataclasses.dataclass
class EngineHists:
    """Per-plan execution distributions (one bundle per ``EngineStats``).

    ``dispatch_s``   host latency of each (async) compute dispatch — one
                     sample per launch on streamed paths, one per call on
                     the single-dispatch in-memory path;
    ``put_chunk_s``  host time of each H2D chunk transfer issue;
    ``disk_read_s``  host time of each store chunk fetch (disk tier only);
    ``launch_nnz``   true nnz per launch — the imbalance observable.
    """
    dispatch_s: Hist = _hist_field()
    put_chunk_s: Hist = _hist_field()
    disk_read_s: Hist = _hist_field()
    launch_nnz: Hist = _hist_field()

    def merge(self, other: "EngineHists") -> "EngineHists":
        self.dispatch_s.merge(other.dispatch_s)
        self.put_chunk_s.merge(other.put_chunk_s)
        self.disk_read_s.merge(other.disk_read_s)
        self.launch_nnz.merge(other.launch_nnz)
        return self

    def snapshot(self) -> dict:
        return {f.name: getattr(self, f.name).snapshot()
                for f in dataclasses.fields(self)}


#: distinct tenant labels each scheduler histogram tracks before new
#: tenants collapse into the overflow label — Prometheus label
#: cardinality must stay bounded no matter how many tenants submit.
MAX_TENANT_LABELS = 32
OVERFLOW_LABEL = "other"


@dataclasses.dataclass
class TenantHists:
    """One tenant's slice of the scheduler distributions."""
    queue_wait_s: Hist = _hist_field()
    quantum_s: Hist = _hist_field()

    def snapshot(self) -> dict:
        return {f.name: getattr(self, f.name).snapshot()
                for f in dataclasses.fields(self)}


@dataclasses.dataclass
class ServiceHists:
    """Service-wide distributions: scheduler behaviour + rolled-up engine
    hists of retired jobs (merged at retirement, lossless).

    ``queue_wait_s``/``quantum_s`` are additionally keyed per tenant via
    :meth:`record_queue_wait`/:meth:`record_quantum`, which record the
    same sample into the global hist and the tenant's — the global hist
    IS the lossless rollup of the tenant slices, by construction, not by
    a merge step that could drift.  Label cardinality is bounded at
    :data:`MAX_TENANT_LABELS`; later tenants share ``"other"``.
    """
    queue_wait_s: Hist = _hist_field()     # submission -> admission, per job
    quantum_s: Hist = _hist_field()        # one ALS sweep, per quantum
    dispatch_s: Hist = _hist_field()
    put_chunk_s: Hist = _hist_field()
    disk_read_s: Hist = _hist_field()
    launch_nnz: Hist = _hist_field()
    tenant: dict = dataclasses.field(default_factory=dict)

    def _tenant(self, tenant: str) -> TenantHists:
        label = str(tenant)
        th = self.tenant.get(label)
        if th is None:
            if len(self.tenant) >= MAX_TENANT_LABELS:
                label = OVERFLOW_LABEL
                th = self.tenant.get(label)
            if th is None:
                th = self.tenant.setdefault(label, TenantHists())
        return th

    def record_queue_wait(self, tenant: str, v: float) -> None:
        self.queue_wait_s.record(v)
        self._tenant(tenant).queue_wait_s.record(v)

    def record_quantum(self, tenant: str, v: float) -> None:
        self.quantum_s.record(v)
        self._tenant(tenant).quantum_s.record(v)

    def merge_engine(self, eh: EngineHists) -> "ServiceHists":
        """Roll a retired job's per-plan distributions into the service."""
        self.dispatch_s.merge(eh.dispatch_s)
        self.put_chunk_s.merge(eh.put_chunk_s)
        self.disk_read_s.merge(eh.disk_read_s)
        self.launch_nnz.merge(eh.launch_nnz)
        return self

    def snapshot(self) -> dict:
        # the tenant dict is not a Hist; it snapshots separately (the
        # schema test pins every value under "hist" to Hist shape)
        return {f.name: getattr(self, f.name).snapshot()
                for f in dataclasses.fields(self) if f.name != "tenant"}

    def tenant_snapshot(self) -> dict:
        return {t: th.snapshot() for t, th in sorted(self.tenant.items())}
