"""Memory-hierarchy bandwidth ledger: bytes/seconds/ops per tier edge.

PR 6's spans and histograms record *times* per pipeline stage; this
module attributes *bytes* to the tier edges those stages cross, so the
streaming gaps BENCH_5 measures (disk-streamed 0.80x, host-streamed
0.65x of in-memory) can be named: which edge is saturated, and how far
from achievable bandwidth each regime runs.

Three edges model the hierarchy::

    disk_host    -- DiskChunkSource reads (NVMe/page cache -> host RAM)
    host_device  -- jax.device_put H2D copies (host RAM -> device)
    device_hbm   -- kernel-side HBM traffic (analytic model; see below)

Each :func:`record` accrues ``(bytes, seconds, ops, flops)`` into three
account families: per edge, per ``(regime, edge)`` (regime = the memory
tier the plan runs in: ``in_memory`` / ``streamed`` / ``disk_streamed``
/ ``sharded``), and per ``(tenant, job, edge)`` when a
:class:`job_scope` is active (the scheduler wraps each quantum and each
admission-time plan build in one).

**Conservation by construction** — the trick that made BENCH_6's track
sums exact: instrumentation sites pass the ledger the *same* local
``nbytes``/``t1 - t0`` values they add to ``EngineStats``, never a
separately measured quantity.  Per ``(regime, edge)`` account, the
accumulation order is identical to the plan's own stats counters, so
:func:`verify_conservation` asserts equality with **zero** relative
error (floats included), not a tolerance.  Sites that carry no stats
object skip the ledger too, keeping the two views in lockstep.  Retries
inherit the property for free: ``retry_call`` sites record stats once,
after success, with the timing window spanning the retries — and the
ledger records from the same window; a giveup raises before either side
records, so nothing is double-counted.

Device HBM traffic cannot be measured from the host, so it is
*attributed* from an analytic per-kernel model over the launch table
(:func:`hbm_model_bytes`): the streamed nnz payload (hi + lo + vals +
per-launch base rows) plus rank-scaled factor gather/scatter traffic,
with the XLA scan kernel additionally charged for its materialized
decode/Hadamard intermediates that the fused Pallas kernel keeps in
VMEM.  The fenced device seconds are real; the bytes are the model —
the roofline report says so explicitly.

Zero-cost-disabled discipline (mirrors ``repro.obs.trace``): the
module-level :data:`LEDGER` singleton carries one ``enabled`` flag; hot
paths guard with ``if LEDGER.enabled:`` (a lock-free read) and pay a
single attribute check when disabled.  All mutation happens under
``LEDGER.lock``.
"""
from __future__ import annotations

import contextvars
import threading

# ----------------------------------------------------------------- edges
DISK_HOST = "disk_host"
HOST_DEVICE = "host_device"
DEVICE_HBM = "device_hbm"
EDGES = (DISK_HOST, HOST_DEVICE, DEVICE_HBM)
_EDGE_SET = frozenset(EDGES)

#: distinct tenant labels tracked per-job before overflowing into
#: :data:`OVERFLOW_TENANT` (bounded label cardinality, same bound the
#: tenant histograms use).
MAX_TENANT_KEYS = 32
OVERFLOW_TENANT = "other"

_GB = 1e9


class EdgeAccount:
    """One accumulator cell: bytes moved, seconds spent, ops, flops."""

    __slots__ = ("bytes", "seconds", "ops", "flops")

    def __init__(self):
        self.bytes = 0
        self.seconds = 0.0
        self.ops = 0
        self.flops = 0.0

    def add(self, nbytes, seconds, ops, flops):
        self.bytes += nbytes
        self.seconds += seconds
        self.ops += ops
        self.flops += flops

    def gb_per_s(self) -> float:
        if self.seconds <= 0.0:
            return 0.0
        return self.bytes / self.seconds / _GB

    def snapshot(self) -> dict:
        return {
            "bytes": int(self.bytes),
            "seconds": self.seconds,
            "ops": self.ops,
            "flops": self.flops,
            "gb_per_s": self.gb_per_s(),
        }


class LedgerState:
    """Module-level singleton state (see :data:`LEDGER`).

    ``enabled`` is read lock-free on hot paths; every write to the
    account dicts happens under ``lock``.  Account keys: ``edges`` by
    edge name, ``regimes`` by ``(regime, edge)``, ``jobs`` by
    ``(tenant, job, edge)``.
    """

    def __init__(self):
        self.enabled = False
        self.lock = threading.Lock()
        self.edges: dict[str, EdgeAccount] = {}
        self.regimes: dict[tuple, EdgeAccount] = {}
        self.jobs: dict[tuple, EdgeAccount] = {}
        self.tenants: set[str] = set()


LEDGER = LedgerState()

#: (tenant, job_id) attribution scope; set by :class:`job_scope`.
_scope: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_ledger_scope", default=None)


def _acct(accounts: dict, key) -> EdgeAccount:
    acct = accounts.get(key)
    if acct is None:
        acct = EdgeAccount()
        accounts[key] = acct
    return acct


def record(edge: str, nbytes, seconds, *, regime: str = "",
           flops: float = 0.0, ops: int = 1) -> None:
    """Accrue one transfer/kernel into the ledger (no-op when disabled).

    Call sites MUST pass the exact ``nbytes``/``seconds`` locals they
    feed ``EngineStats`` — conservation is checked with 0 tolerance.
    ``regime`` is the plan's memory tier (``stats.backend``); empty
    skips the per-regime account but still accrues the edge total.
    """
    if not LEDGER.enabled:
        return
    if edge not in _EDGE_SET:
        raise ValueError(f"unknown ledger edge {edge!r}; one of {EDGES}")
    scope = _scope.get()
    with LEDGER.lock:
        _acct(LEDGER.edges, edge).add(nbytes, seconds, ops, flops)
        if regime:
            _acct(LEDGER.regimes, (regime, edge)).add(
                nbytes, seconds, ops, flops)
        if scope is not None:
            tenant, job = scope
            if tenant not in LEDGER.tenants:
                if len(LEDGER.tenants) >= MAX_TENANT_KEYS:
                    tenant = OVERFLOW_TENANT
                LEDGER.tenants.add(tenant)
            _acct(LEDGER.jobs, (tenant, job, edge)).add(
                nbytes, seconds, ops, flops)


class job_scope:
    """Attribute records inside the block to ``(tenant, job_id)``.

    Context-local (``contextvars``), so concurrent worker threads each
    carry their own attribution; cheap enough to set unconditionally.
    """

    __slots__ = ("_tenant", "_job", "_token")

    def __init__(self, tenant: str, job_id: str):
        self._tenant = str(tenant)
        self._job = str(job_id)
        self._token = None

    def __enter__(self):
        self._token = _scope.set((self._tenant, self._job))
        return self

    def __exit__(self, *exc):
        _scope.reset(self._token)
        return False


# ------------------------------------------------------------- lifecycle
def enable() -> None:
    with LEDGER.lock:
        LEDGER.enabled = True


def disable() -> None:
    with LEDGER.lock:
        LEDGER.enabled = False


def is_enabled() -> bool:
    return LEDGER.enabled


def clear() -> None:
    """Drop all accounts (the enabled flag is untouched)."""
    with LEDGER.lock:
        LEDGER.edges.clear()
        LEDGER.regimes.clear()
        LEDGER.jobs.clear()
        LEDGER.tenants.clear()


class enabled:
    """Scoped enable: ``with ledger.enabled(): ...`` restores the prior
    state on exit (mirrors ``obs.trace.enabled``)."""

    def __enter__(self):
        self._was = LEDGER.enabled
        enable()
        return self

    def __exit__(self, *exc):
        with LEDGER.lock:
            LEDGER.enabled = self._was
        return False


def snapshot() -> dict:
    """JSON-safe view: edge totals, per-regime, per-tenant (aggregated),
    and per-(tenant, job) accounts."""
    with LEDGER.lock:
        edges = {e: a.snapshot() for e, a in LEDGER.edges.items()}
        regimes: dict[str, dict] = {}
        for (regime, edge), acct in LEDGER.regimes.items():
            regimes.setdefault(regime, {})[edge] = acct.snapshot()
        jobs: dict[str, dict] = {}
        tenants: dict[str, dict] = {}
        for (tenant, job, edge), acct in LEDGER.jobs.items():
            jobs.setdefault(tenant, {}).setdefault(job, {})[edge] = \
                acct.snapshot()
            agg = tenants.setdefault(tenant, {}).setdefault(
                edge, {"bytes": 0, "seconds": 0.0, "ops": 0, "flops": 0.0})
            agg["bytes"] += acct.bytes
            agg["seconds"] += acct.seconds
            agg["ops"] += acct.ops
            agg["flops"] += acct.flops
        enabled_flag = LEDGER.enabled
    for per_edge in tenants.values():
        for agg in per_edge.values():
            s = agg["seconds"]
            agg["gb_per_s"] = (agg["bytes"] / s / _GB) if s > 0.0 else 0.0
    return {"enabled": enabled_flag, "edges": edges, "regimes": regimes,
            "tenants": tenants, "jobs": jobs}


# ------------------------------------------------------- analytic models
def hbm_model_bytes(nnz: int, *, order: int, rank: int,
                    value_itemsize: int, factor_itemsize: int = 4,
                    kernel: str = "pallas") -> float:
    """Analytic device-HBM traffic for one MTTKRP pass over ``nnz``
    elements of an order-``order`` BLCO tensor at rank ``rank``.

    Common to both kernels (the paper's streamed payload):

    * index/value stream: ``nnz * (hi + lo + vals)`` = 4 + 4 +
      ``value_itemsize`` bytes per element;
    * factor gathers: ``(order - 1)`` rows of ``rank`` floats per
      element;
    * output scatter: read + write of a ``rank`` row per element.

    The XLA scan kernel additionally materializes its decoded
    coordinates (write + read, 4 bytes x ``order``) and the Hadamard
    intermediate (write + read, ``rank`` floats); the fused Pallas
    kernel keeps both in VMEM, which is exactly the traffic the fusion
    saves.  A model, not a measurement — reported as such.
    """
    n = float(nnz)
    f = float(factor_itemsize)
    stream = n * (4.0 + 4.0 + float(value_itemsize))
    gathers = n * (order - 1) * rank * f
    scatter = n * 2.0 * rank * f
    total = stream + gathers + scatter
    if kernel != "pallas":
        total += n * order * 4.0 * 2.0        # decoded coords, out + in
        total += n * rank * f * 2.0           # Hadamard intermediate
    return total


def mttkrp_flops(nnz: int, *, order: int, rank: int) -> float:
    """Flops for one MTTKRP pass: per element and rank lane, ``order-1``
    Hadamard multiplies plus one scatter-accumulate add."""
    return float(nnz) * rank * order


# ----------------------------------------------------------- conservation
#: edge -> (ledger field, EngineStats counter) pairs that must agree
#: exactly.  device_hbm bytes are model-attributed (no stats mirror), so
#: only its seconds are conservation-checked, against the fenced
#: ``device_time_s``.
CONSERVATION_FIELDS = {
    DISK_HOST: (("bytes", "disk_bytes"), ("seconds", "disk_time_s")),
    HOST_DEVICE: (("bytes", "h2d_bytes"), ("seconds", "put_time_s")),
    DEVICE_HBM: (("seconds", "device_time_s"),),
}


def _rel_err(a: float, b: float) -> float:
    if a == b:
        return 0.0
    denom = max(abs(a), abs(b))
    return abs(a - b) / denom


def verify_conservation(pairs) -> dict:
    """Check per-(regime, edge) ledger totals against EngineStats.

    ``pairs``: iterable of ``(regime, stats)`` where ``stats`` is an
    ``EngineStats`` (or its ``snapshot()`` dict); each regime must map
    to exactly one stats object — within one, ledger and stats
    accumulate the identical float sequence, so the expected relative
    error is exactly 0.0, not "small".
    """
    snap = snapshot()
    checks = []
    max_err = 0.0
    for regime, stats in pairs:
        stats_snap = stats if isinstance(stats, dict) else stats.snapshot()
        per_edge = snap["regimes"].get(regime, {})
        for edge, fields in CONSERVATION_FIELDS.items():
            acct = per_edge.get(edge, {"bytes": 0, "seconds": 0.0})
            for ledger_field, stats_field in fields:
                lv = acct.get(ledger_field, 0)
                sv = stats_snap.get(stats_field, 0)
                err = _rel_err(float(lv), float(sv))
                max_err = max(max_err, err)
                checks.append({
                    "regime": regime, "edge": edge,
                    "field": ledger_field, "stats_field": stats_field,
                    "ledger": lv, "stats": sv, "rel_err": err,
                })
    return {"checks": checks, "max_rel_err": max_err}
