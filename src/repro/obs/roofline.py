"""Roofline attribution over the bandwidth ledger.

Turns :mod:`repro.obs.ledger` accounts into the report the ROADMAP's
streaming item needs: achieved GB/s per tier edge, arithmetic intensity
per regime (flops from the rank/nnz model, bytes from the ledger's HBM
attribution), and a memory-vs-compute-bound classification — i.e. the
classic roofline, but with the x-axis bytes coming from a conservation-
checked ledger instead of hand-waving.

Classification needs machine ceilings.  ``peaks`` maps edge name ->
peak GB/s (measured by ``bench_roofline``'s microbenchmarks — achievable
on *this* host, not a datasheet number) and ``peak_flops`` the device
flop ceiling; without them the report still carries bytes/seconds/GB/s
but classifies ``"unknown"`` rather than guessing.  The ``saturated_edge``
of a regime is the edge running closest to its ceiling when fractions
are available, else the edge where the regime spends the most time.

Everything returned is a plain JSON-safe dict (no Inf/NaN), suitable for
``GetRoofline`` service responses, BENCH_7 payloads, and
``scripts/obs_report.py`` rendering.
"""
from __future__ import annotations

from . import ledger as _ledger

_GB = 1e9


def arithmetic_intensity(flops: float, hbm_bytes: float) -> float:
    """Flops per byte of device-HBM traffic (0 when nothing moved)."""
    if hbm_bytes <= 0.0:
        return 0.0
    return flops / hbm_bytes


def classify(ai: float, *, peak_flops: float | None,
             peak_hbm_gb_per_s: float | None) -> str:
    """Roofline side of the ridge: memory-bound iff the arithmetic
    intensity sits left of the machine balance point."""
    if not peak_flops or not peak_hbm_gb_per_s:
        return "unknown"
    balance = peak_flops / (peak_hbm_gb_per_s * _GB)
    return "memory_bound" if ai < balance else "compute_bound"


def _edge_report(acct: dict, peak: float | None) -> dict:
    out = {
        "bytes": acct.get("bytes", 0),
        "seconds": acct.get("seconds", 0.0),
        "ops": acct.get("ops", 0),
        "gb_per_s": acct.get("gb_per_s", 0.0),
    }
    if peak:
        out["peak_gb_per_s"] = peak
        out["achieved_fraction"] = out["gb_per_s"] / peak
    return out


def _saturated_edge(edges: dict) -> str | None:
    """Edge nearest its ceiling; falls back to largest time share when no
    fractions are present.  Only edges with measured seconds count."""
    best, best_frac = None, -1.0
    for edge, rep in edges.items():
        if rep.get("seconds", 0.0) <= 0.0:
            continue
        frac = rep.get("achieved_fraction")
        if frac is None:
            continue
        if frac > best_frac:
            best, best_frac = edge, frac
    if best is not None:
        return best
    for edge, rep in sorted(edges.items(),
                            key=lambda kv: kv[1].get("seconds", 0.0),
                            reverse=True):
        if rep.get("seconds", 0.0) > 0.0:
            return edge
    return None


def roofline_report(snap: dict | None = None, *,
                    peaks: dict | None = None,
                    peak_flops: float | None = None) -> dict:
    """Build the machine-readable roofline from a ledger snapshot.

    ``snap`` defaults to ``ledger.snapshot()``.  Returns::

        {"edges":   {edge: {bytes, seconds, ops, gb_per_s,
                            [peak_gb_per_s, achieved_fraction]}},
         "regimes": {regime: {"edges": {...}, "flops",
                              "arithmetic_intensity", "gflops_per_s",
                              "bound", "saturated_edge"}},
         "peaks":   {...}, "peak_flops": ...}

    ``arithmetic_intensity`` divides the regime's flops by its
    *model-attributed* device_hbm bytes (see ``ledger.hbm_model_bytes``);
    ``bound`` applies :func:`classify` against the supplied ceilings.
    """
    if snap is None:
        snap = _ledger.snapshot()
    peaks = peaks or {}
    hbm_peak = peaks.get(_ledger.DEVICE_HBM)

    edges = {e: _edge_report(a, peaks.get(e))
             for e, a in snap.get("edges", {}).items()}

    regimes = {}
    for regime, per_edge in snap.get("regimes", {}).items():
        redges = {e: _edge_report(a, peaks.get(e))
                  for e, a in per_edge.items()}
        hbm = per_edge.get(_ledger.DEVICE_HBM, {})
        flops = hbm.get("flops", 0.0)
        hbm_bytes = float(hbm.get("bytes", 0))
        hbm_seconds = hbm.get("seconds", 0.0)
        ai = arithmetic_intensity(flops, hbm_bytes)
        regimes[regime] = {
            "edges": redges,
            "flops": flops,
            "arithmetic_intensity": ai,
            "gflops_per_s": (flops / hbm_seconds / _GB)
            if hbm_seconds > 0.0 else 0.0,
            "bound": classify(ai, peak_flops=peak_flops,
                              peak_hbm_gb_per_s=hbm_peak),
            "saturated_edge": _saturated_edge(redges),
        }

    return {"edges": edges, "regimes": regimes,
            "peaks": dict(peaks), "peak_flops": peak_flops}
