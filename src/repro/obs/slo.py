"""Per-tenant latency SLOs + a background telemetry exporter.

An SLO here is "fraction of samples at or under a latency threshold
must be >= target", evaluated over the scheduler's ``queue_wait_s`` /
``quantum_s`` histograms — globally and per tenant now that
``ServiceHists`` keys them by tenant.  Evaluation is *conservative*:
the log2 histograms only know bucket upper bounds, so a sample counts
as good only when its whole bucket sits at or under the threshold
(``min``/``max`` shortcuts recover exactness at the extremes).  The
burn rate is the standard error-budget ratio: ``bad_fraction /
(1 - target)`` — 1.0 means burning the budget exactly as fast as the
objective allows, >1 means the objective will be violated.

:class:`TelemetryExporter` is the push half: a daemon thread that
periodically appends a JSONL snapshot (metrics + SLO report + ledger)
and/or atomically rewrites a Prometheus textfile, for scrape-less
environments (node_exporter textfile collector).  It deliberately runs
*outside* the service worker: the runtime watchdog can kill and restart
the worker thread without the exporter missing a tick — the chaos soak
proves exactly that.  Zero-cost-disabled discipline: nothing runs until
``start()``; ``stop()`` joins the thread.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

from . import ledger as _ledger
from .hist import Hist, bucket_le


@dataclasses.dataclass(frozen=True)
class SLO:
    """One latency objective over a named scheduler histogram."""
    name: str
    hist: str                 # "queue_wait_s" | "quantum_s"
    threshold_s: float
    target: float             # required good fraction, e.g. 0.99

    def config(self) -> dict:
        return dataclasses.asdict(self)


DEFAULT_SLOS = (
    SLO(name="queue_wait_under_1s", hist="queue_wait_s",
        threshold_s=1.0, target=0.99),
    SLO(name="quantum_under_4s", hist="quantum_s",
        threshold_s=4.0, target=0.95),
)


def fraction_le(hist: Hist, threshold_s: float) -> float:
    """Conservative fraction of samples <= threshold (1.0 on empty)."""
    if hist.count == 0:
        return 1.0
    if hist.max <= threshold_s:
        return 1.0
    if hist.min > threshold_s:
        return 0.0
    good = 0
    for i, c in enumerate(hist.counts):
        if c and bucket_le(i) <= threshold_s:
            good += c
    return good / hist.count


def evaluate(slo: SLO, hist: Hist) -> dict:
    """Evaluate one objective against one histogram (JSON-safe)."""
    good = fraction_le(hist, slo.threshold_s)
    bad = 1.0 - good
    budget = max(1.0 - slo.target, 1e-9)
    return {
        "name": slo.name,
        "hist": slo.hist,
        "threshold_s": slo.threshold_s,
        "target": slo.target,
        "samples": hist.count,
        "good_fraction": good,
        "met": good >= slo.target,
        "burn_rate": bad / budget,
    }


def slo_report(service_hists, slos=DEFAULT_SLOS) -> dict:
    """Evaluate every objective globally and per tenant.

    ``service_hists`` is a ``ServiceHists`` (global ``queue_wait_s`` /
    ``quantum_s`` plus the ``tenant`` slices).  Tenants beyond the label
    bound appear under ``"other"``, same as the histograms themselves.
    """
    out = {
        "slos": [s.config() for s in slos],
        "global": {s.name: evaluate(s, getattr(service_hists, s.hist))
                   for s in slos},
        "tenants": {},
    }
    for tenant, th in sorted(service_hists.tenant.items()):
        out["tenants"][tenant] = {s.name: evaluate(s, getattr(th, s.hist))
                                  for s in slos}
    return out


class TelemetryExporter:
    """Periodic background export of metrics/SLO/ledger snapshots.

    ``target`` is a ``DecompositionService`` or ``ServiceRuntime`` —
    anything with ``service_metrics()`` and ``get_slo()``.  At each tick
    the exporter appends one JSON line to ``jsonl_path`` (if set) and
    atomically replaces ``prom_path`` (if set) with the Prometheus
    exposition.  Export failures are counted, never raised into the
    timer thread.  Independent of the service worker thread by design.
    """

    def __init__(self, target, *, interval_s: float = 5.0,
                 jsonl_path: str | None = None,
                 prom_path: str | None = None,
                 slos=DEFAULT_SLOS):
        self._target = target
        self._interval_s = float(interval_s)
        self._jsonl_path = jsonl_path
        self._prom_path = prom_path
        self._slos = tuple(slos)
        self._lock = threading.Lock()
        self._stop_ev = threading.Event()
        self._thread = None
        self._exports = 0
        self._failures = 0

    # ------------------------------------------------------------ control
    def start(self) -> "TelemetryExporter":
        self._stop_ev.clear()
        with self._lock:
            if self._thread is not None:
                return self
            t = threading.Thread(target=self._loop,
                                 name="repro-telemetry", daemon=True)
            self._thread = t
        t.start()
        return self

    def stop(self, *, final_export: bool = True) -> None:
        self._stop_ev.set()
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=10.0)
        if final_export:
            self.export_once()

    def __enter__(self) -> "TelemetryExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        with self._lock:
            return self._thread is not None and self._thread.is_alive()

    def counters(self) -> dict:
        with self._lock:
            return {"exports": self._exports, "failures": self._failures}

    # ------------------------------------------------------------ export
    def _loop(self) -> None:
        while not self._stop_ev.wait(self._interval_s):
            self.export_once()

    def export_once(self) -> bool:
        """One synchronous export tick; returns success."""
        try:
            record = self._build_record()
            if self._jsonl_path:
                with open(self._jsonl_path, "a", encoding="utf-8") as f:
                    f.write(json.dumps(record) + "\n")
            if self._prom_path:
                self._write_prom_textfile()
        except Exception:
            with self._lock:
                self._failures += 1
            return False
        with self._lock:
            self._exports += 1
        return True

    def _build_record(self) -> dict:
        return {
            "ts": time.time(),
            "metrics": self._target.service_metrics(),
            "slo": self._target.get_slo(),
            "ledger": _ledger.snapshot(),
        }

    def _write_prom_textfile(self) -> None:
        # imported here to avoid an export<->slo module cycle
        from .export import render_prometheus
        metrics = getattr(self._target, "metrics", None)
        if metrics is None:                      # runtime wraps a service
            metrics = self._target.service.metrics
        text = render_prometheus(metrics)
        tmp = f"{self._prom_path}.tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(text)
        os.replace(tmp, self._prom_path)
