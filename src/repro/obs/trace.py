"""Lightweight span tracer: where the time goes, per pipeline stage.

The paper's claims are about *attribution* — kernel-launch overhead vs
memory-access irregularity vs streaming overlap — so flat time totals are
not enough: the streamed regimes interleave disk reads, H2D puts, and
device dispatches, and only a timeline shows whether they overlap.  This
module records that timeline as **spans**: named intervals on a *track*
(one track per pipeline stage: ``store`` / ``h2d`` / ``dispatch`` /
``device`` / ``scheduler`` / ``registry`` / ``plan``), each carrying
attributes like ``nnz``, ``launch``, ``bytes``.

Two recording APIs:

* :func:`span` — a context manager for code whose interval the tracer
  itself measures (plan ``mttkrp`` calls, scheduler quanta, registry
  spill/load).  Nesting is tracked through a :mod:`contextvars` variable,
  so a child span records its parent's name; contexts are per-thread, so
  spans emitted inside the service runtime's worker thread nest under the
  quantum span that thread opened — no cross-thread leakage.
* :func:`add_event` — records an interval the caller ALREADY measured
  (the streaming hot loop times every put/dispatch for ``EngineStats``
  anyway; tracing reuses those exact timestamps, so span sums and stats
  totals agree by construction).

Zero-cost when disabled: recording is gated on one module-level flag
(``TRACING.enabled``), :func:`span` returns a shared no-op singleton, and
hot paths guard ``add_event`` calls on the same flag so the disabled fast
path allocates nothing.  Completed spans land in a thread-safe bounded
ring buffer (oldest evicted first, ``TRACING.dropped`` counts evictions);
export them with :mod:`repro.obs.export`.
"""
from __future__ import annotations

import contextvars
import threading
import time
from collections import deque

DEFAULT_CAPACITY = 65536        # spans held in the ring buffer


class TracerState:
    """The module-level tracer: enable flag + bounded span ring buffer."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self.lock = threading.Lock()
        self.buf: deque = deque(maxlen=int(capacity))
        self.dropped = 0             # spans evicted by the bounded ring
        self.epoch_s = time.perf_counter()   # trace time zero (export origin)


# THE module-level state; hot paths read ``TRACING.enabled`` once per span.
TRACING = TracerState()

# Current span of this thread/context (contextvars are per-thread, so the
# runtime worker's quantum span parents only spans opened on that thread).
_current: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


class Span:
    """One named interval on a track, with attributes and a parent name."""

    __slots__ = ("name", "track", "attrs", "start_s", "end_s", "parent",
                 "_token")

    def __init__(self, name: str, track: str, attrs: dict):
        self.name = name
        self.track = track
        self.attrs = attrs
        self.start_s = 0.0
        self.end_s = 0.0
        self.parent: str | None = None
        self._token = None

    @property
    def duration_s(self) -> float:
        return max(0.0, self.end_s - self.start_s)

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (e.g. the chosen backend)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        parent = _current.get()
        self.parent = parent.name if parent is not None else None
        self._token = _current.set(self)
        self.start_s = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.end_s = time.perf_counter()
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        _record(self)
        return False

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, track={self.track!r}, "
                f"dur={self.duration_s * 1e6:.1f}us, attrs={self.attrs})")


class _NullSpan:
    """The shared disabled-path span: every operation is a no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


def _record(s: Span) -> None:
    with TRACING.lock:
        if len(TRACING.buf) == TRACING.buf.maxlen:
            TRACING.dropped += 1
        TRACING.buf.append(s)


# ------------------------------------------------------------------ recording
def span(name: str, track: str = "main", **attrs):
    """Context manager recording ``name`` on ``track`` while entered.

    Returns the shared no-op singleton when tracing is disabled — one flag
    check, no allocation beyond the call's own kwargs.
    """
    if not TRACING.enabled:
        return _NULL
    return Span(name, track, attrs)


def add_event(name: str, track: str, start_s: float, end_s: float,
              **attrs) -> None:
    """Record an interval the caller already measured (hot-loop path).

    The streaming loop times every chunk put / launch dispatch for
    ``EngineStats``; passing those timestamps here makes the trace agree
    with the stats *exactly*.  Hot paths should guard the call on
    ``TRACING.enabled`` so the disabled path does not even build kwargs.
    """
    if not TRACING.enabled:
        return
    s = Span(name, track, attrs)
    s.start_s = start_s
    s.end_s = end_s
    parent = _current.get()
    s.parent = parent.name if parent is not None else None
    _record(s)


def current_span():
    """The innermost entered span of this thread/context (or None)."""
    return _current.get()


# ------------------------------------------------------------------- control
def enable(capacity: int | None = None) -> None:
    """Turn span recording on (optionally resizing the ring buffer)."""
    with TRACING.lock:
        if capacity is not None and int(capacity) != TRACING.buf.maxlen:
            TRACING.buf = deque(TRACING.buf, maxlen=int(capacity))
        TRACING.enabled = True


def disable() -> None:
    # writes to the singleton go under its lock (hot-path READS of
    # ``TRACING.enabled`` stay lock-free by design: a stale read is a
    # dropped span, a torn enable/resize sequence would be corruption)
    with TRACING.lock:
        TRACING.enabled = False


def is_enabled() -> bool:
    return TRACING.enabled


def clear() -> None:
    """Drop all recorded spans and reset the export time origin."""
    with TRACING.lock:
        TRACING.buf.clear()
        TRACING.dropped = 0
        TRACING.epoch_s = time.perf_counter()


def spans() -> list:
    """Snapshot of the recorded spans (oldest first); buffer unchanged."""
    with TRACING.lock:
        return list(TRACING.buf)


def drain() -> list:
    """Remove and return all recorded spans (oldest first)."""
    with TRACING.lock:
        out = list(TRACING.buf)
        TRACING.buf.clear()
        return out


class enabled:
    """``with obs.trace.enabled(): ...`` — scoped tracing for tests/benches."""

    def __init__(self, capacity: int | None = None):
        self.capacity = capacity
        self._was = False

    def __enter__(self):
        self._was = TRACING.enabled
        enable(self.capacity)
        return self

    def __exit__(self, *exc) -> bool:
        with TRACING.lock:
            TRACING.enabled = self._was
        return False
