from .adamw import AdamWConfig, init_state, apply_updates
from . import schedules
__all__ = ["AdamWConfig", "init_state", "apply_updates", "schedules"]
