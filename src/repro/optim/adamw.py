"""AdamW with global-norm clipping, WSD/cosine schedules, and an optional
int8-quantized second moment (distributed-optimization memory trick).

The optimizer state is a pytree mirroring the params tree, so GSPMD shards
m/v exactly like the parameters (ZeRO-style: fully sharded optimizer states).

``quantize_v="int8"`` stores the second moment as int8 + per-tensor fp32
scale — 4x less optimizer HBM for the largest models (the deepseek-236b
train_4k cell needs it to fit v5e HBM; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import schedules


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_v: str = "none"          # none | int8


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros_like(p)
    state = {"step": jnp.zeros((), jnp.int32),
             "m": jax.tree.map(zeros, params)}
    if cfg.quantize_v == "int8":
        state["v_q"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.int8), params)
        state["v_scale"] = jax.tree.map(
            lambda p: jnp.zeros((), jnp.float32), params)
    else:
        state["v"] = jax.tree.map(zeros, params)
    return state


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _dequant(v_q, scale):
    return v_q.astype(jnp.float32) * scale


def _quant(v, old_scale):
    scale = jnp.maximum(jnp.max(jnp.abs(v)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    sched_fn = {"cosine": schedules.cosine, "wsd": schedules.wsd}[cfg.schedule]
    step = state["step"] + 1
    lr = sched_fn(step, peak_lr=cfg.peak_lr, warmup=cfg.warmup,
                  total=cfg.total_steps)

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * clip, grads)

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g.astype(m.dtype),
        state["m"], grads)

    if cfg.quantize_v == "int8":
        def upd(p, g, m, vq, vs):
            v = cfg.b2 * _dequant(vq, vs) + (1 - cfg.b2) * \
                jnp.square(g.astype(jnp.float32))
            update = (m.astype(jnp.float32) / bc1) / \
                (jnp.sqrt(v / bc2) + cfg.eps)
            newp = p.astype(jnp.float32) - lr * (update + cfg.weight_decay *
                                                 p.astype(jnp.float32))
            nq, ns = _quant(v, vs)
            return newp.astype(p.dtype), nq, ns
        out = jax.tree.map(upd, params, grads, new_m,
                           state["v_q"], state["v_scale"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_vq = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_vs = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_state = {"step": step, "m": new_m, "v_q": new_vq,
                     "v_scale": new_vs}
    else:
        new_v = jax.tree.map(
            lambda v, g: cfg.b2 * v + (1 - cfg.b2) *
            jnp.square(g.astype(v.dtype)), state["v"], grads)

        def upd(p, m, v):
            update = (m.astype(jnp.float32) / bc1) / \
                (jnp.sqrt(v.astype(jnp.float32) / bc2) + cfg.eps)
            newp = p.astype(jnp.float32) - lr * (update + cfg.weight_decay *
                                                 p.astype(jnp.float32))
            return newp.astype(p.dtype)
        new_params = jax.tree.map(upd, params, new_m, new_v)
        new_state = {"step": step, "m": new_m, "v": new_v}

    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
