"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int,
           final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(1, warmup)
    frac = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> stable plateau -> sharp (exponential) decay over the last
    ``decay_frac`` of training (MiniCPM, arXiv:2404.06395)."""
    step = jnp.asarray(step, jnp.float32)
    decay_steps = jnp.maximum(1, total * decay_frac)
    decay_start = total - decay_steps
    warm = peak_lr * step / jnp.maximum(1, warmup)
    dec_t = jnp.clip((step - decay_start) / decay_steps, 0, 1)
    dec = peak_lr * jnp.exp(jnp.log(final_frac) * dec_t)
    return jnp.where(step < warmup, warm,
                     jnp.where(step < decay_start, peak_lr, dec))


def make_schedule(name: str, **kw):
    return {"cosine": cosine, "wsd": wsd}[name], kw
