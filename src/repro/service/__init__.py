"""Multi-tenant decomposition service over pooled device reservations.

Turns the paper's single-copy BLCO + fixed-reservation streaming into a
serving layer: many concurrent CP-ALS / MTTKRP jobs share one accelerator
under a device-memory admission budget.

    registry   BLCO construction cache keyed by content fingerprint
    executor   pooled reservation executor (shared launch-buffer shapes)
    scheduler  FIFO admission under a byte budget + round-robin iterations
    api        typed requests/responses + the DecompositionService facade
    metrics    per-job and service-wide counters
"""
from .api import (DecompositionResult, DecompositionService, JobStatus,
                  MTTKRPQuery, SubmitDecomposition, DEFAULT_DEVICE_BUDGET)
from .executor import PooledExecutor
from .metrics import JobMetrics, ServiceMetrics
from .registry import BuildParams, TensorHandle, TensorRegistry, fingerprint
from .scheduler import Job, JobScheduler, QUEUED, RUNNING, DONE, FAILED

__all__ = [
    "DecompositionResult", "DecompositionService", "JobStatus",
    "MTTKRPQuery", "SubmitDecomposition", "DEFAULT_DEVICE_BUDGET",
    "PooledExecutor", "JobMetrics", "ServiceMetrics",
    "BuildParams", "TensorHandle", "TensorRegistry", "fingerprint",
    "Job", "JobScheduler", "QUEUED", "RUNNING", "DONE", "FAILED",
]
