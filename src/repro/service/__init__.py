"""Multi-tenant decomposition service over pooled execution plans.

Turns the paper's single-copy BLCO + unified engine API into a serving
layer: many concurrent CP-ALS / MTTKRP jobs share one accelerator under a
measured device-byte admission budget, each executing through an
``ExecutionPlan`` — device-resident for small tensors, streamed through
pooled reservations for large ones.

    registry   two-tier (host/disk) BLCO cache keyed by content
               fingerprint, with LRU spilling to the persistent store
    executor   ServiceEngine: pooled plans (reservations + device
               residency + disk streaming for spilled tensors)
    scheduler  FIFO admission by plan.device_bytes() + weighted stride
               fair share with cancellation
    api        typed requests/responses + the DecompositionService facade
               (incl. snapshot()/restore() persistence)
    metrics    per-job and service-wide counters (unified EngineStats)
    runtime    ServiceRuntime: threaded async driver with job cancellation
               and streaming per-iteration status feeds
"""
from .api import (CancelJob, CancelResult, DecompositionResult,
                  DecompositionService, GetMetrics, GetRoofline, GetSLO,
                  GetTrace, JobStatus, MTTKRPQuery, SetWeight,
                  SubmitDecomposition, WeightUpdate, DEFAULT_DEVICE_BUDGET)
from .executor import (PooledDiskStreamedPlan, PooledExecutor,
                       PooledInMemoryPlan, PooledStreamedPlan, ServiceEngine)
from .metrics import JobMetrics, ServiceMetrics
from .registry import BuildParams, TensorHandle, TensorRegistry, fingerprint
from .runtime import JobEvent, ServiceRuntime, StatusFeed
from .scheduler import (Job, JobScheduler, QUEUED, RUNNING, DONE, FAILED,
                        CANCELLED, TERMINAL_STATES)

__all__ = [
    "CancelJob", "CancelResult", "DecompositionResult",
    "DecompositionService", "GetMetrics", "GetRoofline", "GetSLO",
    "GetTrace", "JobStatus",
    "MTTKRPQuery", "SetWeight", "SubmitDecomposition", "WeightUpdate",
    "DEFAULT_DEVICE_BUDGET",
    "ServiceEngine", "PooledExecutor", "PooledInMemoryPlan",
    "PooledStreamedPlan", "PooledDiskStreamedPlan",
    "JobMetrics", "ServiceMetrics",
    "BuildParams", "TensorHandle", "TensorRegistry", "fingerprint",
    "JobEvent", "ServiceRuntime", "StatusFeed",
    "Job", "JobScheduler", "QUEUED", "RUNNING", "DONE", "FAILED",
    "CANCELLED", "TERMINAL_STATES",
]
