"""Typed request/response surface + the synchronous service facade.

``DecompositionService`` wires registry -> scheduler -> service engine into
one front door: submit decomposition jobs (CP-ALS to convergence), issue
one-shot MTTKRP queries against registered tensors, drive everything to
completion, and read per-job / service-wide metrics.  Every MTTKRP — job
iteration or one-shot query — executes through an ``ExecutionPlan`` from
the pooled ``ServiceEngine``: small tensors transparently run
device-resident, larger ones stream, all under one measured byte budget.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.cp_als import CPResult
from repro.core.tensor import SparseTensor
from repro.obs import roofline as obs_roofline
from repro.obs import slo as obs_slo
from repro.obs import trace as obs_trace
from repro.obs.export import chrome_trace, render_prometheus

from . import scheduler as sched
from .executor import ServiceEngine
from .metrics import ServiceMetrics
from .registry import BuildParams, TensorRegistry

DEFAULT_DEVICE_BUDGET = 256 << 20           # 256 MiB of admitted plan bytes


@dataclasses.dataclass
class SubmitDecomposition:
    """Request: decompose ``tensor`` at rank R (CP-ALS until converged/iters).

    ``tenant`` labels the job for per-tenant share accounting; ``weight``
    is its fair-share weight (a weight-2 tenant receives twice the ALS
    sweeps of a weight-1 tenant while both are active).
    """
    tensor: SparseTensor
    rank: int
    iters: int = 25
    tol: float = 1e-5
    seed: int = 0
    tenant: str = "default"
    weight: float = 1.0
    build: BuildParams = dataclasses.field(default_factory=BuildParams)
    reservation_nnz: int | None = None


@dataclasses.dataclass
class CancelJob:
    """Request: cancel a queued or running job (idempotent on final jobs)."""
    job_id: int


@dataclasses.dataclass
class CancelResult:
    """Response: what cancelling freed.  ``freed_bytes`` is the measured
    budget release (pooled share + per-job working set); 0 when the job was
    still queued or already final."""
    job_id: int
    cancelled: bool
    state: str
    freed_bytes: int


@dataclasses.dataclass
class SetWeight:
    """Request: re-weight one job, or every non-final job of a tenant."""
    weight: float
    job_id: int | None = None
    tenant: str | None = None


@dataclasses.dataclass
class WeightUpdate:
    """Response: which jobs now carry the new weight."""
    weight: float
    job_ids: tuple


@dataclasses.dataclass
class MTTKRPQuery:
    """Request: one mode-n MTTKRP against a (cached) tensor."""
    tensor: SparseTensor
    factors: list
    mode: int
    build: BuildParams = dataclasses.field(default_factory=BuildParams)
    reservation_nnz: int | None = None


@dataclasses.dataclass
class GetMetrics:
    """Request: the service-wide metrics snapshot.

    ``format="json"`` returns the ``ServiceMetrics.snapshot()`` dict;
    ``format="prometheus"`` returns the text exposition
    (:func:`repro.obs.export.render_prometheus`) an off-the-shelf
    Prometheus can scrape.
    """
    format: str = "json"          # "json" | "prometheus"


@dataclasses.dataclass
class GetTrace:
    """Request: the recorded span timeline as Chrome trace-event JSON.

    ``drain=True`` removes the returned spans from the ring buffer, so
    successive calls stream disjoint windows of the timeline.
    """
    drain: bool = False


@dataclasses.dataclass
class GetRoofline:
    """Request: the roofline attribution report over the bandwidth ledger.

    ``peaks`` maps tier-edge name (``disk_host`` / ``host_device`` /
    ``device_hbm``) to a measured peak GB/s; with ``peak_flops`` it turns
    achieved GB/s into achieved fractions and classifies each regime
    memory- vs compute-bound.  Without ceilings the report still carries
    bytes / seconds / GB/s per edge (classification ``"unknown"``).  The
    ledger must be enabled (``repro.obs.ledger.enable()``) for accounts
    to accumulate.
    """
    peaks: dict | None = None
    peak_flops: float | None = None


@dataclasses.dataclass
class GetSLO:
    """Request: per-tenant latency-SLO evaluation + burn rates.

    Evaluated over the scheduler's ``queue_wait_s``/``quantum_s``
    histograms, globally and per tenant.  ``slos`` overrides the
    objectives (a tuple of :class:`repro.obs.slo.SLO`); empty means
    :data:`repro.obs.slo.DEFAULT_SLOS`.
    """
    slos: tuple = ()


@dataclasses.dataclass
class JobStatus:
    """Response: where one job is in its lifecycle."""
    job_id: int
    state: str            # queued | running | done | failed | cancelled
    tensor_key: str
    iteration: int
    fit: float | None
    converged: bool
    queue_wait_s: float
    cache_hit: bool
    backend: str = ""            # engine regime ("in_memory" | "streamed" | "")
    tenant: str = "default"
    weight: float = 1.0
    error: str | None = None
    # quarantine explanation for FAILED jobs: type / message / where /
    # transient / injected (see scheduler._error_payload)
    error_payload: dict | None = None


@dataclasses.dataclass
class DecompositionResult:
    """Response: a finished decomposition + its cost accounting."""
    job_id: int
    tensor_key: str
    result: CPResult
    metrics: dict


class DecompositionService:
    """Multi-tenant decomposition service over pooled execution plans."""

    def __init__(self, *, device_budget_bytes: int = DEFAULT_DEVICE_BUDGET,
                 queues: int = 4, max_active: int | None = None,
                 kernel: str = "xla", store_dir: str | None = None,
                 host_budget_bytes: int | None = None):
        self.registry = TensorRegistry(store_dir=store_dir,
                                       host_budget_bytes=host_budget_bytes)
        self.engine = ServiceEngine(queues=queues, kernel=kernel)
        self.metrics = ServiceMetrics()
        self.scheduler = sched.JobScheduler(
            self.engine, device_budget_bytes=device_budget_bytes,
            max_active=max_active, metrics=self.metrics)

    @property
    def executor(self) -> ServiceEngine:
        """Deprecated PR-1 name for the service engine."""
        return self.engine

    # ------------------------------------------------------------- requests
    def submit(self, req: SubmitDecomposition) -> int:
        """Register (or cache-hit) the tensor and enqueue a CP-ALS job.

        A spilled/adopted tensor is reloaded to the host tier when the
        registry's host budget has room (restoring the in-memory fast
        path after restarts and evictions); under host pressure the stub
        stays and the job disk-streams from the store.
        """
        hits_before = self.registry.hits
        handle = self.registry.register(req.tensor, build=req.build,
                                        reservation_nnz=req.reservation_nnz)
        handle = self.registry.maybe_load(handle.key)
        self._sync_cache_counters()
        job_id = self.scheduler.submit(handle, rank=req.rank,
                                       iters=req.iters, tol=req.tol,
                                       seed=req.seed, weight=req.weight,
                                       tenant=req.tenant)
        self.scheduler.jobs[job_id].metrics.cache_hit = \
            self.registry.hits > hits_before
        return job_id

    def cancel(self, req: CancelJob | int) -> CancelResult:
        """Cancel a queued/running job; release its plan bytes immediately.

        Idempotent: cancelling a done/failed/cancelled job reports
        ``cancelled=False`` instead of raising.  Freed bytes re-run
        admission, so a waiting job can be admitted in the same call.
        """
        job_id = req.job_id if isinstance(req, CancelJob) else int(req)
        job = self._get_job(job_id)
        cancelled = self.scheduler.cancel(job_id)
        return CancelResult(job_id=job_id, cancelled=cancelled,
                            state=job.state,
                            freed_bytes=job.metrics.released_bytes
                            if cancelled else 0)

    def set_weight(self, req: SetWeight) -> WeightUpdate:
        """Apply a fair-share weight to one job or a whole tenant.

        Takes effect at the next scheduling quantum (between ALS sweeps):
        a demoted tenant keeps its resumable ``CPState``, it is simply
        picked less often from now on.
        """
        if (req.job_id is None) == (req.tenant is None):
            raise ValueError("SetWeight targets exactly one of job_id or "
                             "tenant")
        if req.job_id is not None:
            ids = [self._get_job(req.job_id).job_id]
        else:
            # a tenant whose jobs all finished between the caller's decision
            # and this call is a no-op, not an error: under the async
            # runtime the caller cannot win that race from outside the lock
            ids = [j.job_id for j in self.scheduler.jobs.values()
                   if j.tenant == req.tenant
                   and j.state not in sched.TERMINAL_STATES]
        for job_id in ids:
            self.scheduler.set_weight(job_id, req.weight)
        return WeightUpdate(weight=float(req.weight), job_ids=tuple(ids))

    def mttkrp(self, query: MTTKRPQuery):
        """One-shot MTTKRP (registers/caches the tensor first).

        Runs through an engine plan under the same measured admission
        budget as jobs: the engine picks device-resident or streamed for
        the query, and the plan is closed (its bytes released) afterwards.
        """
        if not 0 <= query.mode < query.tensor.order:
            raise ValueError(f"mode {query.mode} out of range for "
                             f"order-{query.tensor.order} tensor")
        handle = self.registry.register(query.tensor, build=query.build,
                                        reservation_nnz=query.reservation_nnz)
        handle = self.registry.maybe_load(handle.key)
        self._sync_cache_counters()
        rank = query.factors[0].shape[1]
        remaining = self.scheduler.device_budget_bytes \
            - self.metrics.admitted_reservation_bytes
        plan = self.engine.try_plan(handle, rank=rank,
                                    dtype=query.factors[0].dtype,
                                    budget_remaining=remaining)
        if plan is None:
            raise ValueError(
                f"query does not fit the device budget: needs "
                f"{self.engine.min_cost(handle, rank)} B but only "
                f"{remaining} B of {self.scheduler.device_budget_bytes} B "
                f"remain unadmitted")
        self.metrics.hold_bytes(plan.device_bytes())
        try:
            return plan.mttkrp(query.factors, query.mode)
        finally:
            self.metrics.hold_bytes(-plan.close())

    # --------------------------------------------------------------- driving
    def step(self) -> bool:
        """One weighted fair-share quantum; True while work remains."""
        return self.scheduler.step()

    def run(self) -> dict[int, DecompositionResult]:
        """Drive every submitted job to completion; return finished results."""
        self.scheduler.run()
        return {job_id: self.result(job_id)
                for job_id, job in self.scheduler.jobs.items()
                if job.state == sched.DONE}

    # ---------------------------------------------------------------- status
    def _get_job(self, job_id: int) -> sched.Job:
        job = self.scheduler.jobs.get(job_id)
        if job is None:
            known = sorted(self.scheduler.jobs)
            known_desc = f"known ids: {known[0]}..{known[-1]}" if known \
                else "no jobs submitted yet"
            raise ValueError(f"unknown job id {job_id!r}; {known_desc}")
        return job

    def status(self, job_id: int) -> JobStatus:
        job = self._get_job(job_id)
        return JobStatus(
            job_id=job.job_id, state=job.state, tensor_key=job.handle.key,
            iteration=job.cp.iteration if job.cp is not None else 0,
            fit=job.fit,
            converged=bool(job.cp is not None and job.cp.converged),
            queue_wait_s=job.metrics.queue_wait_s,
            cache_hit=job.metrics.cache_hit,
            backend=job.metrics.backend, tenant=job.tenant,
            weight=job.weight, error=job.error,
            error_payload=job.error_payload)

    def result(self, job_id: int) -> DecompositionResult:
        job = self._get_job(job_id)
        if job.state != sched.DONE:
            raise ValueError(f"job {job_id} is {job.state}, not done")
        return DecompositionResult(
            job_id=job_id, tensor_key=job.handle.key,
            result=job.cp.as_result(), metrics=job.metrics.snapshot())

    def service_metrics(self) -> dict[str, Any]:
        self._sync_cache_counters()
        return self.metrics.snapshot()

    def get_metrics(self, req: GetMetrics | None = None):
        """Service metrics in the requested format (see ``GetMetrics``)."""
        req = req if req is not None else GetMetrics()
        self._sync_cache_counters()
        if req.format == "prometheus":
            return render_prometheus(self.metrics)
        if req.format == "json":
            return self.metrics.snapshot()
        raise ValueError(f"unknown metrics format {req.format!r}; "
                         f"expected 'json' or 'prometheus'")

    def get_roofline(self, req: GetRoofline | None = None) -> dict:
        """Roofline attribution from the bandwidth ledger (``GetRoofline``):
        achieved GB/s per tier edge, arithmetic intensity and bound
        classification per regime, saturated edge per regime."""
        req = req if req is not None else GetRoofline()
        return obs_roofline.roofline_report(peaks=req.peaks,
                                            peak_flops=req.peak_flops)

    def get_slo(self, req: GetSLO | None = None) -> dict:
        """Latency-SLO evaluation over the scheduler hists (``GetSLO``):
        good fraction, met/violated, and burn rate — globally and per
        tenant."""
        req = req if req is not None else GetSLO()
        slos = req.slos if req.slos else obs_slo.DEFAULT_SLOS
        return obs_slo.slo_report(self.metrics.hist, slos=slos)

    def trace(self, req: GetTrace | None = None) -> dict:
        """Recorded spans as Chrome trace-event JSON (see ``GetTrace``).

        Load the returned dict (or its ``json.dump``) in
        https://ui.perfetto.dev to see the service's pipeline timeline —
        one track per stage (scheduler / plan / store / h2d / dispatch /
        device / registry).  Tracing must be enabled (``repro.obs.enable``
        or ``ServiceRuntime(tracing=True)``) for spans to be recorded.
        """
        req = req if req is not None else GetTrace()
        spans = obs_trace.drain() if req.drain else None
        return chrome_trace(spans)

    # ------------------------------------------------------------ persistence
    def snapshot(self, path: str) -> dict:
        """Write a restartable snapshot (registry + job CPState) to ``path``.

        Requires ``store_dir`` (the registry's spill store holds the
        tensors; the snapshot holds only the manifest and checkpoints).
        """
        from repro.store import snapshot_service
        manifest = snapshot_service(self, path)
        self._sync_cache_counters()
        return manifest

    @classmethod
    def restore(cls, path: str, **service_kwargs) -> "DecompositionService":
        """A fresh service resuming every snapshotted job under its
        original id (tensors adopt from the spill store, no BLCO rebuild)."""
        from repro.store import restore_service
        service = cls(**service_kwargs)
        restore_service(path, service)
        service._sync_cache_counters()
        return service

    def _sync_cache_counters(self) -> None:
        self.metrics.blco_cache_hits = self.registry.hits
        self.metrics.blco_cache_misses = self.registry.misses
        self.metrics.blco_disk_hits = self.registry.disk_hits
        self.metrics.spills = self.registry.spills
        self.metrics.spill_bytes_total = self.registry.spill_bytes
        self.metrics.loads = self.registry.loads
        self.metrics.store_rebuilds = self.registry.rebuilds
        self.metrics.host_budget_used_bytes = self.registry.host_bytes()
