"""ServiceEngine: pooled ExecutionPlans for registered tensors.

The multi-tenant restatement of ``repro.engine``'s regime decision.  Two
pools back the plans it hands out:

* **reservation pool** — jobs whose tensors pad to the same
  ``ReservationSpec`` stream through identical device buffer shapes, so
  they hit the same compiled ``launch_mttkrp`` executable and the budget is
  charged once per pooled shape (the paper's reused queue reservations,
  shared across tenants).  Both streaming *tiers* join this pool: host-
  resident tensors (``PooledStreamedPlan``) and spilled, disk-resident
  ones (``PooledDiskStreamedPlan``) — the store pads launches to the same
  power-of-two reservations;
* **residency pool** — jobs on the same registered tensor whose BLCO fits
  the remaining budget share ONE device-resident copy (``DeviceBLCO``),
  skipping per-iteration H2D entirely — the device-resident fast path
  under the same admission accounting.

Each admitted job gets its *own* plan object (own ``EngineStats``) over the
shared pooled state; ``plan.device_bytes()`` reports the bytes that plan
newly holds against the budget: its private rank-R factor working set
(charged per job on EVERY branch — it is never pooled) plus the pooled
tensor state (charged once, when the plan created the pool entry).
``plan.close()`` returns the bytes freed (working set + the full pooled
entry when the last sharer leaves) — so summing charges and frees over any
admission order nets to zero.  Plans pin their ``TensorHandle`` for their
lifetime, which blocks registry eviction of in-use tensors.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.mttkrp import DeviceBLCO
from repro.core.streaming import ReservationSpec
from repro.faults import inject as faults
from repro.engine.api import factor_bytes, in_memory_bytes
from repro.engine.plans import InMemoryPlan, StreamedPlan
from repro.obs import ledger as obs_ledger
from repro.store import DiskStreamedPlan

from .registry import TensorHandle


@dataclasses.dataclass
class PoolEntry:
    spec: ReservationSpec
    refcount: int = 0
    launches: int = 0


@dataclasses.dataclass
class ResidentEntry:
    key: str
    device: DeviceBLCO
    bytes: int
    refcount: int = 0


class PooledStreamedPlan(StreamedPlan):
    """A per-job streamed plan over a pooled reservation shape."""

    def __init__(self, engine: "ServiceEngine", handle: TensorHandle,
                 held_bytes: int, working_bytes: int = 0):
        super().__init__(handle.blco, queues=engine.queues, spec=handle.spec,
                         chunks=handle.chunks, kernel=engine.kernel)
        self._engine = engine
        self._handle = handle
        self._held = held_bytes
        self._working = working_bytes       # per-job factor set, never pooled

    def device_bytes(self) -> int:
        """Bytes this plan newly holds against the budget: its per-job
        factor working set plus the pooled reservation (0 when the
        reservation shape was already pooled by another tenant)."""
        return 0 if self._closed else self._held + self._working

    def close(self) -> int:
        if self._closed:
            return 0
        self._closed = True
        self._chunks = None                 # handle keeps its own reference
        self._handle.unpin()
        return self._engine._release_stream(self.spec) + self._working


class PooledDiskStreamedPlan(DiskStreamedPlan):
    """A per-job disk-streamed plan over a pooled reservation shape.

    Spilled tensors stream mmap'd store chunks straight to the device;
    because the store pads launches to the same power-of-two reservation
    the host-streaming regime uses, the plan joins the SAME stream pool
    (and compiled executable) as host-streamed plans of that spec.
    """

    def __init__(self, engine: "ServiceEngine", handle: TensorHandle,
                 held_bytes: int, working_bytes: int = 0):
        super().__init__(handle.open_stored(), queues=engine.queues,
                         spec=handle.spec, kernel=engine.kernel)
        self._engine = engine
        self._handle = handle
        self._held = held_bytes
        self._working = working_bytes       # per-job factor set, never pooled

    def device_bytes(self) -> int:
        return 0 if self._closed else self._held + self._working

    def close(self) -> int:
        if self._closed:
            return 0
        super().close()
        self._handle.unpin()
        return self._engine._release_stream(self.spec) + self._working


class PooledInMemoryPlan(InMemoryPlan):
    """A per-job device-resident plan over a pooled DeviceBLCO copy."""

    def __init__(self, engine: "ServiceEngine", handle: TensorHandle,
                 entry: ResidentEntry, held_bytes: int,
                 working_bytes: int = 0):
        super().__init__(handle.blco, device=entry.device, owns_device=False,
                         kernel=engine.kernel)
        self._engine = engine
        self._handle = handle
        self._entry = entry
        self._held = held_bytes
        self._working = working_bytes       # per-job factor set, never pooled
        if held_bytes:                      # this plan paid for the upload
            self._stats.h2d_bytes += held_bytes
            if obs_ledger.LEDGER.enabled:
                # mirror of the stats line above: bytes, zero seconds
                obs_ledger.record(obs_ledger.HOST_DEVICE, held_bytes, 0.0,
                                  regime=self.backend)

    def device_bytes(self) -> int:
        return 0 if self._dev is None else self._held + self._working

    def close(self) -> int:
        if self._dev is None:
            return 0
        self._dev = None
        self._handle.unpin()
        return self._engine._release_resident(self._entry.key) + self._working


class ServiceEngine:
    """Plans pooled execution for registered tensors under one device budget."""

    def __init__(self, *, queues: int = 4, kernel: str = "xla"):
        self.queues = queues
        self.kernel = kernel
        self._stream_pool: dict[ReservationSpec, PoolEntry] = {}
        self._resident_pool: dict[str, ResidentEntry] = {}

    # --------------------------------------------------------------- costs
    def streamed_cost(self, handle: TensorHandle) -> int:
        """Bytes a streamed plan for this handle would newly hold."""
        if handle.spec in self._stream_pool:
            return 0
        return handle.spec.bytes_in_flight(self.queues)

    def resident_cost(self, handle: TensorHandle) -> int:
        """Bytes a device-resident plan for this handle would newly hold."""
        if handle.key in self._resident_pool:
            return 0
        return in_memory_bytes(handle.blco)

    def min_cost(self, handle: TensorHandle, rank: int, dtype=jnp.float32) -> int:
        """Cheapest unpooled device need (the can-never-fit check).

        Every regime keeps the rank-R factor working set resident alongside
        the tensor state, so it is part of the need either way.  A spilled
        handle's only regime is (disk-)streaming, and both streaming tiers
        share the reservation cost.
        """
        working = factor_bytes(handle.dims, rank, dtype)
        stream = handle.spec.bytes_in_flight(self.queues)
        if not handle.resident:
            return working + stream
        return working + min(stream, in_memory_bytes(handle.blco))

    # ---------------------------------------------------------------- plans
    def try_plan(self, handle: TensorHandle, *, rank: int,
                 dtype=jnp.float32, budget_remaining: int):
        """The pooled regime decision: an ExecutionPlan, or None to wait.

        Every branch charges the per-job rank-R factor working set: it is
        private to the job (factors + accumulator live on device for the
        job's whole run) and is NEVER pooled, so joining an existing
        resident copy or a pooled reservation still costs ``working`` bytes.
        Device-resident when the pooled residency cost plus the working set
        fits what is left of the budget (joining an existing copy makes the
        pooled part free and strictly better than streaming); streamed when
        the (pooled) reservation plus the working set fits; None when
        neither does.  A SPILLED handle admits straight from the store —
        disk-streamed through the same pooled reservation shapes, without
        ever reloading the tensor into host memory.

        Degradation ladder: a device-allocation failure while
        materializing the resident copy demotes the job to the streamed
        tier, and a failure there demotes to disk-streaming when the
        handle has a persistent copy — each demotion recorded in the
        plan's ``EngineStats.demotions`` (the scheduler rolls it up into
        ``demotions_total`` at admission).  Non-allocation errors
        propagate; the pool joins below are exception-safe, so a failed
        rung never leaks a pin or a pool refcount.
        """
        from repro.analysis.sanitize import wrap_plan
        working = factor_bytes(handle.dims, rank, dtype)
        if not handle.resident:
            if self.streamed_cost(handle) + working <= budget_remaining:
                return wrap_plan(self._plan_disk(handle, working))
            return None
        demotions = 0
        rc = self.resident_cost(handle)
        if rc + working <= budget_remaining:
            try:
                return wrap_plan(self._plan_resident(handle, working))
            except Exception as exc:    # noqa: BLE001 — classified below
                if not faults.is_alloc_failure(exc):
                    raise
                demotions += 1
        sc = self.streamed_cost(handle)
        if sc + working <= budget_remaining:
            try:
                plan = self._plan_streamed(handle, working)
            except Exception as exc:    # noqa: BLE001 — classified below
                if not (faults.is_alloc_failure(exc)
                        and handle.store_path is not None):
                    raise
                demotions += 1
                plan = self._plan_disk(handle, working)
            plan.stats().demotions += demotions
            return wrap_plan(plan)
        return None

    def _plan_resident(self, handle: TensorHandle,
                       working: int = 0) -> PooledInMemoryPlan:
        # the DeviceBLCO upload happens BEFORE the pool entry exists, so a
        # failed allocation (the ladder's demotion trigger) leaves both the
        # pool and the handle's pin count untouched
        entry = self._resident_pool.get(handle.key)
        held = 0
        if entry is None:
            device = DeviceBLCO(handle.blco, kernel=self.kernel)
            entry = ResidentEntry(key=handle.key, device=device,
                                  bytes=device.device_bytes())
            self._resident_pool[handle.key] = entry
            held = entry.bytes
        entry.refcount += 1
        handle.pin()
        try:
            return PooledInMemoryPlan(self, handle, entry, held, working)
        except BaseException:
            handle.unpin()
            self._release_resident(handle.key)
            raise

    def _join_stream_pool(self, handle: TensorHandle) -> int:
        """Join (or create) the pooled reservation entry for ``handle``;
        pins the handle and returns the bytes newly charged (0 on join)."""
        entry = self._stream_pool.get(handle.spec)
        held = 0
        if entry is None:
            entry = self._stream_pool[handle.spec] = PoolEntry(spec=handle.spec)
            held = handle.spec.bytes_in_flight(self.queues)
        entry.refcount += 1
        handle.pin()
        return held

    def _abort_stream_join(self, handle: TensorHandle) -> None:
        """Undo a ``_join_stream_pool`` whose plan construction failed."""
        handle.unpin()
        self._release_stream(handle.spec)

    def _plan_streamed(self, handle: TensorHandle,
                       working: int = 0) -> PooledStreamedPlan:
        faults.maybe_fail("plan.alloc")
        held = self._join_stream_pool(handle)
        try:
            return PooledStreamedPlan(self, handle, held, working)
        except BaseException:
            self._abort_stream_join(handle)
            raise

    def _plan_disk(self, handle: TensorHandle,
                   working: int = 0) -> PooledDiskStreamedPlan:
        """Disk-streamed plan joining the same reservation pool as streamed.

        ``open_stored`` in the plan constructor touches the store file; a
        corrupt or missing file must not strand the pool refcount/pin it
        just took — the join is rolled back before the error propagates.
        """
        held = self._join_stream_pool(handle)
        try:
            return PooledDiskStreamedPlan(self, handle, held, working)
        except BaseException:
            self._abort_stream_join(handle)
            raise

    # ------------------------------------------------------------- releases
    def _release_stream(self, spec: ReservationSpec) -> int:
        entry = self._stream_pool[spec]
        entry.refcount -= 1
        if entry.refcount == 0:
            del self._stream_pool[spec]
            return spec.bytes_in_flight(self.queues)
        return 0

    def _release_resident(self, key: str) -> int:
        entry = self._resident_pool[key]
        entry.refcount -= 1
        if entry.refcount == 0:
            del self._resident_pool[key]
            entry.device.delete()
            return entry.bytes
        return 0

    # ------------------------------------------------------------ introspect
    def pooled_bytes(self) -> int:
        """Device bytes currently held across both pools."""
        return sum(spec.bytes_in_flight(self.queues)
                   for spec in self._stream_pool) \
            + sum(e.bytes for e in self._resident_pool.values())

    @property
    def pool_size(self) -> int:
        """Number of pooled streaming reservation shapes."""
        return len(self._stream_pool)

    @property
    def resident_count(self) -> int:
        """Number of pooled device-resident tensor copies."""
        return len(self._resident_pool)


# Deprecated name from PR 1; the pooled executor grew into the service's
# MTTKRPEngine.  Kept so external callers keep importing.
PooledExecutor = ServiceEngine
