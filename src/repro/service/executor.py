"""Pooled reservation executor: fixed launch-buffer shapes shared by tenants.

Refactored out of ``core/streaming.py``: the single-tensor ``OOMExecutor``
owns one reservation; here a *pool* of reservation shapes serves every
admitted job. Two jobs whose tensors pad to the same ``ReservationSpec``
stream through identical device buffer shapes, so they hit the same
compiled ``launch_mttkrp`` executable (jit caches on shapes + static args)
and the scheduler charges the device budget once per pooled shape, not once
per job — the multi-tenant generalization of the paper's reused queue
reservations.
"""
from __future__ import annotations

import dataclasses

from repro.core.mttkrp import DEFAULT_COPIES
from repro.core.streaming import ReservationSpec, StreamStats, stream_mttkrp

from .registry import TensorHandle


@dataclasses.dataclass
class PoolEntry:
    spec: ReservationSpec
    refcount: int = 0
    launches: int = 0


class PooledExecutor:
    """Streams any registered tensor through a shared reservation pool."""

    def __init__(self, *, queues: int = 4):
        self.queues = queues
        self._pool: dict[ReservationSpec, PoolEntry] = {}

    # ------------------------------------------------------ pool accounting
    def acquire(self, handle: TensorHandle) -> int:
        """Take a reference on the handle's reservation shape.

        Returns the device bytes newly held (0 when the shape is already
        pooled — the paper's fixed reservations are shape-keyed, so a second
        tenant on an existing shape is free).
        """
        entry = self._pool.get(handle.spec)
        if entry is None:
            entry = self._pool[handle.spec] = PoolEntry(spec=handle.spec)
        entry.refcount += 1
        if entry.refcount == 1:
            return handle.spec.bytes_in_flight(self.queues)
        return 0

    def release(self, handle: TensorHandle) -> int:
        """Drop a reference; returns device bytes freed (0 if still shared)."""
        entry = self._pool[handle.spec]
        entry.refcount -= 1
        if entry.refcount == 0:
            del self._pool[handle.spec]
            return handle.spec.bytes_in_flight(self.queues)
        return 0

    def pooled_bytes(self) -> int:
        """Device bytes currently reserved across all pooled shapes."""
        return sum(spec.bytes_in_flight(self.queues) for spec in self._pool)

    def reservation_bytes(self, handle: TensorHandle) -> int:
        """Bytes admitting this handle would add to the pool."""
        if handle.spec in self._pool:
            return 0
        return handle.spec.bytes_in_flight(self.queues)

    @property
    def pool_size(self) -> int:
        return len(self._pool)

    # ------------------------------------------------------------- compute
    def mttkrp(self, handle: TensorHandle, factors, mode: int, *,
               resolution: str = "auto", copies: int = DEFAULT_COPIES,
               stats: StreamStats | None = None):
        """Streamed mode-n MTTKRP for one registered tensor.

        ``stats`` is the caller's (per-job) accounting object; pool-wide
        launch counts are kept on the entry.
        """
        entry = self._pool.get(handle.spec)
        if entry is None or entry.refcount <= 0:
            raise RuntimeError("handle not admitted to the pool "
                               "(scheduler admission must acquire() first)")
        stats = stats if stats is not None else StreamStats()
        before = stats.launches
        out = stream_mttkrp(handle.chunks, handle.blco, factors, mode,
                            queues=self.queues, resolution=resolution,
                            copies=copies, stats=stats)
        entry.launches += stats.launches - before
        return out
