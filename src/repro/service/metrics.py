"""Per-job and service-wide counters.

Every admitted job references its plan's ``EngineStats`` (the unified
engine counters: H2D bytes, launches, dispatch vs fenced device time), plus
queue timestamps; the service aggregates across jobs and tracks the
measured plan bytes the scheduler holds against the device budget.

Beyond the scalar totals, ``ServiceMetrics`` carries a
:class:`~repro.obs.hist.ServiceHists` bundle: scheduler distributions
(queue wait, quantum duration) recorded live, and the engine
distributions of retired jobs rolled up losslessly at retirement.
Throughput is reported over **busy time** (the summed duration of
executed scheduler quanta), not wall-clock since construction — an idle
service does not decay its measured rate.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.streaming import EngineStats
from repro.obs.hist import ServiceHists


@dataclasses.dataclass
class JobMetrics:
    submitted_s: float = dataclasses.field(default_factory=time.perf_counter)
    admitted_s: float | None = None
    completed_s: float | None = None
    iterations: int = 0
    cache_hit: bool = False
    backend: str = ""                    # which regime the engine chose
    released_bytes: int = 0              # budget bytes freed at retirement
    stats: EngineStats = dataclasses.field(default_factory=EngineStats)

    @property
    def queue_wait_s(self) -> float:
        """Time from submission until admission — or until the job left the
        queue for a terminal state without ever being admitted (cancelled
        while queued), so the value freezes at retirement."""
        if self.admitted_s is not None:
            end = self.admitted_s
        elif self.completed_s is not None:
            end = self.completed_s
        else:
            end = time.perf_counter()
        return end - self.submitted_s

    @property
    def run_time_s(self) -> float | None:
        if self.admitted_s is None or self.completed_s is None:
            return None
        return self.completed_s - self.admitted_s

    def snapshot(self) -> dict:
        return {
            "iterations": self.iterations,
            "queue_wait_s": self.queue_wait_s,
            "run_time_s": self.run_time_s,
            "cache_hit": self.cache_hit,
            "backend": self.backend,
            "released_bytes": self.released_bytes,
            "h2d_bytes": self.stats.h2d_bytes,
            "disk_bytes": self.stats.disk_bytes,
            "mttkrp_calls": self.stats.mttkrp_calls,
            "launches": self.stats.launches,
            "put_time_s": self.stats.put_time_s,
            "disk_time_s": self.stats.disk_time_s,
            "dispatch_time_s": self.stats.dispatch_time_s,
            "device_time_s": self.stats.device_time_s,
            "retries": self.stats.retries,
            "giveups": self.stats.giveups,
            "demotions": self.stats.demotions,
            "hist": self.stats.hist.snapshot(),
        }


@dataclasses.dataclass
class ServiceMetrics:
    started_s: float = dataclasses.field(default_factory=time.perf_counter)
    jobs_submitted: int = 0
    jobs_admitted: int = 0
    jobs_completed: int = 0
    jobs_failed: int = 0
    jobs_cancelled: int = 0
    preemptions: int = 0                 # weight demotions of running jobs
    cancel_freed_bytes_total: int = 0    # budget bytes freed by cancel()
    blco_cache_hits: int = 0
    blco_cache_misses: int = 0
    blco_disk_hits: int = 0              # registrations served off the store
    spills: int = 0                      # host -> disk evictions (LRU/manual)
    spill_bytes_total: int = 0           # host bytes freed by spilling
    loads: int = 0                       # disk -> host reloads (un-spills)
    store_rebuilds: int = 0              # corrupt store files self-healed
    jobs_restored: int = 0               # jobs resumed from a snapshot
    retries_total: int = 0               # transient faults absorbed by retry
    giveups_total: int = 0               # retry budgets exhausted
    demotions_total: int = 0             # degradation-ladder rungs taken
    watchdog_restarts: int = 0           # worker threads revived after crash
    iterations_total: int = 0
    h2d_bytes_total: int = 0
    disk_bytes_total: int = 0            # store->host traffic of retired jobs
    disk_time_s_total: float = 0.0
    launches_total: int = 0
    # summed duration of executed scheduler quanta — the throughput
    # denominator (wall-clock minus idle/queue-empty time)
    busy_time_s: float = 0.0
    # live scheduler gauges, synced on every lifecycle edge
    queue_depth: int = 0
    running_jobs: int = 0
    host_budget_used_bytes: int = 0      # registry host-tier residency
    # executed ALS sweeps per tenant: the observable the weighted fair
    # share is measured by (share_i ~ weight_i / sum(weights))
    tenant_iterations: dict = dataclasses.field(default_factory=dict)
    # measured plan bytes currently held vs the budget (the name predates
    # the engine API, when only reservations were charged; kept for compat)
    admitted_reservation_bytes: int = 0
    peak_admitted_reservation_bytes: int = 0
    hist: ServiceHists = dataclasses.field(default_factory=ServiceHists)

    def hold_bytes(self, delta: int) -> None:
        self.admitted_reservation_bytes += delta
        self.peak_admitted_reservation_bytes = max(
            self.peak_admitted_reservation_bytes,
            self.admitted_reservation_bytes)

    def record_iteration(self, tenant: str) -> None:
        self.tenant_iterations[tenant] = \
            self.tenant_iterations.get(tenant, 0) + 1

    def tenant_shares(self) -> dict:
        """Fraction of all executed iterations each tenant received."""
        total = sum(self.tenant_iterations.values())
        if not total:
            return {}
        return {t: n / total for t, n in self.tenant_iterations.items()}

    @property
    def uptime_s(self) -> float:
        """Wall-clock seconds since the metrics object was constructed."""
        return time.perf_counter() - self.started_s

    def iterations_per_sec(self) -> float:
        """Executed ALS sweeps per second of *busy* time.

        The denominator is the summed duration of executed scheduler
        quanta, not wall-clock since construction, so the rate measures
        the service's actual sweep throughput and does not decay while
        the queue is empty.  (The old wall-clock version made an idle
        service look progressively slower.)
        """
        if self.busy_time_s > 0:
            return self.iterations_total / self.busy_time_s
        return 0.0

    def snapshot(self) -> dict:
        return {
            "jobs_submitted": self.jobs_submitted,
            "jobs_admitted": self.jobs_admitted,
            "jobs_completed": self.jobs_completed,
            "jobs_failed": self.jobs_failed,
            "jobs_cancelled": self.jobs_cancelled,
            "preemptions": self.preemptions,
            "cancel_freed_bytes_total": self.cancel_freed_bytes_total,
            "blco_cache_hits": self.blco_cache_hits,
            "blco_cache_misses": self.blco_cache_misses,
            "blco_disk_hits": self.blco_disk_hits,
            "spills": self.spills,
            "spill_bytes_total": self.spill_bytes_total,
            "loads": self.loads,
            "store_rebuilds": self.store_rebuilds,
            "jobs_restored": self.jobs_restored,
            "retries_total": self.retries_total,
            "giveups_total": self.giveups_total,
            "demotions_total": self.demotions_total,
            "watchdog_restarts": self.watchdog_restarts,
            "iterations_total": self.iterations_total,
            "iterations_per_sec": self.iterations_per_sec(),
            "h2d_bytes_total": self.h2d_bytes_total,
            "disk_bytes_total": self.disk_bytes_total,
            "disk_time_s_total": self.disk_time_s_total,
            "launches_total": self.launches_total,
            "busy_time_s": self.busy_time_s,
            "uptime_s": self.uptime_s,
            "queue_depth": self.queue_depth,
            "running_jobs": self.running_jobs,
            "host_budget_used_bytes": self.host_budget_used_bytes,
            "tenant_iterations": dict(self.tenant_iterations),
            "tenant_shares": self.tenant_shares(),
            "admitted_reservation_bytes": self.admitted_reservation_bytes,
            "peak_admitted_reservation_bytes":
                self.peak_admitted_reservation_bytes,
            "hist": self.hist.snapshot(),
            "tenant_hist": self.hist.tenant_snapshot(),
        }
