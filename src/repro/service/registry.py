"""Tensor registry: a two-tier BLCO cache keyed by content fingerprint.

BLCO's defining property (paper §4.2) is that ONE tensor copy serves every
mode and every decomposition run. In a multi-tenant service that property
compounds: any number of jobs on the same tensor share one BLCO build, one
reservation shape, and (via the pooled executor) one compiled executable
per shape. The cache key is a content fingerprint (dims + coordinates +
values) combined with the build parameters, so a re-submitted tensor —
even a different ``SparseTensor`` object with identical content — is a
hit, while changing ``target_bits`` or the blocking budget correctly
misses.

The registry is **two-tier** (host ⊃ disk).  With a ``store_dir``, handles
can be *spilled*: the BLCO is written to the persistent store
(``repro.store``) and the host arrays dropped, leaving a stub handle that
jobs disk-stream from (or explicitly ``load`` back).  With a
``host_budget_bytes``, spilling is automatic: an LRU policy (least
recently ``get``/registered first, pin-refcount-aware) keeps resident
host bytes under the budget.  Because store files are named by
fingerprint, a RESTARTED process re-registers the same tensor as a cache
hit straight off disk — no BLCO rebuild — which is what makes service
snapshots restart-safe.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import weakref

import numpy as np

from repro.core.blco import BLCOTensor, build_blco, format_bytes
from repro.core.streaming import (LaunchChunks, ReservationSpec,
                                  reservation_for)
from repro.core.tensor import SparseTensor
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class BuildParams:
    """BLCO construction parameters (see ``core.build_blco``)."""
    target_bits: int = 64
    max_nnz_per_block: int = 1 << 27
    launch_nnz_budget: int | None = None


def fingerprint(t: SparseTensor, build: BuildParams,
                reservation_nnz: int | None = None) -> str:
    """Content hash of (dims, coordinates, values) + build params."""
    h = hashlib.sha256()
    h.update(np.asarray(t.dims, np.int64).tobytes())
    h.update(np.ascontiguousarray(t.indices).tobytes())
    h.update(np.ascontiguousarray(t.values).tobytes())
    h.update(str(t.values.dtype).encode())
    h.update(repr((build.target_bits, build.max_nnz_per_block,
                   build.launch_nnz_budget, reservation_nnz)).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class TensorHandle:
    """A registered tensor: the single shared copy every job streams from.

    Either host-resident (``blco``/``chunks`` set) or spilled to the store
    (``store_path`` set, ``blco is None``) — a stub that keeps only the
    O(1) metadata admission control needs.  ``chunks`` is a lazily padding
    :class:`~repro.core.streaming.LaunchChunks`; nothing is padded until a
    streaming plan actually pulls a launch.
    """
    key: str
    dims: tuple
    nnz: int
    norm_x: float                # Frobenius norm (CP-ALS fit denominator)
    blco: BLCOTensor | None
    spec: ReservationSpec        # padded launch-buffer shape
    chunks: LaunchChunks | None  # lazy reservation-padded launch source
    pins: int = 0                # live plans referencing blco/chunks/store
    store_path: str | None = None   # persistent copy (spill tier)
    last_used: int = 0           # registry LRU clock at last touch
    build: BuildParams | None = None    # rebuild recipe (self-heal)
    source_ref: weakref.ref | None = None  # weakref to the source COO
    quarantined: bool = False    # store copy corrupt + unrebuildable
    quarantine_reason: str | None = None

    def pin(self) -> None:
        """A plan now references this handle (blocks evict/spill)."""
        self.pins += 1

    def unpin(self) -> None:
        if self.pins <= 0:
            raise RuntimeError(f"unbalanced unpin of tensor {self.key}")
        self.pins -= 1

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def resident(self) -> bool:
        """True when the BLCO is host-resident (not just a disk stub)."""
        return self.blco is not None

    @property
    def host_bytes(self) -> int:
        """Host-resident bytes of this handle's tensor copy (0 if spilled)."""
        return format_bytes(self.blco) if self.blco is not None else 0

    @property
    def format_bytes(self) -> int:
        """True device footprint of the format (hi + lo + vals + bases).

        Computed from the metadata (nnz x per-element words) so it is
        available for spilled stubs too; equals
        ``core.format_bytes(self.blco)`` when resident.
        """
        return self.nnz * (4 + 4 + self.spec.value_itemsize + 4 * self.order)

    @property
    def in_memory_bytes(self) -> int:
        """Predicted device bytes of a resident (InMemoryPlan) copy."""
        if self.blco is None:
            raise RuntimeError(
                f"tensor {self.key} is spilled to disk; load() it before "
                f"planning a device-resident copy")
        from repro.engine.api import in_memory_bytes
        return in_memory_bytes(self.blco)

    def open_stored(self):
        """Open the persistent copy for disk-streaming (caller closes)."""
        if self.store_path is None:
            raise RuntimeError(f"tensor {self.key} has no persistent copy")
        from repro.store import open_blco
        return open_blco(self.store_path)


class TensorRegistry:
    """Fingerprint-keyed two-tier cache of BLCO builds.

    ``store_dir``: directory of the persistent spill tier (files are
    ``<fingerprint>.blco``); enables ``spill``/``persist``/``adopt`` and
    restart-safe re-registration.  ``host_budget_bytes``: automatic LRU
    spilling — after every operation that grows the resident set, the
    least-recently-used unpinned handles are spilled until resident
    ``host_bytes()`` fits the budget.

    Thread-safe: the service runtime's worker thread and caller threads
    (submit paths, snapshot queries) reach the registry concurrently, so
    every method serializes on one internal re-entrant lock — re-entrant
    because the operations compose (``register`` → ``adopt`` /
    ``_maybe_spill`` → ``spill`` → ``persist``).  Lock ordering with the
    runtime is strictly runtime → registry; the registry never calls out.
    """

    def __init__(self, *, store_dir: str | None = None,
                 host_budget_bytes: int | None = None):
        self.store_dir = store_dir
        self.host_budget_bytes = host_budget_bytes
        self._lock = threading.RLock()
        self._cache: dict[str, TensorHandle] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0           # registrations served from the store
        self.spills = 0
        self.spill_bytes = 0         # host bytes freed by spilling
        self.loads = 0               # un-spills (store -> host reloads)
        self.rebuilds = 0            # corrupt store files healed from COO

    # ---------------------------------------------------------------- paths
    def _store_file(self, key: str) -> str:
        if self.store_dir is None:
            raise RuntimeError("registry has no store_dir; construct "
                               "TensorRegistry(store_dir=...) to enable "
                               "the spill tier")
        os.makedirs(self.store_dir, exist_ok=True)
        return os.path.join(self.store_dir, f"{key}.blco")

    def _touch(self, handle: TensorHandle) -> None:
        with self._lock:
            self._clock += 1
            handle.last_used = self._clock

    # ------------------------------------------------------------- register
    def register(self, t: SparseTensor, *,
                 build: BuildParams | None = None,
                 reservation_nnz: int | None = None) -> TensorHandle:
        build = build or BuildParams()
        key = fingerprint(t, build, reservation_nnz)
        with self._lock:
            handle = self._cache.get(key)
            if handle is not None:
                self.hits += 1
                self._touch(handle)
                return handle
            # restart path: the fingerprint names a store file written by a
            # previous process — adopt the stub instead of rebuilding the
            # BLCO.  A damaged file (crash mid-write on an old layout, bit
            # rot) must not brick registration while we hold the COO: fall
            # through to a rebuild, which re-persists over it on the next
            # spill.
            if self.store_dir is not None:
                path = os.path.join(self.store_dir, f"{key}.blco")
                if os.path.exists(path):
                    from repro.store import StoreError
                    try:
                        handle = self.adopt(key, path)
                    except StoreError:
                        pass
                    else:
                        self.hits += 1
                        self.disk_hits += 1
                        handle.build = build
                        handle.source_ref = weakref.ref(t)
                        return handle
            self.misses += 1
            blco = build_blco(t, target_bits=build.target_bits,
                              max_nnz_per_block=build.max_nnz_per_block,
                              launch_nnz_budget=build.launch_nnz_budget)
            spec = reservation_for(blco, reservation_nnz)
            handle = TensorHandle(
                key=key, dims=t.dims, nnz=t.nnz,
                norm_x=float(np.linalg.norm(t.values.astype(np.float64))),
                blco=blco, spec=spec, chunks=LaunchChunks(blco, spec.nnz),
                build=build, source_ref=weakref.ref(t))
            self._cache[key] = handle
            self._touch(handle)
            self._maybe_spill()
            return handle

    def adopt(self, key: str, path: str) -> TensorHandle:
        """Register a spilled stub straight from a store file (no COO, no
        build) — the restart/snapshot entry point.

        The file's section checksums are verified here, once, at adoption
        (plans opening it later skip the re-read): silently streaming
        bit-rotted values into every job on this tensor would be far
        worse than the one sequential read.  Corruption raises the typed
        ``StoreCorruptionError`` — which ``register`` turns into a
        rebuild when it still holds the COO.
        """
        with self._lock:
            handle = self._cache.get(key)
            if handle is not None:
                self._touch(handle)
                return handle
            from repro.store import open_blco
            with open_blco(path, verify=True) as stored:
                if stored.fingerprint is not None \
                        and stored.fingerprint != key:
                    from repro.store import StoreCorruptionError
                    raise StoreCorruptionError(
                        f"{path}: stored fingerprint {stored.fingerprint!r} "
                        f"does not match registry key {key!r}")
                handle = TensorHandle(
                    key=key, dims=stored.dims, nnz=stored.nnz,
                    norm_x=float(stored.norm_x or 0.0),
                    blco=None, spec=stored.spec, chunks=None,
                    store_path=path)
            self._cache[key] = handle
            self._touch(handle)
            return handle

    # ------------------------------------------------------------ spill tier
    def persist(self, key: str) -> str:
        """Ensure ``key`` has an up-to-date store file; returns its path.

        Keeps the host copy (unlike ``spill``) — this is the snapshot
        write path, safe to call on pinned handles.
        """
        with self._lock:
            handle = self._require(key)
            if handle.store_path is not None:
                return handle.store_path
            path = self._store_file(key)
            from repro.store import save_blco
            save_blco(handle.blco, path, reservation_nnz=handle.spec.nnz,
                      fingerprint=key, norm_x=handle.norm_x)
            handle.store_path = path
            return path

    def spill(self, key: str) -> int:
        """Write ``key``'s BLCO to the store and drop its host arrays.

        Returns the host bytes freed.  Refuses pinned handles (live plans
        hold the blco/chunks); a no-op (0) for already-spilled handles.
        """
        with self._lock:
            handle = self._require(key)
            if not handle.resident:
                return 0
            if handle.pins > 0:
                raise RuntimeError(
                    f"tensor {key} is pinned by {handle.pins} live plan(s); "
                    f"close them before spilling")
            with obs_trace.span("registry.spill", "registry", key=key,
                                nnz=handle.nnz) as sp:
                self.persist(key)
                freed = handle.host_bytes
                handle.blco = None
                handle.chunks = None
                self.spills += 1
                self.spill_bytes += freed
                sp.set(bytes=freed)
            return freed

    def maybe_load(self, key: str) -> TensorHandle:
        """Reload a spilled handle only when the host tier has room.

        The submit-path policy: a stub whose reload would fit the host
        budget comes back resident (so jobs regain the in-memory /
        host-streamed fast paths after a restart or an eviction), while
        a registry under genuine host pressure keeps the stub and lets
        jobs disk-stream — reloading there would just thrash the LRU.
        """
        with self._lock:
            handle = self._require(key)
            if handle.resident:
                return handle
            if self.host_budget_bytes is not None and \
                    self.host_bytes() + handle.format_bytes \
                    > self.host_budget_bytes:
                return handle
            return self.load(key)

    def load(self, key: str) -> TensorHandle:
        """Reload a spilled handle's BLCO from the store (un-spill).

        The reload reuses the stored build verbatim — same fingerprint,
        same blocks/launches/reservation, no re-construction — so a
        load-after-spill (or after a process restart) is bit-identical to
        the original registration.

        Self-heal: the reload verifies section checksums.  On corruption,
        when the source COO is still alive (``source_ref``), the BLCO is
        rebuilt from it with the recorded build params and re-persisted
        over the damaged file — bit-identical to the original build
        because ``build_blco`` is deterministic.  Without a live source
        the handle is *quarantined* (new jobs are refused with the
        reason) and the corruption error propagates.
        """
        with self._lock:
            handle = self._require(key)
            self._touch(handle)
            if handle.resident:
                return handle
            from repro.store import StoreCorruptionError, open_blco
            with obs_trace.span("registry.load", "registry", key=key,
                                nnz=handle.nnz):
                try:
                    with open_blco(handle.store_path, verify=True) as stored:
                        handle.blco = stored.to_blco()
                except StoreCorruptionError as exc:
                    self._self_heal(handle, exc)
                handle.chunks = LaunchChunks(handle.blco, handle.spec.nnz)
            self.loads += 1
            self._touch(handle)           # the reload makes it MRU
            self._maybe_spill(keep=handle)
            return handle

    def _self_heal(self, handle: TensorHandle,
                   exc: BaseException) -> None:
        """Corrupt store file: rebuild from the live COO or quarantine."""
        source = handle.source_ref() if handle.source_ref is not None \
            else None
        if source is None or handle.build is None:
            handle.quarantined = True
            handle.quarantine_reason = (
                f"store file {handle.store_path} failed verification and "
                f"no source tensor survives to rebuild from: {exc}")
            raise exc
        with obs_trace.span("registry.rebuild", "registry", key=handle.key,
                            nnz=handle.nnz, error=repr(exc)):
            build = handle.build
            blco = build_blco(source, target_bits=build.target_bits,
                              max_nnz_per_block=build.max_nnz_per_block,
                              launch_nnz_budget=build.launch_nnz_budget)
            from repro.store import save_blco
            save_blco(blco, handle.store_path,
                      reservation_nnz=handle.spec.nnz,
                      fingerprint=handle.key, norm_x=handle.norm_x)
            handle.blco = blco
            handle.quarantined = False
            handle.quarantine_reason = None
            self.rebuilds += 1

    def _maybe_spill(self, keep: TensorHandle | None = None) -> None:
        """LRU: spill least-recently-used unpinned handles over the budget.

        ``keep`` exempts a handle the caller just made resident on
        purpose (``load``): spilling it straight back would turn an
        explicit reload into wasted I/O — like the pinned case, the
        registry stays over budget instead.
        """
        if self.host_budget_bytes is None or self.store_dir is None:
            return
        with self._lock:
            while self.host_bytes() > self.host_budget_bytes:
                victims = sorted(
                    (h for h in self._cache.values()
                     if h.resident and h.pins == 0 and h is not keep),
                    key=lambda h: h.last_used)
                if not victims:
                    return       # everything resident is pinned; over-budget
                self.spill(victims[0].key)

    # ---------------------------------------------------------------- lookup
    def get(self, key: str) -> TensorHandle | None:
        with self._lock:
            handle = self._cache.get(key)
            if handle is not None:
                self._touch(handle)
            return handle

    def evict(self, key: str) -> bool:
        """Drop a cached handle entirely; refuses while any plan holds it.

        Streaming plans hold the handle's ``chunks`` (or store file) for
        their whole lifetime, so evicting a pinned handle would corrupt
        running jobs — the refcount turns that silent corruption into an
        error.  The store file, if any, is left on disk (it is the
        durable tier; delete it through the filesystem if truly unwanted).
        """
        with self._lock:
            handle = self._cache.get(key)
            if handle is None:
                return False
            if handle.pins > 0:
                raise RuntimeError(
                    f"tensor {key} is pinned by {handle.pins} live plan(s); "
                    f"close them before evicting")
            del self._cache[key]
            return True

    def _require(self, key: str) -> TensorHandle:
        with self._lock:
            handle = self._cache.get(key)
            if handle is None:
                raise KeyError(f"unknown tensor key {key!r}")
            return handle

    def __len__(self) -> int:
        with self._lock:
            return len(self._cache)

    def host_bytes(self) -> int:
        """Host-resident tensor bytes across all cached handles.

        Counts the BLCO's per-element footprint (hi + lo + vals + bases
        words) for resident handles; spilled stubs count 0 — their bytes
        live on disk.  Padded launch chunks are no longer materialized up
        front (``LaunchChunks`` pads lazily), so they do not appear here.
        """
        with self._lock:
            return sum(h.host_bytes for h in self._cache.values())

    def store_bytes(self) -> int:
        """Bytes of this registry's handles resident in the disk tier."""
        with self._lock:
            total = 0
            for h in self._cache.values():
                if h.store_path is not None and os.path.exists(h.store_path):
                    total += os.path.getsize(h.store_path)
            return total
