"""Tensor registry: a BLCO construction cache keyed by content fingerprint.

BLCO's defining property (paper §4.2) is that ONE tensor copy serves every
mode and every decomposition run. In a multi-tenant service that property
compounds: any number of jobs on the same tensor share one BLCO build, one
set of reservation-padded launch chunks, and (via the pooled executor) one
compiled executable per reservation shape. The cache key is a content
fingerprint (dims + coordinates + values) combined with the build
parameters, so a re-submitted tensor — even a different ``SparseTensor``
object with identical content — is a hit, while changing ``target_bits`` or
the blocking budget correctly misses.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.blco import BLCOTensor, build_blco, format_bytes
from repro.core.streaming import ReservationSpec, prepare_chunks, reservation_for
from repro.core.tensor import SparseTensor


@dataclasses.dataclass(frozen=True)
class BuildParams:
    """BLCO construction parameters (see ``core.build_blco``)."""
    target_bits: int = 64
    max_nnz_per_block: int = 1 << 27
    launch_nnz_budget: int | None = None


def fingerprint(t: SparseTensor, build: BuildParams,
                reservation_nnz: int | None = None) -> str:
    """Content hash of (dims, coordinates, values) + build params."""
    h = hashlib.sha256()
    h.update(np.asarray(t.dims, np.int64).tobytes())
    h.update(np.ascontiguousarray(t.indices).tobytes())
    h.update(np.ascontiguousarray(t.values).tobytes())
    h.update(str(t.values.dtype).encode())
    h.update(repr((build.target_bits, build.max_nnz_per_block,
                   build.launch_nnz_budget, reservation_nnz)).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class TensorHandle:
    """A registered tensor: the single shared copy every job streams from."""
    key: str
    dims: tuple
    nnz: int
    norm_x: float                # Frobenius norm (CP-ALS fit denominator)
    blco: BLCOTensor
    spec: ReservationSpec        # padded launch-buffer shape
    chunks: list                 # reservation-padded launch chunks (host)
    pins: int = 0                # live plans referencing blco/chunks

    def pin(self) -> None:
        """A plan now references this handle's blco/chunks (blocks evict)."""
        self.pins += 1

    def unpin(self) -> None:
        if self.pins <= 0:
            raise RuntimeError(f"unbalanced unpin of tensor {self.key}")
        self.pins -= 1

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def format_bytes(self) -> int:
        """True device footprint of the format (hi + lo + vals + bases)."""
        return format_bytes(self.blco)

    @property
    def in_memory_bytes(self) -> int:
        """Predicted device bytes of a resident (InMemoryPlan) copy."""
        from repro.engine.api import in_memory_bytes
        return in_memory_bytes(self.blco)


class TensorRegistry:
    """Fingerprint-keyed cache of BLCO builds + prepared launch chunks."""

    def __init__(self):
        self._cache: dict[str, TensorHandle] = {}
        self.hits = 0
        self.misses = 0

    def register(self, t: SparseTensor, *,
                 build: BuildParams | None = None,
                 reservation_nnz: int | None = None) -> TensorHandle:
        build = build or BuildParams()
        key = fingerprint(t, build, reservation_nnz)
        handle = self._cache.get(key)
        if handle is not None:
            self.hits += 1
            return handle
        self.misses += 1
        blco = build_blco(t, target_bits=build.target_bits,
                          max_nnz_per_block=build.max_nnz_per_block,
                          launch_nnz_budget=build.launch_nnz_budget)
        spec = reservation_for(blco, reservation_nnz)
        handle = TensorHandle(
            key=key, dims=t.dims, nnz=t.nnz,
            norm_x=float(np.linalg.norm(t.values.astype(np.float64))),
            blco=blco, spec=spec, chunks=prepare_chunks(blco, spec.nnz))
        self._cache[key] = handle
        return handle

    def get(self, key: str) -> TensorHandle | None:
        return self._cache.get(key)

    def evict(self, key: str) -> bool:
        """Drop a cached handle; refuses while any live plan references it.

        Streaming plans hold the handle's ``chunks`` for their whole
        lifetime, so evicting a pinned handle would corrupt running jobs —
        the refcount turns that silent corruption into an error (and makes
        an LRU policy over ``host_bytes()`` safe to build on top).
        """
        handle = self._cache.get(key)
        if handle is None:
            return False
        if handle.pins > 0:
            raise RuntimeError(
                f"tensor {key} is pinned by {handle.pins} live plan(s); "
                f"close them before evicting")
        del self._cache[key]
        return True

    def __len__(self) -> int:
        return len(self._cache)

    def host_bytes(self) -> int:
        """Host-resident bytes of all cached prepared chunks."""
        total = 0
        for h in self._cache.values():
            total += h.spec.bytes_per_launch * len(h.chunks)
        return total
