"""Asynchronous service runtime: a threaded driver over the job scheduler.

``DecompositionService`` is synchronous — callers block in ``run()`` until
every tenant finishes.  ``ServiceRuntime`` turns it into a *live* service:
a worker thread executes one scheduling quantum (one weighted-fair-share
ALS sweep, see ``scheduler.step``) at a time while callers submit, cancel,
re-weight, and observe jobs concurrently.  Control actions synchronise on
the quantum boundary — the lock is held exactly for one sweep — so
**preemption is between ALS sweeps by construction**: a cancel or weight
change never interrupts a sweep mid-flight and never corrupts ``CPState``.

Status is *streamed*, not polled: every lifecycle edge (queued, admitted,
done, failed, cancelled, weight change) and every completed iteration
publishes a :class:`JobEvent` snapshot (state, fit trajectory, per-job
metrics) to all subscribed feeds.  A :class:`StatusFeed` is a thread-safe
blocking iterator; :meth:`ServiceRuntime.stream` wraps one as an **async
iterator** for asyncio front-ends (e.g. a web gateway pushing server-sent
events per tenant).

    with ServiceRuntime(device_budget_bytes=...) as rt:
        a = rt.submit(SubmitDecomposition(...), )        # weight via request
        async for ev in rt.stream(a):                    # live fit trajectory
            ...
        rt.cancel(b)                                     # frees pooled bytes
        rt.drain()                                       # wait until idle
"""
from __future__ import annotations

import asyncio
import dataclasses
import os
import queue
import threading
import time

from repro.core.cp_als import cp_als_init

from . import scheduler as sched
from .api import (CancelJob, CancelResult, DecompositionResult,
                  DecompositionService, GetMetrics, GetTrace, JobStatus,
                  SetWeight, SubmitDecomposition, WeightUpdate)

_IDLE_POLL_S = 0.05         # worker re-check period while the queue is empty
_YIELD_S = 0.0005           # unlocked window between quanta (see _drive)


@dataclasses.dataclass(frozen=True)
class JobEvent:
    """One streamed status snapshot of one job.

    ``kind`` is the edge that produced it: ``queued`` / ``admitted`` /
    ``demoted`` (the plan took a degradation-ladder rung) / ``iteration``
    (one completed ALS sweep) / ``weight`` / ``rollback`` (the watchdog
    rewound a mid-sweep job after a worker crash) / ``done`` / ``failed``
    / ``cancelled``.  ``fits`` is the fit trajectory up to and
    including this event, so a late subscriber's first iteration event
    still carries the whole history (note this makes publishing a job's
    full event stream O(iterations^2) in copied floats — fine at ALS
    iteration counts; events are only built while feeds are subscribed).
    """
    seq: int
    kind: str
    job_id: int
    tenant: str
    state: str
    iteration: int
    fit: float | None
    fits: tuple
    weight: float
    backend: str
    metrics: dict
    timestamp_s: float

    @property
    def terminal(self) -> bool:
        return self.state in sched.TERMINAL_STATES


class StatusFeed:
    """Thread-safe stream of :class:`JobEvent`; iterable until closed.

    ``job_id=None`` subscribes to every job.  A job-scoped feed closes
    itself after delivering that job's terminal event; iterating a feed
    yields events until it closes.
    """

    _CLOSE = object()

    def __init__(self, job_id: int | None = None):
        self.job_id = job_id
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._closed = False

    def publish(self, event: JobEvent) -> None:
        if self._closed:
            return
        if self.job_id is not None and event.job_id != self.job_id:
            return
        self._q.put(event)
        if self.job_id is not None and event.terminal:
            self.close()

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._q.put(self._CLOSE)

    def get(self, timeout: float | None = None) -> JobEvent | None:
        """Next event, or None when the feed is closed (or timed out)."""
        try:
            item = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        return None if item is self._CLOSE else item

    def __iter__(self):
        while True:
            ev = self.get()
            if ev is None:
                return
            yield ev


class ServiceRuntime:
    """Threaded asynchronous driver around a :class:`DecompositionService`.

    One worker thread owns execution; all public methods are thread-safe
    and may be called from any thread (or, via the ``async`` helpers, any
    asyncio event loop).  Constructor kwargs other than ``service`` are
    forwarded to ``DecompositionService`` when no service is given.

    **Watchdog** (``watchdog=True``): a crash that escapes the worker
    (observer bugs, injected ``runtime.quantum:crash`` worker death) is
    *recovered* instead of hanging the service — the in-flight job's
    ``CPState`` is rolled back to the last completed sweep (the
    auto-snapshot checkpoint, else the deterministic fresh init) and a
    replacement worker thread is started, up to ``max_restarts`` times.
    Beyond the cap — a persistently failing worker — the legacy fail-stop
    path runs: the error is recorded, feeds close, and every
    ``drain()``/``wait()`` caller gets ``RuntimeError('service runtime
    worker failed')`` instead of a hang.  ``auto_snapshot_dir`` (with
    ``auto_snapshot_every`` quanta) enables periodic snapshots at quantum
    boundaries, bounding how many sweeps a rollback can lose.
    """

    def __init__(self, service: DecompositionService | None = None, *,
                 watchdog: bool = True, max_restarts: int = 3,
                 auto_snapshot_dir: str | None = None,
                 auto_snapshot_every: int = 8,
                 **service_kwargs):
        self.service = service if service is not None \
            else DecompositionService(**service_kwargs)
        self.scheduler = self.service.scheduler
        self._watchdog = watchdog
        self._max_restarts = max_restarts
        self._restarts = 0
        self._auto_snapshot_dir = auto_snapshot_dir
        self._auto_snapshot_every = max(1, auto_snapshot_every)
        self._quanta_since_snapshot = 0
        self._auto_snapshot_failures = 0
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)    # new work / stop
        self._idle = threading.Condition(self._lock)    # queue fully drained
        self._feeds: list[StatusFeed] = []
        self._seq = 0
        self._stop = False
        self._error: BaseException | None = None
        self._thread: threading.Thread | None = None
        self.scheduler.observers.append(self._on_event)
        # the sanitizer's lock-order assertion: every scheduler mutation on
        # a runtime-owned scheduler must hold the runtime lock
        self.scheduler.guard_lock = self._lock

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "ServiceRuntime":
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("runtime already started")
            thread = threading.Thread(target=self._drive,
                                      name="service-runtime", daemon=True)
            self._thread = thread
        thread.start()
        return self

    def stop(self) -> None:
        """Stop the worker after the in-flight sweep; close all feeds.

        Unfinished jobs stay in the scheduler (their plans remain held);
        call ``drain()`` first for a graceful shutdown.  The thread handle
        is swapped out under the lock (so concurrent ``stop`` calls each
        join a private reference, never a half-cleared attribute) but
        joined OUTSIDE it — the worker needs the lock to finish its sweep.
        """
        with self._lock:
            self._stop = True
            self._work.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()
        with self._lock:
            for feed in self._feeds:
                feed.close()
            self._feeds.clear()

    def __enter__(self) -> "ServiceRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _drive(self) -> None:
        try:
            while True:
                with self._lock:
                    if self._stop:
                        return
                    if not (self.scheduler.active or self.scheduler.pending):
                        self._idle.notify_all()
                        self._work.wait(timeout=_IDLE_POLL_S)
                        continue
                    # ONE quantum under the lock: control actions (submit /
                    # cancel / set_weight) interleave only between ALS sweeps
                    self.scheduler.step()
                    self._maybe_auto_snapshot()
                # lock released: sleep a moment so blocked control threads
                # can actually acquire it (releasing and immediately
                # re-acquiring would convoy them out for many sweeps)
                time.sleep(_YIELD_S)
        except BaseException as exc:      # noqa: BLE001 — job isolation is
            # step()'s business; anything escaping it (admission failures,
            # observer bugs) kills this worker thread — the watchdog rolls
            # the in-flight job back and starts a replacement.  A disabled
            # or exhausted watchdog must not silently hang every
            # drain()/wait() caller — record the error and close the feeds
            if self._recover(exc):
                return
            with self._lock:
                self._error = exc
                self._idle.notify_all()
                for feed in self._feeds:
                    feed.close()
                self._feeds.clear()

    # ------------------------------------------------------------- watchdog
    def _recover(self, exc: BaseException) -> bool:
        """Restart the worker after a crash; False means stay dead.

        Runs on the dying worker thread.  The in-flight job (if any) is
        rolled back to its last completed sweep, then a replacement
        thread takes over the drive loop.  Refuses when the watchdog is
        off, the restart budget is spent, or ``stop()`` already swapped
        the thread handle out (a racing shutdown wins).
        """
        with self._lock:
            if not self._watchdog or self._stop \
                    or self._restarts >= self._max_restarts:
                return False
            if self._thread is not threading.current_thread():
                return False              # stop() owns the handle now
            self._restarts += 1
            self.service.metrics.watchdog_restarts += 1
            self._rollback_inflight()
            thread = threading.Thread(target=self._drive,
                                      name="service-runtime", daemon=True)
            self._thread = thread
        thread.start()
        return True

    def _rollback_inflight(self) -> None:
        """Rewind the job whose quantum the crash interrupted (lock held).

        ``scheduler.stepping``/``in_sweep`` say whether a ``cp_als_step``
        was mid-flight — its in-place factor mutations may be partial, so
        the ``CPState`` is replaced by the last auto-snapshot checkpoint
        when one exists, else by the deterministic fresh init.  Either
        way the replay is bit-identical to an uninterrupted run at every
        completed sweep; only wasted sweeps differ.  A crash *between*
        sweeps (``in_sweep`` False) needs no rollback — the state is a
        complete iteration already.
        """
        jid, mid_sweep = self.scheduler.stepping, self.scheduler.in_sweep
        self.scheduler.stepping = None
        self.scheduler.in_sweep = False
        if jid is None:
            return
        job = self.scheduler.jobs.get(jid)
        if job is None or job.state != sched.RUNNING or job.cp is None:
            return
        if mid_sweep:
            job.cp = self._checkpointed_cp(job) or cp_als_init(
                job.handle.dims, job.rank, norm_x=job.handle.norm_x,
                tol=job.tol, seed=job.seed)
            job.metrics.iterations = job.cp.iteration
            self.scheduler._publish(job, "rollback")

    def _checkpointed_cp(self, job: sched.Job):
        """The job's CPState from the latest auto-snapshot, or None."""
        if self._auto_snapshot_dir is None:
            return None
        path = os.path.join(self._auto_snapshot_dir,
                            f"job_{job.job_id}.npz")
        if not os.path.exists(path):
            return None
        from repro.store.snapshot import _load_cp
        try:
            return _load_cp(path, job.handle.dims, job.rank)
        except Exception:     # noqa: BLE001 — a damaged checkpoint (crash
            return None       # mid-write) degrades to the fresh-init path

    def _maybe_auto_snapshot(self) -> None:
        """Periodic snapshot at the quantum boundary.

        Failures are counted, not raised — a full disk must not kill the
        worker the watchdog exists to protect.  The caller (``_drive``)
        already holds the lock; the re-entrant re-acquire makes that
        lexical.
        """
        if self._auto_snapshot_dir is None:
            return
        with self._lock:
            self._quanta_since_snapshot += 1
            if self._quanta_since_snapshot < self._auto_snapshot_every:
                return
            self._quanta_since_snapshot = 0
            try:
                self.service.snapshot(self._auto_snapshot_dir)
            except Exception:   # noqa: BLE001 — snapshot is best-effort here
                self._auto_snapshot_failures += 1

    def _check_worker(self) -> None:
        # callers reach here from outside the lock too (wait/stream error
        # paths); the re-entrant lock makes the guarded read safe both ways
        with self._lock:
            if self._error is not None:
                raise RuntimeError("service runtime worker failed") \
                    from self._error

    # ------------------------------------------------------------- control
    def submit(self, req: SubmitDecomposition) -> int:
        with self._lock:
            self._check_worker()
            job_id = self.service.submit(req)
            self._work.notify_all()
            return job_id

    def cancel(self, req: CancelJob | int) -> CancelResult:
        job_id = req.job_id if isinstance(req, CancelJob) else int(req)
        with self._lock:
            result = self.service.cancel(job_id)
            self._work.notify_all()
            return result

    def set_weight(self, req: SetWeight) -> WeightUpdate:
        with self._lock:
            update = self.service.set_weight(req)
            self._work.notify_all()
            return update

    # --------------------------------------------------------- persistence
    def snapshot(self, path: str) -> dict:
        """Snapshot the service at a quantum boundary (lock held, so no
        sweep is mid-flight: every checkpointed ``CPState`` is a complete
        ALS iteration a restarted service can resume from)."""
        with self._lock:
            return self.service.snapshot(path)

    @classmethod
    def restore(cls, path: str, **service_kwargs) -> "ServiceRuntime":
        """A (not yet started) runtime over a service restored from
        ``path`` — every snapshotted job re-enters admission under its
        original id with its checkpointed state."""
        return cls(DecompositionService.restore(path, **service_kwargs))

    # -------------------------------------------------------------- status
    def status(self, job_id: int) -> JobStatus:
        with self._lock:
            return self.service.status(job_id)

    def result(self, job_id: int) -> DecompositionResult:
        with self._lock:
            return self.service.result(job_id)

    def service_metrics(self) -> dict:
        with self._lock:
            return self.service.service_metrics()

    def get_metrics(self, req: GetMetrics | None = None):
        """Service metrics (JSON dict or Prometheus text; see GetMetrics)."""
        with self._lock:
            return self.service.get_metrics(req)

    def get_roofline(self, req=None) -> dict:
        """Roofline attribution from the bandwidth ledger (see GetRoofline).

        Taken outside the runtime lock: the ledger synchronizes its own
        accounts, and a mid-sweep report never blocks an in-flight
        quantum (same reasoning as ``trace``).
        """
        return self.service.get_roofline(req)  # repro-lint: disable=lock-discipline

    def get_slo(self, req=None) -> dict:
        """Per-tenant SLO evaluation + burn rates (see GetSLO)."""
        with self._lock:
            return self.service.get_slo(req)

    def trace(self, req: GetTrace | None = None) -> dict:
        """Recorded spans as Chrome trace-event JSON (see GetTrace).

        Taken outside the runtime lock: the tracer's ring buffer has its
        own lock, and the worker thread's spans are complete objects by
        the time they are recorded, so a mid-sweep export never blocks on
        (or is blocked by) an in-flight quantum.
        """
        return self.service.trace(req)  # repro-lint: disable=lock-discipline

    def subscribe(self, job_id: int | None = None) -> StatusFeed:
        """A feed of subsequent events (all jobs, or one job).

        Subscribing to a job already in a terminal state returns a closed
        feed, so iterating it terminates instead of hanging.
        """
        feed = StatusFeed(job_id)
        with self._lock:
            if job_id is not None:
                self.service.status(job_id)   # typed error on unknown ids
                if self.scheduler.jobs[job_id].state in \
                        sched.TERMINAL_STATES:
                    feed.close()
                    return feed
            self._feeds.append(feed)
        return feed

    def unsubscribe(self, feed: StatusFeed) -> None:
        with self._lock:
            if feed in self._feeds:
                self._feeds.remove(feed)
        feed.close()

    def _on_event(self, job: sched.Job, kind: str) -> None:
        # called by the scheduler with the runtime lock already held
        # (worker thread during sweeps, caller threads during control
        # actions); the re-entrant acquire makes the guarantee lexical
        # instead of by-convention — a future caller that forgets the lock
        # synchronizes here instead of racing on _seq/_feeds
        with self._lock:
            if not self._feeds:
                return  # snapshotting fits/metrics for nobody is O(iters^2)
            self._seq += 1
            event = JobEvent(
                seq=self._seq, kind=kind, job_id=job.job_id,
                tenant=job.tenant, state=job.state,
                iteration=job.cp.iteration if job.cp is not None else 0,
                fit=job.fit,
                fits=tuple(job.cp.fits) if job.cp is not None else (),
                weight=job.weight, backend=job.metrics.backend,
                metrics=job.metrics.snapshot(),
                timestamp_s=time.perf_counter())
            closed = []
            for feed in self._feeds:
                feed.publish(event)
                if feed._closed:
                    closed.append(feed)
            for feed in closed:
                self._feeds.remove(feed)

    # -------------------------------------------------------------- waiting
    def wait(self, job_id: int, timeout: float | None = None) -> JobStatus:
        """Block until the job reaches a terminal state; returns its status."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            job = self.scheduler.jobs.get(job_id)
            if job is None:
                return self.service.status(job_id)    # raises the typed error
            if job.state in sched.TERMINAL_STATES:
                return self.service.status(job_id)
            feed = self.subscribe(job_id)             # atomic with the check
        try:
            while True:
                remaining = None if deadline is None \
                    else max(0.0, deadline - time.monotonic())
                ev = feed.get(timeout=remaining)
                if ev is None:
                    status = self.status(job_id)
                    if status.state in sched.TERMINAL_STATES:
                        return status
                    self._check_worker()
                    if feed._closed:
                        raise RuntimeError(f"runtime stopped while job "
                                           f"{job_id} was {status.state}")
                    raise TimeoutError(
                        f"job {job_id} still {status.state} after {timeout}s")
        finally:
            self.unsubscribe(feed)

    def drain(self, timeout: float | None = None) -> bool:
        """Block until no job is active or queued; False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self.scheduler.active or self.scheduler.pending:
                self._check_worker()
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(timeout=remaining)
            return True

    # --------------------------------------------------------------- asyncio
    async def stream(self, job_id: int | None = None):
        """Async iterator of :class:`JobEvent` (one job, or every job).

        Bridges the thread-side feed into the calling event loop; yields
        until the job completes (job-scoped) or the runtime stops.
        """
        feed = self.subscribe(job_id)
        loop = asyncio.get_running_loop()
        try:
            while True:
                ev = await loop.run_in_executor(None, feed.get)
                if ev is None:
                    # a worker crash closes feeds without a terminal event;
                    # it must not look like a clean end-of-stream
                    self._check_worker()
                    return
                yield ev
        finally:
            self.unsubscribe(feed)

    async def wait_async(self, job_id: int,
                         timeout: float | None = None) -> JobStatus:
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            None, lambda: self.wait(job_id, timeout=timeout))

    async def result_async(self, job_id: int,
                           timeout: float | None = None) -> DecompositionResult:
        """Await a job's completion and return its decomposition result."""
        await self.wait_async(job_id, timeout=timeout)
        return self.result(job_id)
