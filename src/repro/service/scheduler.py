"""Job scheduler: admission by measured plan bytes + weighted fair share.

Admission control is the service restatement of the paper's §4.2 memory
constraint, now in terms of the unified engine API: each admitted job holds
an ``ExecutionPlan`` and is charged exactly ``plan.device_bytes()`` — the
bytes the plan *measurably* holds resident: its per-job factor working set
plus the pooled tensor state (a shared pool entry is charged once, by
whichever tenant created it) — instead of a padded worst-case reservation
sum.  The engine picks the regime per job under the remaining budget: small
tensors get the device-resident fast path, larger ones stream through
pooled reservations, and jobs that fit neither wait in a FIFO queue.
Completions (and cancellations) close their plans — releasing pool
references and the working set — and re-run admission.

Fair share is **stride scheduling** at CP-ALS *iteration* granularity
(Waldspurger's deterministic lottery): every job carries a per-tenant
``weight``; each scheduling quantum runs ONE full ALS sweep
(``cp_als_step``) of the active job with the lowest virtual time
(``pass_value``), then advances that job's pass by ``STRIDE1 / weight``.
Over any window, iterations divide in proportion to the weights — the
load-balance behaviour heterogeneous MTTKRP workloads need (Nisa et al.),
generalising the old equal round-robin (all weights 1 reproduce it
exactly, including the admission-order tie-break).  Because the quantum is
a whole sweep, preemption is natural: ``set_weight`` takes effect at the
next quantum and a demoted tenant keeps its ``CPState`` intact.

``cancel`` retires a queued or running job immediately: the plan is
closed, its pooled bytes and working set are released, and admission
re-runs so a waiting job can take the freed budget.

Observers (``observers``: callables ``(job, kind)``) are notified on every
lifecycle edge and every completed iteration — the hook the async runtime
uses to stream per-iteration status without polling.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax.numpy as jnp

from repro.analysis import sanitize as _san
from repro.core.cp_als import CPState, cp_als_init, cp_als_step
from repro.faults import inject as faults
from repro.faults.retry import is_transient
from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace

from .executor import ServiceEngine
from .metrics import JobMetrics, ServiceMetrics
from .registry import TensorHandle

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
CANCELLED = "cancelled"

TERMINAL_STATES = (DONE, FAILED, CANCELLED)

# Stride-scheduling precision constant (pass advances in STRIDE1/weight
# steps); large so integer-ish weights stay exact in float arithmetic.
STRIDE1 = float(1 << 20)


class FactorPoisonError(RuntimeError):
    """The always-on quantum-boundary guard: a sweep produced a non-finite
    fit, meaning the job's factor matrices are poisoned (NaN/Inf).  The
    job quarantines FAILED; other tenants are unaffected."""


def _poison_factors(job: "Job") -> None:
    """The ``factors.nan`` injection: corrupt a factor matrix in place the
    way a buggy kernel or bad input data would.

    Poisons a factor the coming sweep *reads* before overwriting (factor 1
    when the tensor has one: mode 0's MTTKRP consumes factors 1..N-1), so
    the NaN propagates through the sweep into the fit that the always-on
    quantum-boundary guard checks.
    """
    i = 1 if len(job.cp.factors) > 1 else 0
    f = job.cp.factors[i].at[0, 0].set(jnp.nan)
    job.cp.factors[i] = f
    job.cp.grams[i] = f.T @ f


def _error_payload(exc: BaseException, *, where: str) -> dict:
    """The explanatory payload a quarantined (FAILED) job carries."""
    return {
        "type": type(exc).__name__,
        "message": str(exc),
        "where": where,
        "transient": is_transient(exc),
        "injected": str(exc).startswith("[fault-injection]"),
    }


@dataclasses.dataclass
class Job:
    job_id: int
    handle: TensorHandle
    rank: int
    iters: int
    tol: float
    seed: int
    tenant: str = "default"
    weight: float = 1.0
    pass_value: float = 0.0               # stride-scheduling virtual time
    state: str = QUEUED
    cp: CPState | None = None
    metrics: JobMetrics = dataclasses.field(default_factory=JobMetrics)
    error: str | None = None
    error_payload: dict | None = None     # quarantine explanation (FAILED)
    plan: object | None = None            # ExecutionPlan once admitted
    mttkrp_fn: Callable | None = None     # test/override hook; default = plan

    @property
    def stride(self) -> float:
        """Virtual-time advance per executed sweep (inverse weight)."""
        return STRIDE1 / self.weight

    @property
    def fit(self) -> float | None:
        if self.cp is None or not self.cp.fits:
            return None
        return self.cp.fits[-1]


class JobScheduler:
    """FIFO admission by measured plan bytes; weighted stride stepping."""

    def __init__(self, engine: ServiceEngine, *,
                 device_budget_bytes: int,
                 max_active: int | None = None,
                 metrics: ServiceMetrics | None = None):
        self.engine = engine
        self.device_budget_bytes = int(device_budget_bytes)
        self.max_active = max_active
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self._next_id = 0
        self._global_pass = 0.0           # virtual time of the last quantum
        self.jobs: dict[int, Job] = {}
        self.pending: list[int] = []          # FIFO admission queue
        self.active: list[int] = []           # admission order
        self.trace: list[int] = []            # job id per executed iteration
        self.observers: list[Callable[[Job, str], None]] = []
        # watchdog bookkeeping: the job whose quantum is in flight, and
        # whether its CPState may be mid-sweep (partially mutated) — read
        # by the runtime's crash-recovery path to decide on a rollback
        self.stepping: int | None = None
        self.in_sweep: bool = False

    # -------------------------------------------------------------- events
    def _publish(self, job: Job, kind: str) -> None:
        for fn in list(self.observers):
            fn(job, kind)

    def _sync_gauges(self) -> None:
        """Refresh the live scheduler gauges after a lifecycle edge."""
        self.metrics.queue_depth = len(self.pending)
        self.metrics.running_jobs = len(self.active)

    # ------------------------------------------------------------ lifecycle
    def submit(self, handle: TensorHandle, *, rank: int, iters: int = 25,
               tol: float = 1e-5, seed: int = 0, weight: float = 1.0,
               tenant: str = "default", cp_state: CPState | None = None,
               job_id: int | None = None) -> int:
        """Enqueue a CP-ALS job; returns its id.

        ``cp_state``/``job_id`` are the snapshot-restore hooks: a restored
        job keeps its original id and resumes from its checkpointed sweep
        instead of a fresh ``cp_als_init``.
        """
        _san.assert_scheduler_guard(self, "scheduler.submit")
        if not weight > 0:
            raise ValueError(f"tenant weight must be > 0, got {weight!r}")
        need = self.engine.min_cost(handle, rank)
        if need > self.device_budget_bytes:
            raise ValueError(
                f"job needs at least {need} B of device memory in its "
                f"cheapest regime, which exceeds the device budget "
                f"({self.device_budget_bytes} B): it can never be admitted")
        if job_id is None:
            job_id = self._next_id
        elif job_id in self.jobs:
            raise ValueError(f"job id {job_id} already exists")
        self._next_id = max(self._next_id, job_id + 1)
        job = Job(job_id=job_id, handle=handle, rank=rank,
                  iters=iters, tol=tol, seed=seed, weight=float(weight),
                  tenant=tenant, cp=cp_state)
        self.jobs[job.job_id] = job
        self.pending.append(job.job_id)
        self.metrics.jobs_submitted += 1
        self._sync_gauges()
        self._publish(job, QUEUED)
        self._admit()
        return job.job_id

    def adopt_finished(self, handle: TensorHandle, *, rank: int, iters: int,
                       tol: float, seed: int, tenant: str, weight: float,
                       cp_state: CPState | None, job_id: int,
                       state: str = DONE, error: str | None = None,
                       error_payload: dict | None = None) -> int:
        """Install a terminal job record under its original id.

        The snapshot-restore hook for DONE/FAILED jobs: no admission, no
        plan, no events — the record only serves ``status()``/``result()``
        for job ids that finished before the restart.
        """
        _san.assert_scheduler_guard(self, "scheduler.adopt_finished")
        if state not in TERMINAL_STATES:
            raise ValueError(f"adopt_finished takes a terminal state, "
                             f"got {state!r}")
        if job_id in self.jobs:
            raise ValueError(f"job id {job_id} already exists")
        job = Job(job_id=job_id, handle=handle, rank=rank, iters=iters,
                  tol=tol, seed=seed, tenant=tenant, weight=float(weight),
                  cp=cp_state, state=state, error=error)
        job.error_payload = error_payload
        job.metrics.completed_s = time.perf_counter()
        self._next_id = max(self._next_id, job_id + 1)
        self.jobs[job_id] = job
        return job_id

    def _admit(self) -> None:
        """Admit queued jobs FIFO while the measured byte budget allows.

        Fault paths quarantine instead of crash: a planning exception
        (corrupt store, unrecoverable alloc failure) fails THAT job and
        moves on, and any exception between the ledger charge and a fully
        registered running job releases the charged bytes first — the
        ledger audit holds on every exit.
        """
        try:
            while self.pending:
                if self.max_active is not None and \
                        len(self.active) >= self.max_active:
                    return
                job = self.jobs[self.pending[0]]
                if job.handle.quarantined:
                    self.pending.pop(0)
                    self._fail_queued(job, RuntimeError(
                        f"tensor {job.handle.key} is quarantined: "
                        f"{job.handle.quarantine_reason}"))
                    continue
                remaining = self.device_budget_bytes \
                    - self.metrics.admitted_reservation_bytes
                try:
                    # admission-time H2D uploads (resident-pool entry
                    # creation) attribute to this tenant/job in the ledger
                    with obs_ledger.job_scope(job.tenant, job.job_id):
                        plan = self.engine.try_plan(
                            job.handle, rank=job.rank,
                            budget_remaining=remaining)
                except Exception as exc:   # noqa: BLE001 — job isolation:
                    # planning failures are this job's problem, not the
                    # worker's; nothing was charged yet (try_plan's pool
                    # joins are exception-safe)
                    self.pending.pop(0)
                    self._fail_queued(job, exc)
                    continue
                if plan is None:
                    return                   # head-of-line waits; keep FIFO
                self.pending.pop(0)
                self.metrics.hold_bytes(plan.device_bytes())
                try:
                    job.plan = plan
                    job.state = RUNNING
                    # a newly admitted job enters one quantum past the
                    # current virtual time: it cannot starve tenants
                    # already in flight
                    job.pass_value = self._global_pass + job.stride
                    job.metrics.admitted_s = time.perf_counter()
                    job.metrics.backend = plan.backend
                    job.metrics.stats = plan.stats()
                    self.metrics.hist.record_queue_wait(
                        job.tenant, job.metrics.queue_wait_s)
                    if job.cp is None:  # restored jobs carry their CPState
                        job.cp = cp_als_init(job.handle.dims, job.rank,
                                             norm_x=job.handle.norm_x,
                                             tol=job.tol, seed=job.seed)
                    self.active.append(job.job_id)
                    self.metrics.jobs_admitted += 1
                    if plan.stats().demotions:
                        self.metrics.demotions_total += \
                            plan.stats().demotions
                    self._sync_gauges()
                    self._publish(job, "admitted")
                    if plan.stats().demotions:
                        self._publish(job, "demoted")
                except BaseException as exc:
                    # the PR-8 reservation-leak fix: the bytes charged two
                    # lines up must not outlive a failed registration
                    self.metrics.hold_bytes(-plan.close())
                    job.plan = None
                    if job.job_id in self.active:
                        self.active.remove(job.job_id)
                    self._fail_queued(job, exc)
                    continue
        finally:
            _san.audit_scheduler(self, "scheduler._admit")

    def _fail_queued(self, job: Job, exc: BaseException,
                     where: str = "scheduler.admit") -> None:
        """Quarantine a job that failed before (or during) admission."""
        job.state = FAILED
        job.error = repr(exc)
        job.error_payload = _error_payload(exc, where=where)
        job.metrics.completed_s = time.perf_counter()
        self.metrics.jobs_failed += 1
        self._sync_gauges()
        self._publish(job, FAILED)

    def _retire(self, job: Job, state: str, error: str | None = None,
                payload: dict | None = None) -> None:
        job.state = state
        job.error = error
        job.error_payload = payload
        job.metrics.completed_s = time.perf_counter()
        self.active.remove(job.job_id)
        freed = job.plan.close() if job.plan is not None else 0
        job.metrics.released_bytes = freed
        self.metrics.hold_bytes(-freed)
        if state == FAILED:
            self.metrics.jobs_failed += 1
        elif state == CANCELLED:
            self.metrics.jobs_cancelled += 1
            self.metrics.cancel_freed_bytes_total += freed
        else:
            self.metrics.jobs_completed += 1
        self.metrics.h2d_bytes_total += job.metrics.stats.h2d_bytes
        self.metrics.disk_bytes_total += job.metrics.stats.disk_bytes
        self.metrics.disk_time_s_total += job.metrics.stats.disk_time_s
        self.metrics.launches_total += job.metrics.stats.launches
        self.metrics.retries_total += job.metrics.stats.retries
        self.metrics.giveups_total += job.metrics.stats.giveups
        # per-job engine distributions roll up losslessly at retirement
        self.metrics.hist.merge_engine(job.metrics.stats.hist)
        self._sync_gauges()
        self._publish(job, state)
        _san.audit_scheduler(self, "scheduler._retire")
        self._admit()

    # ------------------------------------------------------------- control
    def cancel(self, job_id: int) -> bool:
        """Cancel a queued or running job; returns False if already final.

        A running job's plan is closed (pooled bytes + working set
        released) and admission re-runs immediately, so a waiting job can
        be admitted in the same call.  The job's ``CPState`` (partial
        factors, fit trajectory) survives for inspection.
        """
        _san.assert_scheduler_guard(self, "scheduler.cancel")
        job = self._get(job_id)
        if job.state == QUEUED:
            self.pending.remove(job.job_id)
            job.state = CANCELLED
            job.error = None
            job.metrics.completed_s = time.perf_counter()
            self.metrics.jobs_cancelled += 1
            self._sync_gauges()
            self._publish(job, CANCELLED)
            self._admit()                 # unblock jobs queued behind it
            return True
        if job.state == RUNNING:
            self._retire(job, CANCELLED)
            return True
        return False

    def set_weight(self, job_id: int, weight: float) -> Job:
        """Re-weight a tenant's job; effective at the next scheduling quantum.

        Preemption between ALS sweeps: the quantum is a whole sweep, so a
        demotion never interrupts (or loses) the job's ``CPState`` — the
        job simply gets scheduled less often from the next pick on.
        """
        _san.assert_scheduler_guard(self, "scheduler.set_weight")
        if not weight > 0:
            raise ValueError(f"tenant weight must be > 0, got {weight!r}")
        job = self._get(job_id)
        if job.state in TERMINAL_STATES:
            raise ValueError(f"job {job_id} is {job.state}; weight is final")
        demoted = weight < job.weight
        job.weight = float(weight)
        if job.state == RUNNING and demoted:
            self.metrics.preemptions += 1
        self._publish(job, "weight")
        return job

    def _get(self, job_id: int) -> Job:
        job = self.jobs.get(job_id)
        if job is None:
            raise ValueError(f"unknown job id {job_id!r}")
        return job

    # ------------------------------------------------------------- stepping
    def _pick(self) -> Job | None:
        """Stride scheduling: the active job with the lowest virtual time.

        Ties break by job id (= admission order), which makes equal
        weights reproduce the old round-robin trace exactly.
        """
        if not self.active:
            return None
        job = min((self.jobs[j] for j in self.active),
                  key=lambda j: (j.pass_value, j.job_id))
        self._global_pass = job.pass_value
        return job

    def step(self) -> bool:
        """One scheduling quantum: one ALS sweep of the min-pass job.

        Returns True while any job is active or queued.  Weighted fair
        share emerges across quanta: a weight-2 tenant's pass advances half
        as fast, so it is picked twice as often as a weight-1 tenant.
        """
        _san.assert_scheduler_guard(self, "scheduler.step")
        job = self._pick()
        if job is not None:
            self.stepping = job.job_id       # watchdog: quantum in flight
            self.in_sweep = False
            job.pass_value += job.stride
            backend = job.mttkrp_fn if job.mttkrp_fn is not None else job.plan
            t0 = time.perf_counter()
            with obs_trace.span("scheduler.quantum", "scheduler",
                                job=job.job_id, tenant=job.tenant,
                                sweep=job.cp.iteration + 1 if job.cp else 0):
                try:
                    kind = faults.fire("runtime.quantum")
                    if kind is not None:
                        # "exception" (RuntimeError) is caught right below
                        # -> job quarantined; "crash" (WorkerCrashError, a
                        # BaseException) escapes job isolation by design
                        # -> worker death -> watchdog restart
                        raise faults.exception_for("runtime.quantum", kind)
                    if faults.fire("factors.nan") is not None:
                        _poison_factors(job)
                    self.in_sweep = True     # factors mutate in place from
                    # ledger: every byte the sweep moves belongs to this
                    # tenant/job (context-local, so concurrent workers in
                    # other sessions cannot cross-attribute)
                    with obs_ledger.job_scope(job.tenant, job.job_id):
                        cp_als_step(backend, job.cp)    # here to sweep end
                    self.in_sweep = False
                    # always-on quantum-boundary NaN guard: the fit is a
                    # host float the sweep already synchronized on, so the
                    # check costs one math.isfinite — poisoned factors
                    # quarantine the job instead of corrupting its result
                    if job.cp.fits and not math.isfinite(job.cp.fits[-1]):
                        raise FactorPoisonError(
                            f"non-finite fit after sweep "
                            f"{job.cp.iteration}: job {job.job_id}'s factor "
                            f"matrices are poisoned (NaN/Inf)")
                    # the sanitizer's deeper (full-matrix) check rides the
                    # same quarantine path when enabled
                    _san.check_factors(job.cp.factors,
                                       f"job {job.job_id} after sweep "
                                       f"{job.cp.iteration}")
                except Exception as exc:      # noqa: BLE001 — job isolation:
                    self.metrics.busy_time_s += time.perf_counter() - t0
                    self._retire(job, FAILED, error=repr(exc),
                                 payload=_error_payload(
                                     exc, where="runtime.quantum"))
                    self.stepping = None
                    return bool(self.active or self.pending)
            dt = time.perf_counter() - t0
            self.metrics.busy_time_s += dt
            self.metrics.hist.record_quantum(job.tenant, dt)
            self.trace.append(job.job_id)     # one bad tensor must not take
            job.metrics.iterations = job.cp.iteration  # down other tenants
            self.metrics.iterations_total += 1
            self.metrics.record_iteration(job.tenant)
            self._publish(job, "iteration")
            if job.cp.converged or job.cp.iteration >= job.iters:
                self._retire(job, DONE)
            self.stepping = None
        return bool(self.active or self.pending)

    def run(self) -> None:
        """Synchronous driver: step until every submitted job retires."""
        while self.step():
            pass
