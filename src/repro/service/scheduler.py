"""Job scheduler: admission by measured plan bytes + round-robin fair share.

Admission control is the service restatement of the paper's §4.2 memory
constraint, now in terms of the unified engine API: each admitted job holds
an ``ExecutionPlan`` and is charged exactly ``plan.device_bytes()`` — the
bytes the plan *measurably* holds resident (a shared pool entry is charged
once, by whichever tenant created it) — instead of a padded worst-case
reservation sum.  The engine picks the regime per job under the remaining
budget: small tensors get the device-resident fast path, larger ones
stream through pooled reservations, and jobs that fit neither wait in a
FIFO queue.  Completions close their plans (releasing pool references) and
re-run admission.

Fair share is round-robin at CP-ALS *iteration* granularity: each
scheduling cycle gives every active job exactly one full ALS sweep
(``cp_als_step``), so a 4-tenant service advances all tenants at 1/4 the
solo rate instead of serializing whole decompositions — the load-balance
behaviour heterogeneous MTTKRP workloads need (Nisa et al.).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.cp_als import CPState, cp_als_init, cp_als_step

from .executor import ServiceEngine
from .metrics import JobMetrics, ServiceMetrics
from .registry import TensorHandle

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"


@dataclasses.dataclass
class Job:
    job_id: int
    handle: TensorHandle
    rank: int
    iters: int
    tol: float
    seed: int
    state: str = QUEUED
    cp: CPState | None = None
    metrics: JobMetrics = dataclasses.field(default_factory=JobMetrics)
    error: str | None = None
    plan: object | None = None            # ExecutionPlan once admitted
    mttkrp_fn: Callable | None = None     # test/override hook; default = plan

    @property
    def fit(self) -> float | None:
        if self.cp is None or not self.cp.fits:
            return None
        return self.cp.fits[-1]


class JobScheduler:
    """FIFO admission by measured plan bytes; round-robin stepping."""

    def __init__(self, engine: ServiceEngine, *,
                 device_budget_bytes: int,
                 max_active: int | None = None,
                 metrics: ServiceMetrics | None = None):
        self.engine = engine
        self.device_budget_bytes = int(device_budget_bytes)
        self.max_active = max_active
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self._next_id = 0
        self.jobs: dict[int, Job] = {}
        self.pending: list[int] = []          # FIFO admission queue
        self.active: list[int] = []           # admission order = RR order
        self.trace: list[int] = []            # job id per executed iteration

    # ------------------------------------------------------------ lifecycle
    def submit(self, handle: TensorHandle, *, rank: int, iters: int = 25,
               tol: float = 1e-5, seed: int = 0) -> int:
        need = self.engine.min_cost(handle, rank)
        if need > self.device_budget_bytes:
            raise ValueError(
                f"job needs at least {need} B of device memory in its "
                f"cheapest regime, which exceeds the device budget "
                f"({self.device_budget_bytes} B): it can never be admitted")
        job = Job(job_id=self._next_id, handle=handle, rank=rank,
                  iters=iters, tol=tol, seed=seed)
        self._next_id += 1
        self.jobs[job.job_id] = job
        self.pending.append(job.job_id)
        self.metrics.jobs_submitted += 1
        self._admit()
        return job.job_id

    def _admit(self) -> None:
        """Admit queued jobs FIFO while the measured byte budget allows."""
        while self.pending:
            if self.max_active is not None and \
                    len(self.active) >= self.max_active:
                return
            job = self.jobs[self.pending[0]]
            remaining = self.device_budget_bytes \
                - self.metrics.admitted_reservation_bytes
            plan = self.engine.try_plan(job.handle, rank=job.rank,
                                        budget_remaining=remaining)
            if plan is None:
                return                       # head-of-line waits; keep FIFO
            self.pending.pop(0)
            self.metrics.hold_bytes(plan.device_bytes())
            job.plan = plan
            job.state = RUNNING
            job.metrics.admitted_s = time.perf_counter()
            job.metrics.backend = plan.backend
            job.metrics.stats = plan.stats()
            job.cp = cp_als_init(job.handle.dims, job.rank,
                                 norm_x=job.handle.norm_x, tol=job.tol,
                                 seed=job.seed)
            self.active.append(job.job_id)
            self.metrics.jobs_admitted += 1

    def _retire(self, job: Job, state: str, error: str | None = None) -> None:
        job.state = state
        job.error = error
        job.metrics.completed_s = time.perf_counter()
        self.active.remove(job.job_id)
        freed = job.plan.close() if job.plan is not None else 0
        self.metrics.hold_bytes(-freed)
        if state == FAILED:
            self.metrics.jobs_failed += 1
        else:
            self.metrics.jobs_completed += 1
        self.metrics.h2d_bytes_total += job.metrics.stats.h2d_bytes
        self.metrics.launches_total += job.metrics.stats.launches
        self._admit()

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """One scheduling cycle: one ALS sweep per active job, round-robin.

        Returns True while any job is active or queued.
        """
        for job_id in list(self.active):
            job = self.jobs[job_id]
            backend = job.mttkrp_fn if job.mttkrp_fn is not None else job.plan
            try:
                cp_als_step(backend, job.cp)
            except Exception as exc:          # noqa: BLE001 — job isolation:
                self._retire(job, FAILED, error=repr(exc))
                continue                      # one bad tensor must not take
            self.trace.append(job_id)         # down the other tenants
            job.metrics.iterations = job.cp.iteration
            self.metrics.iterations_total += 1
            if job.cp.converged or job.cp.iteration >= job.iters:
                self._retire(job, DONE)
        return bool(self.active or self.pending)

    def run(self) -> None:
        """Synchronous driver: cycle until every submitted job retires."""
        while self.step():
            pass
