from .decode import Server, ServeConfig
__all__ = ["Server", "ServeConfig"]
