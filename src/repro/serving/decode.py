"""Batched serving loop: prefill + streaming decode with a step function
shared with the dry-run's serve_step (launch/steps.py).

Greedy/temperature sampling over batched requests; requests of unequal
length are left-padded into the ring of active slots. At pod scale the same
step runs under jit with cache shardings from dist/sharding.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0


class Server:
    def __init__(self, cfg, serve_cfg: ServeConfig, params):
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.model = build_model(cfg)
        self.params = params
        self._step = jax.jit(self.model.decode_step)

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts: (B, P) int32 token prompts (right-aligned, no padding
        support needed for the synthetic path). Returns (B, n_new)."""
        b, plen = prompts.shape
        max_len = self.serve_cfg.max_len
        assert plen + n_new <= max_len
        cache = self.model.init_cache(b, max_len)
        key = jax.random.key(self.serve_cfg.seed)

        # prefill token-by-token (teaching-clarity path; the batched prefill
        # used by the 32k dry-run shape lives in launch/steps.py)
        logits = None
        for t in range(plen):
            logits, cache = self._step(self.params, cache,
                                       jnp.asarray(prompts[:, t:t + 1]),
                                       jnp.int32(t))
        out = np.zeros((b, n_new), dtype=np.int32)
        tok = None
        for i in range(n_new):
            lg = logits[:, -1]
            if self.serve_cfg.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, lg / self.serve_cfg.temperature, axis=-1)
            else:
                tok = jnp.argmax(lg, axis=-1)
            tok = jnp.asarray(tok, jnp.int32)[:, None]
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = self._step(self.params, cache, tok,
                                       jnp.int32(plen + i))
        return out
