"""Persistent BLCO tensor store: disk tier of the memory hierarchy.

The paper streams BLCO launches host -> device through fixed reservations;
this package extends the same design one tier down (device ⊂ host ⊂ disk):

    format    versioned, checksummed ``.blco`` file layout; launches are
              stored reservation-padded so reads are zero-copy np.memmap
              slices (``save_blco`` / ``open_blco`` / ``StoredBLCO``)
    plan      ``DiskStreamedPlan`` — the fifth ExecutionPlan backend,
              feeding the H2D queue straight from mmap'd chunks with a
              bounded host window
    snapshot  service persistence: registry contents + per-job ``CPState``
              survive a process restart (``snapshot_service`` /
              ``restore_service``)

The service's ``TensorRegistry`` uses the store as its spill tier: LRU
eviction writes the BLCO here instead of discarding it, and fingerprints
make reloads restart-safe.
"""
from .format import (SECTION_ALIGN, VERSION, DiskChunkSource, StoredBLCO,
                     StoreCorruptionError, StoreError, StoreFormatError,
                     open_blco, save_blco)
from .plan import DiskStreamedPlan
from .snapshot import restore_service, snapshot_service

__all__ = [
    "SECTION_ALIGN", "VERSION", "DiskChunkSource", "StoredBLCO",
    "StoreCorruptionError", "StoreError", "StoreFormatError",
    "open_blco", "save_blco", "DiskStreamedPlan",
    "snapshot_service", "restore_service",
]
