"""On-disk BLCO format: versioned, checksummed, memmap-zero-copy.

The paper's streaming design (§4.2) assumes the tensor is host-resident;
this module extends the same reservation discipline one tier down the
memory hierarchy (device <- host <- disk).  A ``.blco`` file stores the
launches **already padded to the reservation**, so feeding the H2D queue
from disk is a zero-copy ``np.memmap`` row slice per launch — the disk
layout *is* the wire layout, exactly like the paper's fixed device
reservations make every launch reuse one buffer shape.

File layout (little-endian)::

    [0:8)    magic  b"BLCOSTR1"
    [8:12)   u32    format version
    [12:16)  u32    header JSON length H
    [16:20)  u32    crc32 of the header JSON bytes
    [20:20+H) header JSON (section table, dims, encoding specs, fingerprint)
    ...      sections, each aligned to SECTION_ALIGN for mmap slicing:
               hi / lo / vals / bases    (num_launches, reservation[, order])
               launch_lens / launch_ranges / launch_blocks
               block_keys / block_ranges / block_upper

Every section carries a crc32 in the header (stored as fixed-width hex so
the header length is known before the data pass).  ``open_blco`` always
validates magic, version, header checksum, and that every section lies
inside the file (truncation); ``verify=True`` additionally checksums every
section's bytes.  All failures raise typed errors (:class:`StoreFormatError`
/ :class:`StoreCorruptionError`), never garbage arrays.

``save_blco`` streams one padded launch at a time through a
:class:`~repro.core.streaming.LaunchChunks`, so writing a tensor to the
store needs O(reservation) host memory — the same bounded-window guarantee
the streaming loop gives.
"""
from __future__ import annotations

import json
import os
import time
import zlib

import numpy as np

from repro.core import linearize as lin
from repro.faults import inject as faults
from repro.faults.retry import retry_call
from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace
from repro.core.blco import BLCOTensor, Block, Launch
from repro.core.streaming import LaunchChunks, ReservationSpec, reservation_for

MAGIC = b"BLCOSTR1"
VERSION = 1
SECTION_ALIGN = 4096          # page-aligned sections: clean mmap slices
_HEADER_FIXED = 20            # magic + version + header len + header crc


class StoreError(RuntimeError):
    """Base error of the persistent BLCO store."""


class StoreFormatError(StoreError):
    """Not a store file / unsupported version / malformed header."""


class StoreCorruptionError(StoreError):
    """Checksum mismatch or truncated section data."""


def _crc_hex(crc: int) -> str:
    return f"{crc & 0xFFFFFFFF:08x}"


def _align(offset: int) -> int:
    return -(-offset // SECTION_ALIGN) * SECTION_ALIGN


def _section_table(num_launches: int, reservation: int, order: int,
                   value_dtype: np.dtype, num_blocks: int) -> dict:
    """Section name -> {dtype, shape} in file order (offsets filled next)."""
    L, R, N, B = num_launches, reservation, order, num_blocks
    return {
        "hi": {"dtype": "uint32", "shape": [L, R]},
        "lo": {"dtype": "uint32", "shape": [L, R]},
        "vals": {"dtype": str(value_dtype), "shape": [L, R]},
        "bases": {"dtype": "int32", "shape": [L, R, N]},
        "launch_lens": {"dtype": "int64", "shape": [L]},
        "launch_ranges": {"dtype": "int64", "shape": [L, 2]},
        "launch_blocks": {"dtype": "int64", "shape": [L, 2]},
        "block_keys": {"dtype": "uint64", "shape": [B]},
        "block_ranges": {"dtype": "int64", "shape": [B, 2]},
        "block_upper": {"dtype": "int64", "shape": [B, N]},
    }


def _section_nbytes(sec: dict) -> int:
    n = np.dtype(sec["dtype"]).itemsize
    for d in sec["shape"]:
        n *= int(d)
    return n


def save_blco(blco: BLCOTensor, path: str, *,
              reservation_nnz: int | None = None,
              fingerprint: str | None = None,
              norm_x: float | None = None) -> int:
    """Write ``blco`` to ``path`` in the store format; returns file bytes.

    Launches are written reservation-padded (default: the streaming
    regime's power-of-two reservation, so a disk-streamed plan joins the
    same pooled buffer shapes as a host-streamed one), one launch at a
    time — O(reservation) host memory regardless of tensor size.
    ``fingerprint``/``norm_x`` ride along so a registry can re-key and
    re-admit the tensor after a process restart without the original COO.
    """
    spec = reservation_for(blco, reservation_nnz)
    res = spec.nnz
    chunks = LaunchChunks(blco, res)
    L, B, N = len(blco.launches), len(blco.blocks), blco.order
    # write-then-rename: a crash mid-write must never leave a truncated
    # file at the final path — the registry's restart path adopts any
    # existing <fingerprint>.blco, so the rename is the commit point
    tmp_path = f"{path}.tmp"

    sections = _section_table(L, res, N, blco.values.dtype, B)
    header = {
        "dims": [int(d) for d in blco.dims],
        "nnz": int(blco.nnz),
        "order": N,
        "value_dtype": str(blco.values.dtype),
        "reservation_nnz": int(res),
        "num_launches": L,
        "num_blocks": B,
        "field_bits": list(blco.re.field_bits),
        "field_shift": list(blco.re.field_shift),
        "block_bits": list(blco.re.block_bits),
        "total_bits": int(blco.spec.total_bits),
        "fingerprint": fingerprint,
        "norm_x": float(norm_x) if norm_x is not None else None,
        "sections": sections,
    }
    # fixed-width crc placeholders keep the header length stable while the
    # real checksums are patched in after the data pass; section offsets
    # depend on the header length (and vice versa through their digit
    # count), so size the header to a fixed point — section alignment makes
    # this converge almost immediately
    for sec in sections.values():
        sec["crc32"] = _crc_hex(0)
        sec["nbytes"] = _section_nbytes(sec)
    hlen, total_bytes, header_json = 0, 0, b""
    for _ in range(10):
        offset = _align(_HEADER_FIXED + hlen)
        for sec in sections.values():
            sec["offset"] = offset
            offset = _align(sec["offset"] + sec["nbytes"])
        total_bytes = (sections["block_upper"]["offset"]
                       + sections["block_upper"]["nbytes"])
        header_json = json.dumps(header, sort_keys=True).encode()
        if len(header_json) == hlen:
            break
        hlen = len(header_json)
    else:
        raise StoreError("header sizing did not converge")

    crcs = {name: 0 for name in sections}
    row_bytes = {name: _section_nbytes(sec) // max(1, L)
                 for name, sec in sections.items()
                 if name in ("hi", "lo", "vals", "bases")}
    try:
        _write_store(tmp_path, header, sections, header_json, chunks, blco,
                     crcs, row_bytes, L, B, N, total_bytes)
    except BaseException:
        try:
            os.unlink(tmp_path)
        except OSError:
            pass
        raise
    os.replace(tmp_path, path)
    return total_bytes


def _write_store(path, header, sections, header_json, chunks, blco,
                 crcs, row_bytes, L, B, N, total_bytes) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(VERSION).tobytes())
        f.write(np.uint32(len(header_json)).tobytes())
        f.write(np.uint32(0).tobytes())            # header crc patched below
        f.write(header_json)
        # --- padded launches, streamed one at a time --------------------
        for i in range(L):
            hi, lo, vals, bases, _n = chunks.chunk(i)
            for name, arr in (("hi", hi), ("lo", lo), ("vals", vals),
                              ("bases", bases)):
                raw = arr.tobytes()
                if len(raw) != row_bytes[name]:
                    raise StoreError(f"section {name} row size mismatch")
                f.seek(sections[name]["offset"] + i * row_bytes[name])
                f.write(raw)
                crcs[name] = zlib.crc32(raw, crcs[name])
        # --- launch + block tables --------------------------------------
        launches = blco.launches
        blocks = blco.blocks
        tables = {
            "launch_lens": np.asarray([l.nnz for l in launches], np.int64),
            "launch_ranges": np.asarray([[l.start, l.end] for l in launches],
                                        np.int64).reshape(L, 2),
            "launch_blocks": np.asarray(
                [[l.block_ids[0], l.block_ids[-1] + 1] for l in launches],
                np.int64).reshape(L, 2),
            "block_keys": np.asarray([b.key for b in blocks], np.uint64),
            "block_ranges": np.asarray([[b.start, b.end] for b in blocks],
                                       np.int64).reshape(B, 2),
            "block_upper": np.asarray([list(b.upper) for b in blocks],
                                      np.int64).reshape(B, N),
        }
        for name, arr in tables.items():
            raw = np.ascontiguousarray(arr).tobytes()
            f.seek(sections[name]["offset"])
            f.write(raw)
            crcs[name] = zlib.crc32(raw, crcs[name])
        # --- patch in the real checksums --------------------------------
        for name, sec in sections.items():
            sec["crc32"] = _crc_hex(crcs[name])
        final_json = json.dumps(header, sort_keys=True).encode()
        if len(final_json) != len(header_json):
            raise StoreError("header length changed while patching checksums")
        f.seek(_HEADER_FIXED)
        f.write(final_json)
        f.seek(12)
        f.write(np.uint32(len(final_json)).tobytes())
        f.write(np.uint32(zlib.crc32(final_json)).tobytes())
        f.truncate(total_bytes)


class DiskChunkSource:
    """Re-iterable chunk source over a :class:`StoredBLCO`'s memmaps.

    Yields ``(hi, lo, vals, bases, n)`` where the arrays are zero-copy
    ``np.memmap`` row slices — the OS pages them in as ``device_put``
    consumes them, so the process's padded-chunk footprint is bounded by
    the streaming window, not the tensor.  When ``stats`` is given, each
    fetch records the chunk's bytes and the host wall time of the (lazy)
    slice construction; the actual page-in overlaps the H2D put.
    """

    def __init__(self, stored: "StoredBLCO", stats=None):
        self.stored = stored
        self.stats = stats

    def __len__(self) -> int:
        return self.stored.num_launches

    def chunk(self, i: int):
        t0 = time.perf_counter()

        def _read():
            faults.maybe_fail("store.read")
            return self.stored.chunk(i)

        # transient read failures (injected OSError or a genuinely flaky
        # mount) retry with backoff; corruption (StoreCorruptionError) is
        # permanent and surfaces immediately — re-reading bad bytes does
        # not help, and the registry's self-heal owns that path
        out = retry_call(_read, site="store.read", stats=self.stats)
        t1 = time.perf_counter()
        nbytes = (out[0].nbytes + out[1].nbytes
                  + out[2].nbytes + out[3].nbytes)
        if self.stats is not None:
            self.stats.disk_time_s += t1 - t0
            self.stats.disk_bytes += nbytes
            self.stats.hist.disk_read_s.record(t1 - t0)
            if obs_ledger.LEDGER.enabled:
                # same nbytes / t1 - t0 as the stats counters, and only
                # when stats are carried — ledger and EngineStats stay in
                # lockstep (exact conservation)
                obs_ledger.record(obs_ledger.DISK_HOST, nbytes, t1 - t0,
                                  regime=self.stats.backend)
        if obs_trace.TRACING.enabled:
            obs_trace.add_event("store.read", "store", t0, t1,
                                launch=i, bytes=nbytes)
        return out

    def __iter__(self):
        for i in range(len(self)):
            yield self.chunk(i)


class StoredBLCO:
    """A disk-resident BLCO tensor opened from the store (mmap-backed).

    Exposes exactly what the streaming loop needs — ``dims``, ``re``, and
    per-launch reservation chunks — without ever materializing the nnz
    arrays in host memory.  ``to_blco()`` is the explicit reload path that
    does (the registry's un-spill).
    """

    def __init__(self, path: str, header: dict, maps: dict):
        self.path = path
        self._header = header
        self._maps = maps
        self.dims = tuple(int(d) for d in header["dims"])
        self.nnz = int(header["nnz"])
        self.value_dtype = np.dtype(header["value_dtype"])
        self.reservation_nnz = int(header["reservation_nnz"])
        self.num_launches = int(header["num_launches"])
        self.num_blocks = int(header["num_blocks"])
        self.fingerprint = header.get("fingerprint")
        self.norm_x = header.get("norm_x")
        self.re = lin.ReencodeSpec(tuple(header["field_bits"]),
                                   tuple(header["field_shift"]),
                                   tuple(header["block_bits"]))
        self._closed = False

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def spec(self) -> ReservationSpec:
        """The reservation shape disk chunks are padded to (pool key)."""
        return ReservationSpec(nnz=self.reservation_nnz, order=self.order,
                               value_itemsize=self.value_dtype.itemsize)

    def file_bytes(self) -> int:
        return os.path.getsize(self.path)

    def chunk(self, i: int):
        """Launch ``i`` as zero-copy memmap slices: (hi, lo, vals, bases, n)."""
        if self._closed:
            raise StoreError(f"store {self.path} is closed")
        m = self._maps
        return (m["hi"][i], m["lo"][i], m["vals"][i], m["bases"][i],
                int(m["launch_lens"][i]))

    def chunks(self, stats=None) -> DiskChunkSource:
        """Re-iterable chunk source for ``stream_mttkrp``."""
        return DiskChunkSource(self, stats=stats)

    _VERIFY_BLOCK = 4 << 20        # checksum in blocks: O(1) host memory

    def verify(self) -> None:
        """Checksum every section; raises :class:`StoreCorruptionError`.

        Reads in fixed-size blocks — verification of a larger-than-RAM
        store must not itself materialize a section in host memory.
        """
        with open(self.path, "rb") as f:
            for name, sec in self._header["sections"].items():
                f.seek(sec["offset"])
                crc, remaining = 0, sec["nbytes"]
                while remaining:
                    raw = f.read(min(remaining, self._VERIFY_BLOCK))
                    if not raw:
                        raise StoreCorruptionError(
                            f"{self.path}: section {name} truncated "
                            f"({sec['nbytes'] - remaining} of "
                            f"{sec['nbytes']} bytes)")
                    crc = zlib.crc32(raw, crc)
                    remaining -= len(raw)
                if _crc_hex(crc) != sec["crc32"]:
                    raise StoreCorruptionError(
                        f"{self.path}: section {name} checksum mismatch")

    def to_blco(self) -> BLCOTensor:
        """Materialize the full host-resident BLCOTensor (the reload path)."""
        if self._closed:
            raise StoreError(f"store {self.path} is closed")
        m = self._maps
        idx_hi = np.empty(self.nnz, np.uint32)
        idx_lo = np.empty(self.nnz, np.uint32)
        values = np.empty(self.nnz, self.value_dtype)
        for i in range(self.num_launches):
            s, e = (int(v) for v in m["launch_ranges"][i])
            n = int(m["launch_lens"][i])
            idx_hi[s:e] = m["hi"][i, :n]
            idx_lo[s:e] = m["lo"][i, :n]
            values[s:e] = m["vals"][i, :n]
        blocks = [Block(key=int(m["block_keys"][i]),
                        start=int(m["block_ranges"][i, 0]),
                        end=int(m["block_ranges"][i, 1]),
                        upper=tuple(int(u) for u in m["block_upper"][i]))
                  for i in range(self.num_blocks)]
        launches = [Launch(block_ids=tuple(range(
                        int(m["launch_blocks"][i, 0]),
                        int(m["launch_blocks"][i, 1]))),
                        start=int(m["launch_ranges"][i, 0]),
                        end=int(m["launch_ranges"][i, 1]))
                    for i in range(self.num_launches)]
        spec = lin.LinearSpec.make(self.dims)
        if spec.total_bits != int(self._header["total_bits"]):
            raise StoreCorruptionError(
                f"{self.path}: linearization width mismatch "
                f"({spec.total_bits} rebuilt vs {self._header['total_bits']} "
                f"stored)")
        return BLCOTensor(dims=self.dims, spec=spec, re=self.re,
                          idx_hi=idx_hi, idx_lo=idx_lo, values=values,
                          blocks=blocks, launches=launches,
                          construction_stats={"loaded_from": self.path})

    def close(self) -> None:
        self._maps = {}
        self._closed = True

    def __enter__(self) -> "StoredBLCO":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def open_blco(path: str, *, verify: bool = False) -> StoredBLCO:
    """Open a store file as a :class:`StoredBLCO` (mmap, no data read).

    Always validates magic, version, header checksum, and section bounds
    against the real file size (truncation); ``verify=True`` additionally
    checksums every section's data.
    """
    try:
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            fixed = f.read(_HEADER_FIXED)
            if len(fixed) < _HEADER_FIXED or fixed[:8] != MAGIC:
                raise StoreFormatError(f"{path}: not a BLCO store file")
            version = int(np.frombuffer(fixed[8:12], np.uint32)[0])
            if version != VERSION:
                raise StoreFormatError(
                    f"{path}: store version {version} unsupported "
                    f"(expected {VERSION})")
            hlen = int(np.frombuffer(fixed[12:16], np.uint32)[0])
            hcrc = int(np.frombuffer(fixed[16:20], np.uint32)[0])
            raw = f.read(hlen)
    except OSError as exc:
        raise StoreError(f"cannot open store file {path}: {exc}") from exc
    if len(raw) != hlen:
        raise StoreCorruptionError(f"{path}: truncated header "
                                   f"({len(raw)} of {hlen} bytes)")
    if zlib.crc32(raw) != hcrc:
        raise StoreCorruptionError(f"{path}: header checksum mismatch")
    try:
        header = json.loads(raw.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise StoreCorruptionError(f"{path}: unreadable header") from exc

    maps = {}
    for name, sec in header["sections"].items():
        if sec["offset"] + sec["nbytes"] > size:
            raise StoreCorruptionError(
                f"{path}: section {name} extends past end of file "
                f"(needs {sec['offset'] + sec['nbytes']} bytes, file has "
                f"{size})")
        shape = tuple(int(d) for d in sec["shape"])
        if sec["nbytes"] == 0:
            maps[name] = np.zeros(shape, np.dtype(sec["dtype"]))
        else:
            maps[name] = np.memmap(path, dtype=np.dtype(sec["dtype"]),
                                   mode="r", offset=sec["offset"],
                                   shape=shape)
    stored = StoredBLCO(path, header, maps)
    if verify:
        stored.verify()
    return stored
