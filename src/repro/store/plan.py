"""``DiskStreamedPlan``: the fifth ExecutionPlan backend (disk -> device).

The paper's out-of-memory regime assumes the tensor fits in host RAM and
streams host -> device through fixed reservations.  This plan starts one
tier lower: the tensor lives in a ``.blco`` store file, and the H2D queue
is fed directly from mmap'd reservation-padded chunks — the host never
holds more than the streaming window (``queues`` padded launches), so
tensors larger than host RAM decompose under the same engine API.

Because the disk layout is reservation-padded with the *same* power-of-two
buckets the host-streaming regime uses, a disk-streamed plan hits the same
compiled launch executable (and, under the service, the same pooled
reservation shapes) as a host-streamed plan of the same spec.
"""
from __future__ import annotations

import os

from repro.core.mttkrp import DEFAULT_COPIES, validate_kernel
from repro.core.streaming import EngineStats, ReservationSpec, stream_mttkrp
from repro.obs import trace as obs_trace

from .format import StoredBLCO, open_blco, save_blco


class DiskStreamedPlan:
    """Disk-resident plan: mmap'd store chunks stream straight to device.

    ``stored`` is a :class:`~repro.store.format.StoredBLCO` or a path to
    one.  ``delete_on_close`` unlinks the file when the plan closes — the
    right setting for an anonymous spill the plan itself created
    (:meth:`spill`); registry-owned store files are kept.
    """

    backend = "disk_streamed"

    def __init__(self, stored: StoredBLCO | str | os.PathLike, *,
                 queues: int = 4, resolution: str = "auto",
                 copies: int = DEFAULT_COPIES, kernel: str = "xla",
                 interpret: bool = True, spec: ReservationSpec | None = None,
                 delete_on_close: bool = False):
        validate_kernel(kernel)
        if not isinstance(stored, StoredBLCO):
            stored = open_blco(os.fspath(stored))
        self.stored = stored
        self.dims = stored.dims
        self.queues = queues
        self.resolution = resolution
        self.copies = copies
        self.kernel = kernel
        self.interpret = interpret
        self.spec = spec if spec is not None else stored.spec
        self.delete_on_close = delete_on_close
        self._stats = EngineStats(backend=self.backend)
        self._closed = False

    @classmethod
    def spill(cls, blco, path: str, *, fingerprint: str | None = None,
              norm_x: float | None = None, reservation_nnz: int | None = None,
              delete_on_close: bool = True, **kwargs) -> "DiskStreamedPlan":
        """Write ``blco`` to ``path`` and plan disk-streaming from it.

        The host copy can be dropped afterwards; by default the spill file
        is private to this plan and unlinked on ``close()``.
        """
        save_blco(blco, path, fingerprint=fingerprint, norm_x=norm_x,
                  reservation_nnz=reservation_nnz)
        return cls(path, delete_on_close=delete_on_close, **kwargs)

    def mttkrp(self, factors, mode: int, *, resolution: str | None = None,
               copies: int | None = None):
        if self._closed:
            raise RuntimeError("plan is closed")
        with obs_trace.span("plan.mttkrp", "plan", backend=self.backend,
                            mode=mode):
            return stream_mttkrp(
                self.stored.chunks(stats=self._stats), self.stored, factors,
                mode, queues=self.queues,
                resolution=resolution if resolution is not None
                else self.resolution,
                copies=copies if copies is not None else self.copies,
                stats=self._stats, kernel=self.kernel,
                interpret=self.interpret)

    def device_bytes(self) -> int:
        """Reservation bytes in flight (identical to the streamed regime)."""
        return 0 if self._closed else self.spec.bytes_in_flight(self.queues)

    def host_window_bytes(self) -> int:
        """Padded chunk bytes the host can hold at once: the queue window."""
        return 0 if self._closed else \
            self.spec.bytes_per_launch * self.queues

    def disk_bytes(self) -> int:
        """Size of the backing store file."""
        return 0 if self._closed else self.stored.file_bytes()

    def stats(self) -> EngineStats:
        return self._stats

    def close(self) -> int:
        if self._closed:
            return 0
        freed = self.spec.bytes_in_flight(self.queues)
        path = self.stored.path
        self.stored.close()
        self._closed = True
        if self.delete_on_close:
            try:
                os.unlink(path)
            except OSError:
                pass
        return freed
