"""Service persistence: snapshot/restore registry contents + job CPState.

A snapshot is a directory::

    manifest.json     registry entries (fingerprint key -> store file) and
                      job records (tenant, weight, rank, iters, ...)
    job_<id>.npz      the job's resumable CPState (factors, lam, fits, ...)

Tensors are NOT copied into the snapshot — they live in the registry's
spill store (``store_dir/<key>.blco``), written once and addressed by
content fingerprint, so any number of snapshots share one tensor file and
a restarted service re-admits jobs without rebuilding a single BLCO.

``restore_service`` replays non-terminal jobs into a fresh service under
their ORIGINAL job ids: each job re-enters the admission queue (plans are
re-planned against the new budget — the restarted process may have a
different one) and resumes CP-ALS from its checkpointed sweep, numerically
continuing where the killed process stopped.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core.cp_als import CPState

from .format import StoreError

SNAPSHOT_VERSION = 1
MANIFEST = "manifest.json"

# job states that resume after a restart; terminal states (done/failed)
# are persisted too — as finished *records* whose results a restarted
# service keeps serving — but never re-enter admission
_RESUMABLE = ("queued", "running")
_PERSISTED = _RESUMABLE + ("done", "failed")


def _save_cp(path: str, cp: CPState) -> None:
    # atomic: write the full npz to a tmp file, then rename over the
    # destination, so a crash mid-write (or a reader racing an
    # auto-snapshot) never sees a truncated checkpoint.  The open file
    # handle matters: np.savez appends ".npz" to suffix-less *paths* but
    # writes file objects verbatim.
    arrays = {f"factor_{n}": np.asarray(f) for n, f in enumerate(cp.factors)}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, lam=np.asarray(cp.lam), fits=np.asarray(cp.fits),
                 prev_fit=np.float64(cp.prev_fit),
                 iteration=np.int64(cp.iteration),
                 converged=np.bool_(cp.converged),
                 norm_x=np.float64(cp.norm_x), tol=np.float64(cp.tol),
                 **arrays)
    os.replace(tmp, path)


def _load_cp(path: str, dims, rank: int) -> CPState:
    with np.load(path) as z:
        factors = [jnp.asarray(z[f"factor_{n}"]) for n in range(len(dims))]
        lam = jnp.asarray(z["lam"])
        fits = [float(f) for f in z["fits"]]
        prev_fit = float(z["prev_fit"])
        iteration = int(z["iteration"])
        converged = bool(z["converged"])
        norm_x = float(z["norm_x"])
        tol = float(z["tol"])
    # grams are pure functions of the factors — recomputed, not stored,
    # exactly as cp_als_init derives them, so the resumed sweep is
    # numerically identical to the uninterrupted one
    grams = [f.T @ f for f in factors]
    return CPState(dims=tuple(dims), rank=rank, norm_x=norm_x, tol=tol,
                   factors=factors, lam=lam, grams=grams, fits=fits,
                   prev_fit=prev_fit, iteration=iteration,
                   converged=converged)


def snapshot_service(service, path: str) -> dict:
    """Write a restartable snapshot of ``service`` into directory ``path``.

    Persists every registered tensor to the registry's spill store (host
    copies stay resident — snapshotting never slows the running service
    down) and checkpoints each non-terminal job's ``CPState``.  Returns
    the manifest dict.  Raises :class:`StoreError` when the service's
    registry has no ``store_dir`` to persist tensors into.
    """
    registry = service.registry
    if registry.store_dir is None:
        raise StoreError("snapshot requires a registry spill store; "
                         "construct the service with store_dir=...")
    os.makedirs(path, exist_ok=True)
    jobs = []
    needed_keys = set()
    for job in service.scheduler.jobs.values():
        if job.state not in _PERSISTED:
            continue
        needed_keys.add(job.handle.key)
        if job.cp is not None:
            _save_cp(os.path.join(path, f"job_{job.job_id}.npz"), job.cp)
        jobs.append({
            "job_id": job.job_id, "tensor_key": job.handle.key,
            "rank": job.rank, "iters": job.iters, "tol": job.tol,
            "seed": job.seed, "tenant": job.tenant, "weight": job.weight,
            "state": job.state, "iteration":
                job.cp.iteration if job.cp is not None else 0,
            "has_cp": job.cp is not None,
            "error": job.error, "error_payload": job.error_payload,
        })
    tensors = {}
    for key in sorted(needed_keys):
        tensors[key] = {"file": os.path.abspath(registry.persist(key))}
    manifest = {"version": SNAPSHOT_VERSION, "tensors": tensors,
                "jobs": jobs}
    tmp = os.path.join(path, MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, os.path.join(path, MANIFEST))
    return manifest


def restore_service(path: str, service) -> list[int]:
    """Replay a snapshot into a (fresh) ``service``; returns resumed ids.

    Registry entries are adopted straight from their store files (stub
    handles — no BLCO rebuild, no host reload; jobs disk-stream or the
    registry reloads on demand), and every snapshotted job re-enters the
    admission queue under its original id with its checkpointed
    ``CPState``.
    """
    manifest_path = os.path.join(path, MANIFEST)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except OSError as exc:
        raise StoreError(f"cannot read snapshot manifest "
                         f"{manifest_path}: {exc}") from exc
    if manifest.get("version") != SNAPSHOT_VERSION:
        raise StoreError(f"snapshot version {manifest.get('version')!r} "
                         f"unsupported (expected {SNAPSHOT_VERSION})")
    registry = service.registry
    for key, rec in manifest["tensors"].items():
        registry.adopt(key, rec["file"])
    restored = []
    for rec in sorted(manifest["jobs"], key=lambda r: r["job_id"]):
        handle = registry.get(rec["tensor_key"])
        cp = None
        if rec["has_cp"]:
            cp = _load_cp(os.path.join(path, f"job_{rec['job_id']}.npz"),
                          handle.dims, rec["rank"])
        if rec.get("state") in _RESUMABLE or "state" not in rec:
            job_id = service.scheduler.submit(
                handle, rank=rec["rank"], iters=rec["iters"],
                tol=rec["tol"], seed=rec["seed"], weight=rec["weight"],
                tenant=rec["tenant"], cp_state=cp, job_id=rec["job_id"])
        else:
            # terminal record: install it directly (no admission) so the
            # restarted service keeps serving status()/result() for jobs
            # that finished before the snapshot
            job_id = service.scheduler.adopt_finished(
                handle, rank=rec["rank"], iters=rec["iters"],
                tol=rec["tol"], seed=rec["seed"], weight=rec["weight"],
                tenant=rec["tenant"], cp_state=cp, job_id=rec["job_id"],
                state=rec["state"], error=rec.get("error"),
                error_payload=rec.get("error_payload"))
        restored.append(job_id)
    if hasattr(service, "metrics"):
        service.metrics.jobs_restored += len(restored)
    return restored
