from .trainer import Trainer, TrainerConfig
from . import checkpoint
__all__ = ["Trainer", "TrainerConfig", "checkpoint"]
