"""Preemption-safe checkpointing (numpy-based, no orbax offline).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per leaf (path-encoded
filenames) + ``manifest.json`` (tree structure, shapes, dtypes, step).
Write protocol: write into ``step_<N>.tmp`` then atomic ``os.rename`` —
a process killed mid-save never corrupts the latest-complete checkpoint,
and ``restore_latest`` simply picks the highest complete step.

At real multi-host scale each host writes only the leaves it owns (the
``shard_filter`` hook); here the single-host path writes everything.
Async save: ``save(..., blocking=False)`` snapshots to host memory and
writes on a background thread — the training loop keeps stepping (straggler
mitigation: checkpoint I/O never stalls the step).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return _SAFE.sub("_", "__".join(parts))


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True,
         keep: int = 3) -> threading.Thread | None:
    """Snapshot ``tree`` (pytree of arrays) for ``step``."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    # snapshot to host memory first (so async writes see a consistent state)
    host = [(_leaf_name(p), np.asarray(leaf)) for p, leaf in flat]
    manifest = {
        "step": int(step),
        "leaves": [{"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                   for n, a in host],
    }

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        for name, arr in host:
            np.save(os.path.join(tmp, name + ".npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    base = os.path.join(ckpt_dir, f"step_{step}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path, like in flat:
        arr = np.load(os.path.join(base, _leaf_name(path) + ".npy"))
        assert tuple(arr.shape) == tuple(like.shape), \
            (path, arr.shape, like.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in flat].__class__(leaves))


def restore_latest(ckpt_dir: str, like_tree):
    steps = list_steps(ckpt_dir)
    if not steps:
        return None, None
    s = steps[-1]
    return s, restore(ckpt_dir, s, like_tree)
