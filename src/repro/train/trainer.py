"""Fault-tolerant training loop.

Scale-out behaviors implemented here (exercised at CPU scale in tests,
designed for the 16x16 / 2x16x16 meshes):

* **checkpoint/restart** — atomic step-tagged snapshots (train/checkpoint.py);
  on start the trainer restores the latest complete step and the data
  pipeline replays deterministically from there (data/pipeline.py), so a
  preempted/failed job resumes bit-exact minus in-flight steps.
* **async checkpointing** — snapshot to host memory, write on a background
  thread: checkpoint I/O never blocks the step loop (straggler class #1).
* **preemption hooks** — ``request_stop()`` (wired to SIGTERM in launch/
  train.py) finishes the current step, saves, and exits cleanly.
* **elastic scaling** — ``state_to_host``/``state_from_host`` reshard a
  host snapshot onto a *different* mesh: on node failure, restart with the
  spare-free smaller mesh (e.g. 2x16x16 -> 16x16) from the same checkpoint
  (GSPMD resharding is just device_put with the new sharding tree).
* **NaN/overflow guard** — skip-and-log on non-finite loss (common large-
  scale hygiene; avoids one bad batch poisoning the run).
* **step-time watchdog** — flags steps slower than ``straggler_factor`` x
  the trailing median (straggler detection signal for the scheduler).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.optim import adamw
from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_async: bool = True
    log_every: int = 10
    straggler_factor: float = 2.0
    keep_ckpts: int = 3
    # donation invalidates the old state's buffers, so the NaN guard could
    # not roll back a poisoned step; at pod scale enable donation and rely on
    # checkpoint-restore for NaN recovery instead.
    donate: bool = False


class Trainer:
    def __init__(self, cfg: TrainerConfig, model, opt_cfg: adamw.AdamWConfig,
                 train_step: Callable, data_source, *,
                 init_key=None, mesh=None, state_shardings=None):
        self.cfg = cfg
        self.model = model
        self.opt_cfg = opt_cfg
        self.data = data_source
        self.mesh = mesh
        self.state_shardings = state_shardings
        self._stop = False
        self._ckpt_thread = None
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []
        self.skipped_nan_steps: list[int] = []

        donate_kw = {"donate_argnums": 0} if cfg.donate else {}
        if mesh is None:
            self.train_step = jax.jit(train_step, **donate_kw)
        else:
            self.train_step = jax.jit(
                train_step, in_shardings=(state_shardings, None),
                out_shardings=(state_shardings, None), **donate_kw)

        # ---- init or restore -------------------------------------------------
        like = jax.eval_shape(self._fresh_state,
                              init_key if init_key is not None
                              else jax.random.key(0))
        step, restored = ckpt.restore_latest(cfg.ckpt_dir, like)
        if restored is not None:
            self.start_step = step
            self.state = self._place(restored)
        else:
            self.start_step = 0
            self.state = self._fresh_state(
                init_key if init_key is not None else jax.random.key(0))

    def _fresh_state(self, key):
        params = self.model.init(key)
        return {"params": params,
                "opt": adamw.init_state(params, self.opt_cfg)}

    def _place(self, host_state):
        if self.mesh is None or self.state_shardings is None:
            return jax.tree.map(jax.numpy.asarray, host_state)
        return jax.tree.map(jax.device_put, host_state, self.state_shardings)

    def request_stop(self, *_):
        self._stop = True

    # ---- elastic rescale -----------------------------------------------------
    def state_to_host(self):
        return jax.tree.map(np.asarray, self.state)

    @staticmethod
    def state_from_host(host_state, mesh, state_shardings):
        """Reshard a host snapshot onto a different mesh (elastic restart)."""
        return jax.tree.map(jax.device_put, host_state, state_shardings)

    # ---- the loop --------------------------------------------------------------
    def run(self) -> dict:
        cfg = self.cfg
        history: list[dict[str, Any]] = []
        t_median = None
        for step in range(self.start_step, cfg.total_steps):
            if self._stop:
                break
            batch = self.data.batch_at(step)
            batch = jax.tree.map(jax.numpy.asarray, batch)
            t0 = time.perf_counter()
            new_state, metrics = self.train_step(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)

            if not np.isfinite(loss):
                # NaN guard: drop the update, keep the old state
                self.skipped_nan_steps.append(step)
                del new_state
                continue
            self.state = new_state

            if len(self.step_times) >= 5:
                t_median = statistics.median(self.step_times[-20:])
                if dt > cfg.straggler_factor * t_median:
                    self.straggler_steps.append(step)

            if (step + 1) % cfg.log_every == 0 or step == self.start_step:
                history.append({"step": step + 1, "loss": loss,
                                "lr": float(metrics["lr"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "step_time_s": dt})
            if (step + 1) % cfg.ckpt_every == 0:
                self._save(step + 1)

        final_step = step + 1 if not self._stop else step
        self._save(final_step, blocking=True)
        return {"history": history, "final_step": final_step,
                "stragglers": self.straggler_steps,
                "nan_skipped": self.skipped_nan_steps}

    def _save(self, step: int, blocking: bool | None = None):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()        # one async save in flight max
        blocking = (not self.cfg.ckpt_async) if blocking is None else blocking
        self._ckpt_thread = ckpt.save(
            self.cfg.ckpt_dir, step, self.state_to_host(),
            blocking=blocking, keep=self.cfg.keep_ckpts)
