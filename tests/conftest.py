"""Shared test helpers.

``hypothesis_or_stub`` lets property-test modules collect (and their
non-property tests run) when `hypothesis` is not installed: the property
tests themselves become individually-skipped stubs, and stay real property
tests whenever the dependency exists.
"""
from __future__ import annotations

import pytest


class _StubStrategies:
    """Accepts any strategy construction; the result is never executed."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


def hypothesis_or_stub():
    """Returns (given, settings, st) — real hypothesis or skipping stubs."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        pass

    def given(*args, **kwargs):
        def deco(fn):
            def stub():
                pytest.skip("hypothesis not installed")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn

    return given, settings, _StubStrategies()
