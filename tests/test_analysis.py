"""repro-lint + runtime-sanitizer suite (ISSUE 7).

Three layers of coverage:

* **per-pass fixtures** — every lint pass fires on a seeded known-bad
  snippet (the exact bug classes PRs 2-6 fixed by hand: f32 downcasts,
  host sync in hot paths, unfenced timing, unguarded lock state, spans
  opened outside ``with``) and stays silent on the fixed form;
* **meta-test** — the repo's own tree lints clean against the committed
  baseline, and the baseline carries no stale or unjustified entries;
* **sanitizer** — sanitized plans are bit-identical to plain ones on the
  in-memory / streamed / disk-streamed backends, every mttkrp contract
  violation raises, the admission-ledger audit catches seeded drift, and
  a threaded race-stress run over ``ServiceRuntime`` passes with the
  lock-order assertions armed.
"""
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (Baseline, Finding, SanitizedPlan, SanitizerError,
                            lint_paths, lint_sources, sanitize_enabled,
                            sanitized, wrap_plan)
from repro.analysis.sanitize import audit_scheduler, check_factors
from repro.core.blco import build_blco
from repro.core.tensor import random_tensor
from repro.engine import plan_for

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _only(findings, pass_id):
    return [f for f in findings if f.pass_id == pass_id]


# ---------------------------------------------------------------- dtype pass
BAD_DTYPE = '''
import jax.numpy as jnp

def coo_mttkrp(vals, cols, factors, mode):
    partial = vals[:, None].astype(factors[0].dtype)   # seeded f32 downcast
    return partial
'''

GOOD_DTYPE = '''
import jax.numpy as jnp

def coo_mttkrp(vals, cols, factors, mode):
    partial = vals[:, None].astype(jnp.result_type(vals, factors[0]))
    return partial
'''

BAD_DTYPE_ZEROS = '''
import jax.numpy as jnp

def stream_mttkrp(b, factors, mode, rank):
    out = jnp.zeros((b.dims[mode], rank), factors[0].dtype)
    return out
'''


def test_dtype_promotion_flags_factor_dtype_downcast():
    findings = _only(lint_sources({"src/repro/core/x.py": BAD_DTYPE}),
                     "dtype-promotion")
    assert len(findings) == 1
    assert findings[0].symbol == "coo_mttkrp"
    assert "result_type" in findings[0].message


def test_dtype_promotion_flags_zeros_with_factor_dtype():
    findings = _only(lint_sources({"src/repro/core/x.py": BAD_DTYPE_ZEROS}),
                     "dtype-promotion")
    assert len(findings) == 1


def test_dtype_promotion_clean_on_result_type_idiom():
    assert not _only(lint_sources({"src/repro/core/x.py": GOOD_DTYPE}),
                     "dtype-promotion")


# ------------------------------------------------------------ host-sync pass
BAD_HOST_SYNC = '''
import numpy as np
import jax

@jax.jit
def hot_kernel(x):
    limits = np.cumsum(x)        # host round-trip inside a jitted fn
    return limits
'''


def test_host_sync_flags_numpy_in_jitted_fn():
    findings = _only(
        lint_sources({"src/repro/engine/plans.py": BAD_HOST_SYNC}),
        "host-sync-in-hot-path")
    assert len(findings) == 1
    assert findings[0].symbol == "hot_kernel"


def test_host_sync_scoped_to_hot_files():
    # the same source outside the hot-path scope is not this pass's business
    assert not _only(lint_sources({"src/repro/obs/export.py": BAD_HOST_SYNC}),
                     "host-sync-in-hot-path")


# ------------------------------------------------------- unfenced-timing pass
BAD_TIMING = '''
import time

def bench_mttkrp(plan, factors):
    t0 = time.perf_counter()
    out = plan.mttkrp(factors, 0)         # async dispatch...
    dt = time.perf_counter() - t0         # ...timed without a fence
    return out, dt
'''

GOOD_TIMING = '''
import time

def bench_mttkrp(plan, factors):
    t0 = time.perf_counter()
    out = plan.mttkrp(factors, 0)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    return out, dt
'''


def test_unfenced_timing_flags_missing_fence():
    findings = _only(lint_sources({"src/repro/bench.py": BAD_TIMING}),
                     "unfenced-timing")
    assert len(findings) == 1
    assert findings[0].symbol == "bench_mttkrp"


def test_unfenced_timing_clean_when_fenced():
    assert not _only(lint_sources({"src/repro/bench.py": GOOD_TIMING}),
                     "unfenced-timing")


# -------------------------------------------------------- lock-discipline pass
BAD_LOCK = '''
import threading

class Runtime:
    def __init__(self):
        self._lock = threading.RLock()
        self._thread = None

    def start(self):
        with self._lock:
            self._thread = object()

    def stop(self):
        if self._thread is not None:      # unguarded read of guarded state
            self._thread = None
'''

GOOD_LOCK = BAD_LOCK.replace(
    """        if self._thread is not None:      # unguarded read of guarded state
            self._thread = None""",
    """        with self._lock:
            if self._thread is not None:
                self._thread = None""")

BAD_LOCK_MUTATOR = '''
import threading

class Runtime:
    def __init__(self):
        self._lock = threading.Lock()
        self._feeds = []

    def subscribe(self, feed):
        with self._lock:
            self._feeds.append(feed)

    def reset(self):
        self._feeds.clear()               # container mutation, no lock
'''

BAD_SINGLETON = '''
import threading

class TracerState:
    def __init__(self):
        self.lock = threading.Lock()
        self.enabled = False

TRACING = TracerState()

def enable():
    with TRACING.lock:
        TRACING.enabled = True

def disable():
    TRACING.enabled = False               # singleton write outside its lock

def is_enabled():
    return TRACING.enabled                # reads stay lock-free by design
'''


def test_lock_discipline_flags_unguarded_attribute():
    """Acceptance: reintroducing an unguarded ``_lock``-protected attribute
    access (the pre-fix ``ServiceRuntime.stop`` shape) is caught."""
    findings = _only(lint_sources({"src/repro/service/x.py": BAD_LOCK}),
                     "lock-discipline")
    assert len(findings) == 1
    assert findings[0].symbol == "Runtime.stop"
    assert "_thread" in findings[0].message


def test_lock_discipline_clean_when_guarded():
    assert not _only(lint_sources({"src/repro/service/x.py": GOOD_LOCK}),
                     "lock-discipline")


def test_lock_discipline_counts_container_mutation_as_write():
    findings = _only(
        lint_sources({"src/repro/service/x.py": BAD_LOCK_MUTATOR}),
        "lock-discipline")
    assert len(findings) == 1
    assert findings[0].symbol == "Runtime.reset"


def test_lock_discipline_singleton_write_needs_lock():
    findings = _only(lint_sources({"src/repro/obs/x.py": BAD_SINGLETON}),
                     "lock-discipline")
    assert len(findings) == 1
    assert findings[0].symbol == "disable"   # the read in is_enabled is fine


# ----------------------------------------------------------- span-hygiene pass
BAD_SPAN = '''
from repro.obs import trace as obs_trace

def run(plan, factors):
    obs_trace.span("plan.mttkrp", "plan")      # never entered: records nothing
    return plan.mttkrp(factors, 0)
'''

GOOD_SPAN = '''
from repro.obs import trace as obs_trace

def run(plan, factors):
    with obs_trace.span("plan.mttkrp", "plan"):
        return plan.mttkrp(factors, 0)
'''


def test_span_hygiene_flags_unentered_span():
    findings = _only(lint_sources({"src/repro/engine/x.py": BAD_SPAN}),
                     "span-hygiene")
    assert len(findings) == 1


def test_span_hygiene_clean_inside_with():
    assert not _only(lint_sources({"src/repro/engine/x.py": GOOD_SPAN}),
                     "span-hygiene")


# ------------------------------------------------------- suppression machinery
def test_inline_disable_comment_suppresses():
    src = BAD_TIMING.replace(
        "    t0 = time.perf_counter()",
        "    t0 = time.perf_counter()",
        1).replace(
        "def bench_mttkrp(plan, factors):",
        "def bench_mttkrp(plan, factors):  "
        "# repro-lint: disable=unfenced-timing")
    assert not _only(lint_sources({"src/repro/bench.py": src}),
                     "unfenced-timing")


def test_baseline_requires_reason():
    with pytest.raises(ValueError, match="reason"):
        Baseline([{"pass": "dtype-promotion", "path": "x.py",
                   "symbol": "f", "reason": ""}])


def test_baseline_suppresses_and_reports_stale():
    f = Finding(pass_id="unfenced-timing", path="src/repro/bench.py",
                symbol="bench_mttkrp", line=5, message="m")
    base = Baseline([
        {"pass": "unfenced-timing", "path": "src/repro/bench.py",
         "symbol": "bench_mttkrp", "reason": "known, tracked in ISSUE 7"},
        {"pass": "dtype-promotion", "path": "gone.py", "symbol": "f",
         "reason": "file was deleted"},
    ])
    assert base.suppresses(f)
    stale = base.stale_entries([f])
    assert len(stale) == 1 and stale[0]["path"] == "gone.py"


# ------------------------------------------------------------------ meta-test
def test_repo_tree_lints_clean_against_committed_baseline():
    """The repo's own invariants hold: zero findings outside the committed
    baseline, and the baseline itself carries no stale entries."""
    findings = lint_paths([os.path.join(REPO, "src", "repro")], root=REPO)
    baseline = Baseline.load(os.path.join(REPO, "scripts",
                                          "lint_baseline.json"))
    unsuppressed = [f.render() for f in findings
                    if not baseline.suppresses(f)]
    assert unsuppressed == []
    assert baseline.stale_entries(findings) == []


# ============================================================ sanitizer layer
@pytest.fixture
def small():
    t = random_tensor((12, 9, 7), nnz=180, seed=3)
    return t, build_blco(t, max_nnz_per_block=1 << 10)


def _factors(dims, rank, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)), dtype)
            for d in dims]


def test_sanitize_env_gate(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitize_enabled()
    with sanitized():
        assert sanitize_enabled()        # override beats the environment
    assert not sanitize_enabled()


@pytest.mark.parametrize("backend", ["in_memory", "streamed",
                                     "disk_streamed"])
def test_sanitized_plan_bit_identical(small, tmp_path, backend):
    """Acceptance: sanitize=True changes nothing about the numbers — the
    wrapper only inspects, on every backend tier."""
    t, b = small
    factors = _factors(t.dims, 5)
    kwargs = dict(rank=5, backend=backend)
    if backend == "disk_streamed":
        kwargs["store_path"] = str(tmp_path / "t.blco")
    plain = plan_for(b, 1 << 30, sanitize=False, **kwargs)
    sane = plan_for(b, 1 << 30, sanitize=True, **kwargs)
    assert type(sane) is SanitizedPlan and type(plain) is not SanitizedPlan
    try:
        for mode in range(t.order):
            out_p = np.asarray(plain.mttkrp(factors, mode))
            out_s = np.asarray(sane.mttkrp(factors, mode))
            np.testing.assert_array_equal(out_p, out_s)
    finally:
        plain.close()
        sane.close()


def test_sanitized_plan_isinstance_transparent(small):
    from repro.engine.plans import InMemoryPlan
    t, b = small
    plan = plan_for(b, 1 << 30, rank=4, backend="in_memory", sanitize=True)
    try:
        assert isinstance(plan, InMemoryPlan)       # regime checks see through
        assert isinstance(plan, SanitizedPlan)      # the wrap is still visible
        assert wrap_plan(plan, enable=True) is plan  # idempotent
    finally:
        plan.close()


class _FakePlan:
    """Minimal ExecutionPlan double with a controllable mttkrp result."""
    dims = (4, 3)
    backend = "fake"

    def __init__(self, result):
        self._result = result

    def mttkrp(self, factors, mode):
        return self._result


def test_sanitizer_rejects_factor_shape_and_mode():
    plan = SanitizedPlan(_FakePlan(jnp.zeros((4, 2))))
    good = [jnp.zeros((4, 2)), jnp.zeros((3, 2))]
    with pytest.raises(SanitizerError, match="factor matrices"):
        plan.mttkrp(good[:1], 0)
    with pytest.raises(SanitizerError, match="out of range"):
        plan.mttkrp(good, 2)
    with pytest.raises(SanitizerError, match="factor 1 has shape"):
        plan.mttkrp([jnp.zeros((4, 2)), jnp.zeros((5, 2))], 0)
    assert plan.mttkrp(good, 0).shape == (4, 2)


def test_sanitizer_rejects_output_shape_downcast_and_nonfinite():
    good = [jnp.zeros((4, 2)), jnp.zeros((3, 2))]
    with pytest.raises(SanitizerError, match="output shape"):
        SanitizedPlan(_FakePlan(jnp.zeros((3, 2)))).mttkrp(good, 0)
    with pytest.raises(SanitizerError, match="downcast"):
        SanitizedPlan(_FakePlan(jnp.zeros((4, 2), jnp.float16))) \
            .mttkrp(good, 0)
    with pytest.raises(SanitizerError, match="non-finite"):
        SanitizedPlan(_FakePlan(jnp.full((4, 2), jnp.nan))).mttkrp(good, 0)


def test_check_factors_guards_nan():
    with sanitized():
        check_factors([jnp.ones((3, 2))], "ok")
        with pytest.raises(SanitizerError, match="non-finite factor"):
            check_factors([jnp.ones((3, 2)),
                           jnp.full((2, 2), jnp.inf)], "sweep 3")
    # disabled: same call is a no-op
    check_factors([jnp.full((2, 2), jnp.nan)], "off")


# ------------------------------------------------------- service integration
def _service(tmp_path, budget=64 << 20):
    from repro.service import DecompositionService
    return DecompositionService(device_budget_bytes=budget, queues=2)


def test_scheduler_ledger_audit_catches_seeded_drift(tmp_path):
    """Acceptance: a hand-corrupted admission ledger (the PR-4 overcommit
    class) trips the audit on the next lifecycle edge."""
    from repro.service.api import SubmitDecomposition
    svc = _service(tmp_path)
    t = random_tensor((10, 8, 6), nnz=120, seed=0)
    with sanitized():
        job = svc.submit(SubmitDecomposition(tensor=t, rank=4, iters=2,
                                             tol=0.0))
        svc.scheduler.metrics.hold_bytes(4096)      # seeded drift
        with pytest.raises(SanitizerError, match="ledger out of sync"):
            svc.scheduler.cancel(job)


def test_scheduler_clean_run_passes_audit(tmp_path):
    from repro.service.api import SubmitDecomposition
    svc = _service(tmp_path)
    t = random_tensor((10, 8, 6), nnz=120, seed=0)
    with sanitized():
        svc.submit(SubmitDecomposition(tensor=t, rank=4, iters=2, tol=0.0))
        svc.run()
        audit_scheduler(svc.scheduler, "test: post-run")
    assert svc.scheduler.metrics.admitted_reservation_bytes == 0


def test_guard_lock_assertion_fires_without_runtime_lock(tmp_path):
    """A runtime-owned scheduler mutated without the runtime lock is the
    race the sanitizer's lock-order assertion exists for."""
    from repro.service import ServiceRuntime
    from repro.service.api import SubmitDecomposition
    rt = ServiceRuntime(device_budget_bytes=64 << 20, queues=2)
    t = random_tensor((10, 8, 6), nnz=120, seed=0)
    handle = rt.service.registry.register(t)
    with sanitized():
        with pytest.raises(SanitizerError, match="runtime lock"):
            rt.scheduler.submit(handle, rank=4)     # bypasses rt.submit
        job = rt.submit(SubmitDecomposition(tensor=t, rank=4, iters=1,
                                            tol=0.0))  # the locked path works
    assert job == 0


def test_runtime_race_stress_under_sanitizer():
    """Threaded submit/cancel/set_weight/status against a live runtime with
    every sanitizer check armed: no SanitizerError, no lost jobs, ledger
    drained to zero."""
    from repro.service import ServiceRuntime
    from repro.service.api import CancelJob, SetWeight, SubmitDecomposition
    tensors = [random_tensor((10, 8, 6), nnz=100, seed=s) for s in range(3)]
    errors = []
    with sanitized():
        with ServiceRuntime(device_budget_bytes=128 << 20, queues=2) as rt:
            ids = []
            ids_lock = threading.Lock()

            def submitter(seed):
                try:
                    for i in range(3):
                        jid = rt.submit(SubmitDecomposition(
                            tensor=tensors[(seed + i) % 3], rank=4,
                            iters=3, tol=0.0, seed=seed,
                            tenant=f"t{seed}", weight=1.0 + seed))
                        with ids_lock:
                            ids.append(jid)
                except BaseException as exc:      # noqa: BLE001
                    errors.append(exc)

            def meddler():
                try:
                    for _ in range(20):
                        with ids_lock:
                            snapshot = list(ids)
                        for jid in snapshot:
                            st = rt.status(jid).state
                            if st == "running":
                                try:
                                    rt.set_weight(SetWeight(weight=2.0,
                                                            job_id=jid))
                                except ValueError:
                                    pass          # already terminal: fine
                        rt.service_metrics()
                except BaseException as exc:      # noqa: BLE001
                    errors.append(exc)

            def canceller():
                try:
                    for _ in range(10):
                        with ids_lock:
                            snapshot = list(ids)
                        if snapshot:
                            rt.cancel(CancelJob(job_id=snapshot[0]))
                except BaseException as exc:      # noqa: BLE001
                    errors.append(exc)

            threads = [threading.Thread(target=submitter, args=(s,))
                       for s in range(3)]
            threads += [threading.Thread(target=meddler),
                        threading.Thread(target=canceller)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            assert rt.drain(timeout=300)
            assert not errors, errors
            states = {jid: rt.status(jid).state for jid in ids}
            assert len(states) == 9
            assert all(s in ("done", "cancelled") for s in states.values())
            assert rt.scheduler.metrics.admitted_reservation_bytes == 0


def test_sanitizer_overhead_smoke(small):
    """The wrapper's checks are O(output) per call; a sanitized sweep stays
    within an order of magnitude of plain (this is a smoke bound against
    accidental per-element Python work, not a perf benchmark)."""
    import time
    t, b = small
    factors = _factors(t.dims, 4)
    plain = plan_for(b, 1 << 30, rank=4, backend="in_memory", sanitize=False)
    sane = plan_for(b, 1 << 30, rank=4, backend="in_memory", sanitize=True)
    try:
        for plan in (plain, sane):                 # warm both paths
            plan.mttkrp(factors, 0).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            plain.mttkrp(factors, 0).block_until_ready()
        t_plain = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(5):
            sane.mttkrp(factors, 0).block_until_ready()
        t_sane = time.perf_counter() - t0
    finally:
        plain.close()
        sane.close()
    assert t_sane < 50 * max(t_plain, 1e-4)
