"""Benchmark harness regressions: the _time warmup=0 fix and the
machine-readable BENCH_3.json dispatch bench."""
import json
import os
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

import run as bench                                    # noqa: E402


def test_time_with_zero_warmup():
    """Satellite: _time(warmup=0) used to crash with NameError (r unbound)."""
    calls = []
    t = bench._time(lambda: calls.append(1), warmup=0, iters=3)
    assert t >= 0 and len(calls) == 3
    # still correct with warmup and a device-array result
    import jax.numpy as jnp
    t = bench._time(lambda: jnp.arange(8) * 2, warmup=1, iters=2)
    assert t >= 0


def test_bench_dispatch_json_schema(tmp_path, monkeypatch):
    """Fast-mode dispatch bench emits the machine-readable trajectory file
    with one-dispatch cached paths and the loop's per-launch dispatches."""
    monkeypatch.setattr(bench, "SUITE", ["uber-like"])
    path = tmp_path / "BENCH_3.json"
    rows = []
    payload = bench.bench_dispatch(rows, fast=True, json_path=str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert payload["bench"] == "fused_single_dispatch_blco_mttkrp"
    assert payload["geomean_speedup_cached_scan_vs_per_launch_loop"] > 0
    s = payload["suites"]["uber-like"]
    for key in ("per_launch_loop_us", "cached_scan_xla_us",
                "fused_pallas_us", "phases_pallas_us", "launches"):
        assert s[key] > 0, key
    assert s["dispatches_per_call_cached"] == 1
    assert s["dispatches_per_call_loop"] == s["launches"] > 1
    assert any(name.startswith("bench3.") for name, _, _ in rows)


def test_bench_multitenant_json_schema(tmp_path):
    """The weighted multi-tenant bench emits per-tenant iterations/sec and
    shares within 10% of the weights (the ISSUE 4 acceptance), plus the
    measured bytes freed by a mid-run cancellation."""
    path = tmp_path / "BENCH_4.json"
    rows = []
    payload = bench.bench_multitenant(rows, fast=True, json_path=str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert payload["bench"] == "weighted_multi_tenant_service"
    assert payload["max_share_deviation_vs_weights"] <= 0.1
    assert payload["cancelled_jobs"] == 1
    assert payload["cancel_freed_bytes"] > 0
    tenants = payload["tenants"]
    assert abs(sum(t["expected_share"] for t in tenants.values()) - 1) < 1e-9
    for name, t in tenants.items():
        assert t["iterations"] > 0 and t["iters_per_sec"] > 0, name
        assert abs(t["share"] - t["expected_share"]) <= \
            0.1 * t["expected_share"], name
    heavy, light = tenants["heavy"], tenants["light-1"]
    assert heavy["weight"] == 2 * light["weight"]
    assert heavy["iterations"] == 2 * light["iterations"]
    assert any(name.startswith("service4.") for name, _, _ in rows)


def test_bench_oom_json_schema(tmp_path):
    """The memory-hierarchy bench writes its store to the given dir and
    emits bit-identical per-tier timings + the bounded-window ratio."""
    path = tmp_path / "BENCH_5.json"
    store = tmp_path / "store"
    store.mkdir()
    rows = []
    payload = bench.bench_oom(rows, fast=True, json_path=str(path),
                              store_dir=str(store))
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert payload["bench"] == "memory_hierarchy_mttkrp"
    assert (store / "bench_oom.blco").exists()   # smoke-run on a real store
    for tier in ("in_memory", "host_streamed", "disk_streamed"):
        assert payload["us_per_call"][tier] > 0, tier
        assert payload["gb_per_s"][tier] > 0, tier
    assert payload["store_file_bytes"] > 0
    # the lazy/bounded window is strictly smaller than the old eager
    # all-launches-resident footprint (the satellite regression, measured)
    assert payload["host_window_bytes"] \
        < payload["all_launches_padded_bytes"]
    assert 0 < payload["host_window_ratio_vs_all_launches"] < 1
    d = payload["disk_stats"]
    assert d["disk_bytes"] > 0 and d["backend"] == "disk_streamed"
    assert any(name.startswith("bench5.") for name, _, _ in rows)


def test_committed_bench5_memory_hierarchy():
    """The committed memory-hierarchy trajectory must show all three tiers
    measured and a genuinely bounded disk-streaming host window."""
    path = os.path.join(REPO, "BENCH_5.json")
    assert os.path.exists(path), "BENCH_5.json must be committed"
    payload = json.loads(open(path).read())
    for tier in ("in_memory", "host_streamed", "disk_streamed"):
        assert payload["gb_per_s"][tier] > 0, tier
    assert payload["host_window_ratio_vs_all_launches"] < 0.5


def test_bench_roofline_json_schema(tmp_path):
    """The roofline bench measures peaks, attributes per-edge bandwidth
    per regime, and conserves against EngineStats exactly."""
    path = tmp_path / "BENCH_7.json"
    store = tmp_path / "store"
    store.mkdir()
    rows = []
    payload = bench.bench_roofline(rows, fast=True, json_path=str(path),
                                   store_dir=str(store))
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert payload["bench"] == "bandwidth_roofline"
    for edge in ("disk_host", "host_device", "device_hbm"):
        assert payload["peak_gb_per_s"][edge] > 0, edge
    assert payload["max_edge_rel_err"] == 0.0       # the conservation law
    for regime in ("in_memory", "streamed", "disk_streamed"):
        assert payload["saturated_edge"][regime] in (
            "disk_host", "host_device", "device_hbm"), regime
        assert payload["bound"][regime] in (
            "memory_bound", "compute_bound"), regime
        assert payload["us_per_call"][regime] > 0, regime
    assert payload["achieved_fraction"]             # non-empty, all > 0
    assert all(v > 0 for v in payload["achieved_fraction"].values())
    assert any(name.startswith("bench7.") for name, _, _ in rows)


def test_committed_bench7_roofline():
    """The committed roofline trajectory must conserve exactly, name a
    saturated edge for the disk-streamed and host-streamed regimes, and
    hold the tracing+ledger overhead bar on the in-memory path."""
    path = os.path.join(REPO, "BENCH_7.json")
    assert os.path.exists(path), "BENCH_7.json must be committed"
    payload = json.loads(open(path).read())
    assert payload["max_edge_rel_err"] == 0.0
    for regime in ("disk_streamed", "streamed"):
        assert payload["saturated_edge"][regime] in (
            "disk_host", "host_device", "device_hbm"), regime
        assert payload["achieved_fraction"][
            f"{regime}.{payload['saturated_edge'][regime]}"] > 0
    assert payload["obs_enabled_overhead_frac"] < 0.02


def test_committed_bench4_weighted_shares():
    """The committed multi-tenant trajectory must hold the 10% share bound
    and show a real cancellation release."""
    path = os.path.join(REPO, "BENCH_4.json")
    assert os.path.exists(path), "BENCH_4.json must be committed"
    payload = json.loads(open(path).read())
    assert payload["max_share_deviation_vs_weights"] <= 0.1
    assert payload["cancel_freed_bytes"] > 0


def test_committed_bench3_shows_speedup():
    """The committed perf trajectory must show the fused/cached path beating
    the PR-2 per-launch loop (acceptance: >= 2x on this machine)."""
    path = os.path.join(REPO, "BENCH_3.json")
    assert os.path.exists(path), "BENCH_3.json must be committed"
    payload = json.loads(open(path).read())
    assert payload["geomean_speedup_cached_scan_vs_per_launch_loop"] >= 2.0
    for name, s in payload["suites"].items():
        assert s["dispatches_per_call_cached"] == 1, name
