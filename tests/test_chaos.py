"""Seeded chaos soak (PR 8 acceptance): a mixed-tenant workload driven
through the async runtime under injected faults.

The invariant every scenario asserts: each job either completes
**bit-identical** to the fault-free reference run (transient faults are
absorbed by retry/demotion/watchdog) or quarantines FAILED with an
explanatory ``error_payload`` — never a hang, never a corrupted result,
never a dead worker, and the admission ledger is fully released at the
end (audited on every admit/retire edge by the runtime sanitizer).
"""
import numpy as np
import pytest

from repro.analysis.sanitize import set_sanitize
from repro.core.tensor import SparseTensor
from repro.faults import FaultPlan, FaultRule, WorkerCrashError, inject
from repro.service import ServiceRuntime, SubmitDecomposition

RANK = 4
ITERS = 5
BUDGET = 64 << 20
DRAIN_S = 300

# (tensor seed, ALS seed, tenant, weight); jobs 0 and 2 share a tensor,
# so pooled plan state is exercised under fault load too
WORKLOAD = ((0, 1, "acme", 1.0), (1, 2, "umbrella", 2.0),
            (0, 3, "umbrella", 1.0))


def _tensor(seed, nnz=200, dim=8):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, size=(nnz, 3)).astype(np.int64)
    vals = rng.standard_normal(nnz).astype(np.float32)
    return SparseTensor(indices=idx, values=vals, dims=(dim, dim, dim))


def _config(kind, tmp_path):
    if kind == "mem":
        return {"device_budget_bytes": BUDGET}
    # force the disk-streamed regime: a 1-byte host budget spills every
    # registration to the store, so jobs stream chunks through
    # store.read and stream.h2d
    return {"device_budget_bytes": BUDGET,
            "store_dir": str(tmp_path / "store"), "host_budget_bytes": 1}


def _run_workload(tmp_path, config_kind, *, runtime_kwargs=None):
    """Submit WORKLOAD, drain, and return per-job outcomes + metrics."""
    out = {}
    with ServiceRuntime(**(runtime_kwargs or {}),
                        **_config(config_kind, tmp_path)) as rt:
        ids = [rt.submit(SubmitDecomposition(
            tensor=_tensor(ts), rank=RANK, iters=ITERS, tol=0.0, seed=ss,
            tenant=tenant, weight=weight))
            for ts, ss, tenant, weight in WORKLOAD]
        assert rt.drain(timeout=DRAIN_S), "chaos workload failed to drain"
        for n, jid in enumerate(ids):
            st = rt.status(jid)
            if st.state == "done":
                res = rt.result(jid).result
                out[n] = ("done", tuple(res.fits),
                          np.asarray(res.factors[0]), None)
            else:
                out[n] = (st.state, None, None, st.error_payload)
        metrics = rt.service_metrics()
        worker_dead = rt._error is not None
    return out, metrics, worker_dead


@pytest.fixture(scope="module")
def references(tmp_path_factory):
    """Fault-free outcomes per config kind (regimes are bit-identical, but
    reference against the exact config anyway)."""
    assert not inject.FAULTS.enabled
    refs = {}
    for kind in ("mem", "disk"):
        out, metrics, dead = _run_workload(
            tmp_path_factory.mktemp(f"ref-{kind}"), kind)
        assert not dead
        assert all(v[0] == "done" for v in out.values())
        refs[kind] = out
    return refs


@pytest.fixture(autouse=True)
def _sanitized():
    """Ledger audit + factor checks on every scenario; no leftover plan."""
    set_sanitize(True)
    yield
    set_sanitize(None)
    inject.uninstall()


def _check_invariants(out, ref, metrics, worker_dead):
    assert not worker_dead, "worker died and stayed dead"
    for n, (state, fits, factors, payload) in out.items():
        if state == "done":
            assert fits == ref[n][1], f"job {n} diverged from reference"
            assert np.array_equal(factors, ref[n][2])
        else:
            assert state == "failed", f"job {n} ended {state!r}"
            assert payload is not None
            assert {"type", "message", "where", "transient",
                    "injected"} <= set(payload)
    assert metrics["admitted_reservation_bytes"] == 0   # ledger clean
    done = sum(1 for v in out.values() if v[0] == "done")
    failed = sum(1 for v in out.values() if v[0] == "failed")
    assert done == metrics["jobs_completed"]
    assert failed == metrics["jobs_failed"]


SCENARIOS = {
    # every store read fails permanently-corrupt: all jobs quarantine
    "store-corruption": ("disk", [
        FaultRule("store.read", kind="corrupt", p=1.0)]),
    # a sprinkle of transient I/O errors: retried, all jobs bit-identical
    "transient-io": ("disk", [
        FaultRule("store.read", kind="transient", nth=n)
        for n in (1, 5, 9)]),
    # an allocation failure on the first plan attempt: the ladder demotes
    # in_memory -> host-streamed and every job completes bit-identical
    # (the disk rung needs a store_dir; tests/test_faults.py covers it)
    "alloc-failure": ("mem", [FaultRule("plan.alloc", nth=1)]),
    # transient H2D put failures: retried, bit-identical
    "h2d-failure": ("disk", [
        FaultRule("stream.h2d", nth=n) for n in (2, 6)]),
    # an exception mid-quantum: exactly the struck job quarantines
    "quantum-exception": ("mem", [
        FaultRule("runtime.quantum", kind="exception", nth=2)]),
    # poisoned factors: the always-on NaN guard quarantines the job
    "nan-poison": ("mem", [FaultRule("factors.nan", nth=3)]),
    # the worker thread dies mid-run: the watchdog restarts it and every
    # job still completes bit-identical
    "worker-death": ("mem", [
        FaultRule("runtime.quantum", kind="crash", nth=3)]),
    # everything at once
    "mixed": ("disk", [
        FaultRule("store.read", kind="transient", nth=2),
        FaultRule("plan.alloc", nth=1),
        FaultRule("stream.h2d", nth=4),
        FaultRule("factors.nan", nth=6),
        FaultRule("runtime.quantum", kind="exception", nth=9)]),
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_chaos_scenario(name, references, tmp_path):
    config_kind, rules = SCENARIOS[name]
    plan = FaultPlan(seed=1234, rules=tuple(rules))
    with inject.active(plan):
        out, metrics, dead = _run_workload(tmp_path, config_kind)
    _check_invariants(out, references[config_kind], metrics, dead)

    failed = sum(1 for v in out.values() if v[0] == "failed")
    if name == "store-corruption":
        assert failed == len(WORKLOAD)      # permanent damage, all refused
    elif name == "transient-io":
        assert failed == 0 and metrics["retries_total"] >= 3
    elif name == "alloc-failure":
        assert failed == 0 and metrics["demotions_total"] >= 1
    elif name == "h2d-failure":
        assert failed == 0 and metrics["retries_total"] >= 2
    elif name in ("quantum-exception", "nan-poison"):
        assert failed == 1
        payload = next(v[3] for v in out.values() if v[0] == "failed")
        if name == "quantum-exception":
            assert payload["injected"] is True
        else:
            # the sanitizer (forced on here) catches the poison first;
            # without it the always-on finite-fit guard raises
            # FactorPoisonError — either way the job quarantines
            assert payload["type"] in ("FactorPoisonError", "SanitizerError")
            assert "nan" in payload["message"].lower() or \
                "finite" in payload["message"].lower()
    elif name == "worker-death":
        assert failed == 0
        assert metrics["watchdog_restarts"] == 1
    elif name == "mixed":
        assert failed >= 1                  # nan-poison at minimum
        assert plan.fired_log, "mixed scenario injected nothing"
    assert metrics["giveups_total"] == 0    # nth-faults never exhaust retry


def test_worker_crash_mid_sweep_resumes_exactly(references, tmp_path):
    """Kill the worker INSIDE a sweep (partial in-place factor mutation):
    the watchdog rolls the job back to its last completed sweep and the
    final trajectory is bit-identical to the fault-free run."""
    ref = references["mem"]
    events = []
    with ServiceRuntime(**_config("mem", tmp_path)) as rt:
        feed = rt.subscribe()
        jid = rt.submit(SubmitDecomposition(
            tensor=_tensor(0), rank=RANK, iters=ITERS, tol=0.0, seed=1,
            tenant="acme"))
        with rt._lock:
            job = rt.scheduler.jobs[jid]
            plan, bombed = job.plan, {"done": False}

            def bomb(factors, mode):
                if job.cp.iteration >= 2 and mode == 1 and not bombed["done"]:
                    bombed["done"] = True
                    raise WorkerCrashError("simulated segfault mid-sweep")
                return plan.mttkrp(factors, mode)

            job.mttkrp_fn = bomb
        st = rt.wait(jid, timeout=DRAIN_S)
        assert st.state == "done"
        fits = tuple(rt.result(jid).result.fits)
        factors = np.asarray(rt.result(jid).result.factors[0])
        m = rt.service_metrics()
        while True:
            ev = feed.get(timeout=0.1)
            if ev is None:
                break
            events.append(ev.kind)
    assert bombed["done"], "the mid-sweep bomb never detonated"
    assert m["watchdog_restarts"] == 1
    assert "rollback" in events             # the rewind was announced
    assert fits == ref[0][1]                # bit-identical despite the crash
    assert np.array_equal(factors, ref[0][2])


def test_worker_crash_resumes_from_auto_snapshot(tmp_path):
    """With auto-snapshots enabled, a mid-sweep crash rolls back to the
    checkpoint (not to iteration 0) and still finishes bit-identically."""
    store = str(tmp_path / "store")
    snap = str(tmp_path / "autosnap")
    with ServiceRuntime(device_budget_bytes=BUDGET,
                        store_dir=store) as rt:
        jid = rt.submit(SubmitDecomposition(
            tensor=_tensor(0), rank=RANK, iters=ITERS, tol=0.0, seed=1))
        rt.wait(jid, timeout=DRAIN_S)
        ref_fits = tuple(rt.result(jid).result.fits)

    with ServiceRuntime(device_budget_bytes=BUDGET, store_dir=store,
                        auto_snapshot_dir=snap,
                        auto_snapshot_every=1) as rt:
        jid = rt.submit(SubmitDecomposition(
            tensor=_tensor(0), rank=RANK, iters=ITERS, tol=0.0, seed=1))
        with rt._lock:
            job = rt.scheduler.jobs[jid]
            plan, bombed = job.plan, {"done": False}

            def bomb(factors, mode):
                if job.cp.iteration >= 3 and mode == 1 and not bombed["done"]:
                    bombed["done"] = True
                    raise WorkerCrashError("simulated segfault mid-sweep")
                return plan.mttkrp(factors, mode)

            job.mttkrp_fn = bomb
        st = rt.wait(jid, timeout=DRAIN_S)
        assert st.state == "done"
        fits = tuple(rt.result(jid).result.fits)
        m = rt.service_metrics()
        rolled_back_to = rt.scheduler.jobs[jid].cp.iteration
    assert bombed["done"]
    assert m["watchdog_restarts"] == 1
    assert fits == ref_fits
    assert rolled_back_to == ITERS


def test_watchdog_cap_surfaces_persistent_failure(tmp_path):
    """A worker that dies every quantum exhausts max_restarts and the
    legacy fail-stop contract still holds: callers get a typed error,
    never a hang."""
    plan = FaultPlan(seed=0, rules=(
        FaultRule("runtime.quantum", kind="crash", p=1.0),))
    with inject.active(plan):
        with ServiceRuntime(device_budget_bytes=BUDGET,
                            max_restarts=2) as rt:
            rt.submit(SubmitDecomposition(
                tensor=_tensor(0), rank=RANK, iters=ITERS, tol=0.0,
                seed=1))
            with pytest.raises(RuntimeError, match="worker failed"):
                rt.drain(timeout=DRAIN_S)
            assert rt.service.metrics.watchdog_restarts == 2
