"""CP-ALS behaviour: fit improvement, exact recovery, backend equivalence."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import core


def _norm(t):
    return float(np.linalg.norm(t.values))


def test_fit_monotone_improvement():
    t = core.random_tensor((40, 25, 30), 2000, seed=3, dist="powerlaw")
    b = core.build_blco(t)
    res = core.cp_als(lambda f, m: core.mttkrp(b, f, m), t.dims, 8,
                      norm_x=_norm(t), iters=10, seed=1)
    fits = res.fits
    assert fits[-1] > fits[0]
    # ALS fit is non-decreasing up to fp noise
    assert all(b2 >= a - 1e-3 for a, b2 in zip(fits, fits[1:]))


def test_exact_low_rank_recovery():
    """A synthetic rank-3 tensor must be fit to ~1.0 by rank-8 CP-ALS."""
    rng = np.random.default_rng(0)
    dims, r0 = (20, 16, 12), 3
    factors = [rng.standard_normal((d, r0)) for d in dims]
    dense = np.einsum("ir,jr,kr->ijk", *factors)
    idx = np.argwhere(np.abs(dense) > 0.5)          # sparsify
    vals = dense[tuple(idx.T)].astype(np.float32)
    t = core.from_coo(idx, vals, dims)
    b = core.build_blco(t)
    res = core.cp_als(lambda f, m: core.mttkrp(b, f, m), t.dims, 8,
                      norm_x=_norm(t), iters=60, seed=2, tol=1e-9)
    # the sampled tensor is not exactly low-rank, but fit must be high
    assert res.fits[-1] > 0.5, res.fits[-5:]


def test_backends_reach_same_fit():
    t = core.random_tensor((25, 18, 21), 1200, seed=4, dist="powerlaw")
    b = core.build_blco(t)
    coo = core.COOFormat.build(t)
    fits = {}
    for name, fn in [
        ("blco", lambda f, m: core.mttkrp(b, f, m)),
        ("coo", lambda f, m: core.coo_mttkrp(coo, f, m)),
    ]:
        res = core.cp_als(fn, t.dims, 6, norm_x=_norm(t), iters=8, seed=5)
        fits[name] = res.fits[-1]
    assert abs(fits["blco"] - fits["coo"]) < 1e-3, fits


def test_streaming_cp_als_matches_in_memory():
    t = core.random_tensor((30, 22, 14), 1500, seed=6, dist="powerlaw")
    b = core.build_blco(t, max_nnz_per_block=256)   # force multiple launches
    ex = core.OOMExecutor(b, queues=3)
    r1 = core.cp_als(lambda f, m: core.mttkrp(b, f, m), t.dims, 6,
                     norm_x=_norm(t), iters=5, seed=7)
    r2 = core.cp_als(lambda f, m: ex.mttkrp(f, m), t.dims, 6,
                     norm_x=_norm(t), iters=5, seed=7)
    np.testing.assert_allclose(r1.fits, r2.fits, rtol=1e-4, atol=1e-4)
    assert ex.stats.launches > 0 and ex.stats.h2d_bytes > 0


def test_reconstruction_shrinks_residual():
    t = core.random_tensor((15, 12, 10), 600, seed=8, dist="clustered")
    b = core.build_blco(t)
    res = core.cp_als(lambda f, m: core.mttkrp(b, f, m), t.dims, 10,
                      norm_x=_norm(t), iters=30, seed=9)
    dense = t.to_dense()
    recon = core.reconstruct_dense(res)
    resid = np.linalg.norm(dense - recon) / np.linalg.norm(dense)
    assert resid < 0.9
    assert abs((1 - resid) - res.fits[-1]) < 0.05   # fit formula consistency
