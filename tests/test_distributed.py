"""Distributed paths on 8 fake XLA devices (subprocess: device count must be
set before jax initializes, and the main test session must keep 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


def test_distributed_mttkrp_matches_oracle():
    out = _run("""
        import numpy as np, jax
        from jax.sharding import PartitionSpec as P
        from repro import core
        from repro.core.distributed import make_distributed_mttkrp
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((4, 2), ("data", "model"))
        t = core.random_tensor((64, 33, 17), 4000, seed=5, dist="powerlaw")
        b = core.build_blco(t, target_bits=10, max_nnz_per_block=512)
        rng = np.random.default_rng(0)
        factors = [jax.device_put(
            rng.standard_normal((d, 8)).astype(np.float32),
            jax.NamedSharding(mesh, P(None, "model"))) for d in t.dims]
        run = make_distributed_mttkrp(b, mesh)
        for mode in range(3):
            out = np.asarray(run(factors, mode))
            oracle = core.mttkrp_dense_oracle(
                t, [np.asarray(f) for f in factors], mode)
            rel = np.max(np.abs(out - oracle)) / (np.max(np.abs(oracle)) + 1e-30)
            assert rel < 1e-4, (mode, rel)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """The same train step on a 4x2 mesh and on 1 device must agree."""
    out = _run("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist import context as dist_context
        from repro.launch import steps
        from repro.launch.mesh import make_test_mesh
        from repro.models import build_model
        from repro.optim import adamw

        cfg = dataclasses.replace(get_config("dbrx_132b").reduced(),
                                  compute_dtype="float32",
                                  num_experts=8,   # divisible by model=2
                                  capacity_factor=8.0)  # no drops: the
        # sharded MoE applies capacity PER DATA SHARD (standard distributed
        # semantics), so only the drop-free regime matches single-device
        # exactly
        opt_cfg = adamw.AdamWConfig(total_steps=10)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)))}

        ref_step = jax.jit(steps.make_train_step(cfg, opt_cfg))
        ref_state, ref_metrics = ref_step(
            jax.tree.map(jnp.copy, state), batch)

        mesh = make_test_mesh((4, 2), ("data", "model"))
        with mesh:
            dist_context.set_mesh(mesh)
            state_sds = jax.eval_shape(lambda s: s, state)
            state_sh = steps.train_state_shardings(mesh, state_sds)
            sh_state = jax.tree.map(jax.device_put, state, state_sh)
            sh_step = jax.jit(steps.make_train_step(cfg, opt_cfg),
                              in_shardings=(state_sh, None),
                              out_shardings=(state_sh, None))
            new_state, metrics = sh_step(sh_state, batch)
            dist_context.set_mesh(None)

        # fp32 reduction order differs across layouts (TP-sharded einsums,
        # psum trees): semantic equivalence within fp32 reassociation noise
        assert abs(float(metrics["loss"]) - float(ref_metrics["loss"])) < 5e-3, (
            float(metrics["loss"]), float(ref_metrics["loss"]))
        # parameters after one update agree across the two layouts
        a = np.asarray(jax.device_get(
            new_state["params"]["moe_layers"]["attn"]["wq"]["w"]))
        b = np.asarray(jax.device_get(
            ref_state["params"]["moe_layers"]["attn"]["wq"]["w"]))
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_reshard_roundtrip():
    """Host snapshot -> different mesh -> values preserved (elastic restart)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch import steps
        from repro.launch.mesh import make_test_mesh
        from repro.models import build_model
        from repro.optim import adamw
        cfg = get_config("minicpm_2b").reduced()
        model = build_model(cfg)
        opt_cfg = adamw.AdamWConfig()
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}

        m1 = make_test_mesh((4, 2), ("data", "model"))
        m2 = make_test_mesh((2, 2), ("data", "model"))  # "failed" smaller fleet
        sds = jax.eval_shape(lambda s: s, state)
        sh1 = steps.train_state_shardings(m1, sds)
        sh2 = steps.train_state_shardings(m2, sds)
        on1 = jax.tree.map(jax.device_put, state, sh1)
        host = jax.tree.map(np.asarray, on1)
        on2 = jax.tree.map(jax.device_put, host, sh2)
        x1 = np.asarray(jax.device_get(on1["params"]["embed"]["table"]))
        x2 = np.asarray(jax.device_get(on2["params"]["embed"]["table"]))
        np.testing.assert_array_equal(x1, x2)
        print("OK")
    """)
    assert "OK" in out
