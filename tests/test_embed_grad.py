"""The paper's technique in the LM path: segment vs scatter embedding grads."""
import numpy as np
import jax
import jax.numpy as jnp

from conftest import hypothesis_or_stub

from repro.core import embedding_lookup

given, settings, st = hypothesis_or_stub()


@settings(max_examples=25, deadline=None)
@given(vocab=st.integers(3, 200), b=st.integers(1, 4), s=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_segment_equals_scatter(vocab, b, s, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((vocab, 8)).astype(np.float32))
    # Zipf ids -> heavy duplicates (the conflict regime the paper targets)
    ids = jnp.asarray((rng.zipf(1.3, size=(b, s)) % vocab).astype(np.int32))
    tgt = jnp.asarray(rng.standard_normal((b, s, 8)).astype(np.float32))

    def loss(tab, method):
        e = embedding_lookup(tab, ids, method)
        return jnp.sum((e - tgt) ** 2)

    g1 = jax.grad(lambda t: loss(t, "scatter"))(table)
    g2 = jax.grad(lambda t: loss(t, "segment"))(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-4)


def test_forward_is_plain_gather():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((50, 16)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, (3, 7)))
    for method in ("segment", "scatter"):
        out = embedding_lookup(table, ids, method)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(table)[np.asarray(ids)])


def test_grad_under_jit_and_vocab_padding():
    table = jnp.zeros((64, 4))
    ids = jnp.asarray([[1, 1, 1, 63]])   # duplicates + last row
    g = jax.jit(jax.grad(lambda t: embedding_lookup(t, ids, "segment").sum()))(
        table)
    assert float(g[1].sum()) == 12.0     # 3 occurrences x 4 dims
    assert float(g[63].sum()) == 4.0
    assert float(np.abs(np.asarray(g[2:63])).sum()) == 0.0
