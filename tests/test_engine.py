"""Unified engine API: regime auto-selection, plan semantics, oracle parity.

Acceptance scenario: a tensor whose device footprint fits the budget yields
an InMemoryPlan, an oversized one yields a StreamedPlan, and both produce
MTTKRP results matching the dense oracle to fp32 tolerance across all
modes — one ``plan_for`` call, one ``ExecutionPlan`` surface.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import core
from repro.engine import (BASELINE_KINDS, BaselinePlan, DefaultEngine,
                          ExecutionPlan, InMemoryPlan, StreamedPlan,
                          factor_bytes, in_memory_bytes, plan_for)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tensor():
    return core.random_tensor((30, 22, 14), 1500, seed=6, dist="powerlaw")


def _factors(dims, rank, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((d, rank)).astype(np.float32) for d in dims]


def _rel_err(a, oracle):
    return np.max(np.abs(np.asarray(a, np.float64) - oracle)) / \
        (np.max(np.abs(oracle)) + 1e-30)


def test_plan_for_auto_selects_regime_and_matches_oracle():
    t = _tensor()
    b = core.build_blco(t, max_nnz_per_block=256)
    factors = _factors(t.dims, 8)
    fits = in_memory_bytes(b) + factor_bytes(t.dims, 8, np.float32)

    big = plan_for(b, fits, rank=8)                   # exactly fits
    small = plan_for(b, fits - 1, rank=8, queues=2)   # one byte short
    assert isinstance(big, InMemoryPlan) and big.backend == "in_memory"
    assert isinstance(small, StreamedPlan) and small.backend == "streamed"
    assert isinstance(big, ExecutionPlan) and isinstance(small, ExecutionPlan)

    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        for plan in (big, small):
            assert _rel_err(plan.mttkrp(factors, mode), oracle) < 5e-4, \
                (plan.backend, mode)
    big.close()
    small.close()


def test_plan_device_bytes_and_close():
    t = _tensor()
    b = core.build_blco(t, max_nnz_per_block=256)
    plan = plan_for(b, 1 << 30, rank=8)
    # exact resident footprint: hi + lo + vals + bases, 256-lane padded
    padded = -(-b.nnz // 256) * 256
    assert plan.device_bytes() == padded * (4 + 4 + 4 + 4 * b.order)
    assert plan.device_bytes() == in_memory_bytes(b)
    freed = plan.close()
    assert freed == in_memory_bytes(b) and plan.device_bytes() == 0
    assert plan.close() == 0                          # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        plan.mttkrp(_factors(t.dims, 8), 0)

    stream = plan_for(b, 1 << 30, rank=8, backend="streamed", queues=3)
    assert stream.device_bytes() == stream.spec.bytes_in_flight(3)
    assert stream.close() == stream.spec.bytes_in_flight(3)
    assert stream.device_bytes() == 0


def test_no_regime_fits_raises():
    b = core.build_blco(_tensor(), max_nnz_per_block=256)
    with pytest.raises(ValueError, match="no regime fits"):
        plan_for(b, 1024, rank=8)
    with pytest.raises(ValueError, match="unknown backend"):
        plan_for(b, 1 << 30, rank=8, backend="nope")
    # explicit backends enforce the budget too (no silent bypass)
    with pytest.raises(ValueError, match="in-memory plan needs"):
        plan_for(b, 1024, rank=8, backend="in_memory")


def test_engine_stats_timing_split():
    t = _tensor()
    b = core.build_blco(t, max_nnz_per_block=128)
    plan = plan_for(b, 1 << 30, rank=4, backend="streamed", queues=2)
    plan.mttkrp(_factors(t.dims, 4), 0)
    s = plan.stats()
    assert s.backend == "streamed" and s.mttkrp_calls == 1
    assert s.launches == len(b.launches) and s.h2d_bytes > 0
    # the fenced device span covers (at least) the async dispatch span, and
    # the deprecated alias reads the fenced number
    assert s.device_time_s >= s.dispatch_time_s > 0
    assert s.compute_time_s == s.device_time_s
    assert s.total_time_s >= s.device_time_s
    plan.close()


@pytest.mark.parametrize("kind", BASELINE_KINDS)
def test_baseline_plans_from_blco_decode(kind):
    """BLCO's single copy decodes back to full coordinates: baseline plans
    built straight from the BLCO encoding match the oracle."""
    t = _tensor()
    b = core.build_blco(t, max_nnz_per_block=256)
    factors = _factors(t.dims, 8)
    plan = plan_for(b, 1 << 30, rank=8, backend=kind)
    assert isinstance(plan, BaselinePlan) and plan.backend == kind
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        assert _rel_err(plan.mttkrp(factors, mode), oracle) < 5e-4, mode
    assert plan.device_bytes() > 0
    plan.close()


def test_decode_coords_roundtrip():
    t = _tensor()
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=64)
    coords = core.decode_coords(b)
    # same multiset of (coords, value) rows as the original tensor
    got = {tuple(c) + (float(v),) for c, v in zip(coords, b.values)}
    want = {tuple(c) + (float(v),) for c, v in zip(t.indices, t.values)}
    assert got == want


def test_cp_als_accepts_plan_engine_and_callable():
    t = _tensor()
    b = core.build_blco(t)
    norm = float(np.linalg.norm(t.values))
    plan = plan_for(b, 1 << 30, rank=5)
    r_plan = core.cp_als(plan, t.dims, 5, norm_x=norm, iters=4, seed=2)
    r_fn = core.cp_als(lambda f, m: plan.mttkrp(f, m), t.dims, 5,
                       norm_x=norm, iters=4, seed=2)
    assert r_plan.fits == r_fn.fits
    for a, b_ in zip(r_plan.factors, r_fn.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b_))
    with pytest.raises(TypeError, match="MTTKRP backend"):
        core.as_mttkrp_fn(42)
    plan.close()


def test_default_engine_protocol():
    b = core.build_blco(_tensor(), max_nnz_per_block=256)
    eng = DefaultEngine(queues=2)
    plan = eng.plan(b, device_budget_bytes=1 << 30, rank=6)
    assert plan.backend == "in_memory"
    plan.close()
    fits = in_memory_bytes(b) + factor_bytes(b.dims, 6, np.float32)
    plan = eng.plan(b, device_budget_bytes=fits - 1, rank=6)
    assert plan.backend == "streamed"
    plan.close()


def test_zero_nnz_plans():
    t = core.from_coo(np.zeros((0, 3), np.int64), np.zeros((0,), np.float32),
                      (8, 6, 4))
    b = core.build_blco(t)
    factors = _factors(t.dims, 5)
    for backend in ("in_memory", "streamed"):
        plan = plan_for(b, 1 << 30, rank=5, backend=backend)
        out = np.asarray(plan.mttkrp(factors, 0))
        assert out.shape == (8, 5)
        np.testing.assert_array_equal(out, 0.0)
        plan.close()


def test_sharded_plan_via_mesh_context():
    """plan_for routes to ShardedPlan when a mesh is active (subprocess:
    fake XLA device count must be set before jax initializes)."""
    code = """
        import numpy as np
        from repro import core
        from repro.dist.context import set_mesh
        from repro.engine import plan_for
        from repro.launch.mesh import make_test_mesh
        set_mesh(make_test_mesh((4, 2), ("data", "model")))
        t = core.random_tensor((64, 33, 17), 4000, seed=5, dist="powerlaw")
        b = core.build_blco(t, target_bits=10, max_nnz_per_block=512)
        plan = plan_for(b, 1 << 30, rank=8)
        assert plan.backend == "sharded", plan.backend
        # nnz arrays shard over data (4) and replicate over model (2):
        # footprint counts every model-axis replica
        per = -(-b.nnz // 4)
        assert plan.device_bytes() == per * 4 * (4 + 4 + 4 + 4 * 3) * 2
        from repro.engine import sharded_bytes
        assert plan.device_bytes() == sharded_bytes(b, plan.mesh)
        # an undersized budget is rejected before any device upload
        try:
            plan_for(b, plan.device_bytes() // 2, rank=8)
            raise SystemExit("expected ValueError")
        except ValueError as e:
            assert "sharded plan needs" in str(e)
        rng = np.random.default_rng(0)
        factors = [rng.standard_normal((d, 8)).astype(np.float32)
                   for d in t.dims]
        for mode in range(t.order):
            oracle = core.mttkrp_dense_oracle(t, factors, mode)
            out = np.asarray(plan.mttkrp(factors, mode), np.float64)
            rel = np.max(np.abs(out - oracle)) / np.max(np.abs(oracle))
            assert rel < 5e-4, (mode, rel)
        assert plan.close() > 0
        print("SHARDED_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert p.returncode == 0, p.stderr[-4000:]
    assert "SHARDED_OK" in p.stdout
