"""Fault-injection harness: spec parsing, per-site taxonomy, retry layer,
degradation ladder, quarantine, registry self-heal, and the
reservation-leak regression (PR 8)."""
import gc
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import set_sanitize
from repro.core.blco import build_blco
from repro.core.tensor import SparseTensor
from repro.engine import plan_for
from repro.faults import (FaultPlan, FaultRule, FaultSpecError, Permanent,
                          RetryPolicy, Transient, inject, is_transient,
                          retry_call)
from repro.service import DecompositionService, SubmitDecomposition
from repro.service.registry import BuildParams, TensorRegistry
from repro.store import DiskStreamedPlan, StoreCorruptionError

RANK = 4
BUDGET = 64 << 20


def _tensor(seed=0, nnz=200, dim=8):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, size=(nnz, 3)).astype(np.int64)
    vals = rng.standard_normal(nnz).astype(np.float32)
    return SparseTensor(indices=idx, values=vals, dims=(dim, dim, dim))


def _factors(dims, rank=RANK):
    return [jnp.ones((d, rank), jnp.float32) for d in dims]


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    yield
    inject.uninstall()


# ------------------------------------------------------------ spec parsing
def test_spec_round_trip():
    plan = FaultPlan.from_spec(
        "7:store.read@p=0.3:transient;plan.alloc@n=1;stream.h2d@n=2,times=1")
    assert plan.seed == 7
    assert [r.site for r in plan.rules] == \
        ["store.read", "plan.alloc", "stream.h2d"]
    assert plan.rules[0].p == pytest.approx(0.3)
    assert plan.rules[1].kind == "alloc"          # site default kind
    assert plan.rules[2].nth == 2 and plan.rules[2].times == 1


@pytest.mark.parametrize("spec,match", [
    ("no-seed-prefix", "seed"),
    ("1:", "no rules"),
    ("1:not.a.site@n=1", "unknown fault site"),
    ("1:store.read@n=1:explode", "no fault kind"),
    ("1:store.read@n=1,p=0.5", "exactly one"),
    ("1:store.read", "exactly one"),
    ("1:store.read@p=2.0", "p must be"),
    ("1:store.read@n=0", "n must be"),
    ("1:store.read@bogus=3", "unknown qualifier"),
])
def test_spec_errors(spec, match):
    with pytest.raises(FaultSpecError, match=match):
        FaultPlan.from_spec(spec)


def test_env_reload(monkeypatch):
    monkeypatch.setenv(inject.ENV_VAR, "3:plan.alloc@n=1")
    plan = inject.reload_from_env()
    assert plan is not None and inject.FAULTS.enabled
    monkeypatch.setenv(inject.ENV_VAR, "")
    assert inject.reload_from_env() is None
    assert not inject.FAULTS.enabled


def test_nth_rule_fires_exactly_once():
    plan = FaultPlan(seed=0, rules=(FaultRule("stream.h2d", nth=2),))
    with inject.active(plan):
        assert inject.fire("stream.h2d") is None
        assert inject.fire("stream.h2d") == "transient"
        assert inject.fire("stream.h2d") is None
    assert plan.fired_log == [("stream.h2d", "transient", 2)]


def test_probabilistic_rule_is_seed_deterministic():
    def run(seed):
        plan = FaultPlan(seed=seed, rules=(
            FaultRule("store.read", p=0.5, kind="transient"),))
        return [plan.fire("store.read") for _ in range(32)]
    assert run(11) == run(11)
    assert run(11) != run(12)      # astronomically unlikely to collide


def test_undeclared_site_raises_when_enabled():
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("store.read", nth=1),))):
        with pytest.raises(FaultSpecError, match="undeclared"):
            # repro-lint: disable=fault-site-hygiene
            inject.fire("store.raed")


def test_disabled_probe_is_cheap_and_inert():
    assert not inject.FAULTS.enabled
    assert inject.fire("store.read") is None
    inject.maybe_fail("plan.alloc")            # no-op, no raise
    # the <1% overhead claim, reduced to its mechanism: a disabled probe
    # is one flag read.  At a handful of probes per ALS sweep (>= ms
    # each), sub-microsecond probes are noise.
    n = 20000
    t0 = time.perf_counter()
    for _ in range(n):
        inject.fire("store.read")
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6


# -------------------------------------------------------------- retry layer
def test_retry_absorbs_transients_and_counts():
    calls = {"n": 0}

    class Stats:
        retries = 0
        giveups = 0

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("flaky")
        return "ok"

    stats = Stats()
    policy = RetryPolicy(attempts=4, base_delay_s=0.0, max_delay_s=0.0)
    assert retry_call(flaky, site="t", policy=policy, stats=stats,
                      sleep=lambda s: None) == "ok"
    assert stats.retries == 2 and stats.giveups == 0


def test_retry_gives_up_and_reraises():
    class Stats:
        retries = 0
        giveups = 0

    stats = Stats()
    policy = RetryPolicy(attempts=3, base_delay_s=0.0, max_delay_s=0.0)

    def always():
        raise TimeoutError("down")

    with pytest.raises(TimeoutError):
        retry_call(always, site="t", policy=policy, stats=stats,
                   sleep=lambda s: None)
    assert stats.retries == 2 and stats.giveups == 1


def test_retry_permanent_fails_fast():
    calls = {"n": 0}

    def broken():
        calls["n"] += 1
        raise Permanent("no point")

    with pytest.raises(Permanent):
        retry_call(broken, site="t", sleep=lambda s: None)
    assert calls["n"] == 1


def test_transient_taxonomy():
    assert is_transient(OSError("x"))
    assert is_transient(TimeoutError("x"))
    assert is_transient(Transient("x"))
    assert not is_transient(ValueError("x"))
    assert not is_transient(StoreCorruptionError("x"))
    assert not is_transient(Permanent("x"))


# ------------------------------------------------------- per-site taxonomy
def test_store_read_transient_is_retried(tmp_path):
    blco = build_blco(_tensor())
    plan_ = FaultPlan(seed=3, rules=(
        FaultRule("store.read", kind="transient", nth=1),))
    with inject.active(plan_):
        p = DiskStreamedPlan.spill(blco, str(tmp_path / "t.blco"),
                                   delete_on_close=True)
        p.mttkrp(_factors(blco.dims), 0)
        st = p.stats()
        p.close()
    assert st.retries >= 1 and st.giveups == 0


def test_store_read_corruption_is_permanent(tmp_path):
    blco = build_blco(_tensor())
    plan_ = FaultPlan(seed=3, rules=(
        FaultRule("store.read", kind="corrupt", nth=1),))
    with inject.active(plan_):
        p = DiskStreamedPlan.spill(blco, str(tmp_path / "t.blco"),
                                   delete_on_close=True)
        with pytest.raises(StoreCorruptionError):
            p.mttkrp(_factors(blco.dims), 0)
        st = p.stats()
        p.close()
    assert st.retries == 0        # permanent faults are not retried


def test_alloc_failure_walks_the_ladder():
    blco = build_blco(_tensor())
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("plan.alloc", nth=1),))):
        p = plan_for(blco, BUDGET, rank=RANK)
    assert p.backend == "streamed" and p.stats().demotions == 1
    p.close()
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("plan.alloc", nth=1), FaultRule("plan.alloc", nth=2)))):
        p = plan_for(blco, BUDGET, rank=RANK)
    assert p.backend == "disk_streamed" and p.stats().demotions == 2
    out = p.mttkrp(_factors(blco.dims), 0)      # demoted plan still computes
    assert out.shape == (blco.dims[0], RANK)
    p.close()


def test_explicit_backend_never_demotes():
    blco = build_blco(_tensor())
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("plan.alloc", nth=1),))):
        with pytest.raises(inject.AllocationError):
            plan_for(blco, BUDGET, rank=RANK, backend="streamed")


def test_kernel_failure_falls_back_to_xla():
    blco = build_blco(_tensor())
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("plan.alloc", kind="kernel", nth=1),))):
        p = plan_for(blco, BUDGET, rank=RANK, kernel="pallas")
    assert p.stats().demotions == 1
    ref = plan_for(blco, BUDGET, rank=RANK, kernel="xla")
    np.testing.assert_array_equal(
        np.asarray(p.mttkrp(_factors(blco.dims), 0)),
        np.asarray(ref.mttkrp(_factors(blco.dims), 0)))
    p.close()
    ref.close()


def test_kernel_failure_on_xla_propagates():
    blco = build_blco(_tensor())
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("plan.alloc", kind="kernel", nth=1),))):
        with pytest.raises(inject.KernelFailure):
            plan_for(blco, BUDGET, rank=RANK, kernel="xla")


def test_h2d_transient_is_retried_bit_identical():
    blco = build_blco(_tensor())
    ref = plan_for(blco, BUDGET, rank=RANK, backend="streamed")
    want = np.asarray(ref.mttkrp(_factors(blco.dims), 0))
    ref.close()
    with inject.active(FaultPlan(seed=4, rules=(
            FaultRule("stream.h2d", nth=1),))):
        p = plan_for(blco, BUDGET, rank=RANK, backend="streamed")
        got = np.asarray(p.mttkrp(_factors(blco.dims), 0))
        st = p.stats()
        p.close()
    assert st.retries >= 1
    np.testing.assert_array_equal(got, want)


def test_quantum_exception_quarantines_job_only():
    svc = DecompositionService(device_budget_bytes=BUDGET)
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("runtime.quantum", kind="exception", nth=1),))):
        bad = svc.submit(SubmitDecomposition(tensor=_tensor(), rank=RANK,
                                             iters=3, tenant="a"))
        good = svc.submit(SubmitDecomposition(tensor=_tensor(seed=1),
                                              rank=RANK, iters=3,
                                              tenant="b"))
        svc.run()
    st = svc.status(bad)
    assert st.state == "failed"
    assert st.error_payload["injected"] is True
    assert st.error_payload["where"] == "runtime.quantum"
    assert svc.status(good).state == "done"
    m = svc.service_metrics()
    assert m["jobs_failed"] == 1 and m["jobs_completed"] == 1
    assert m["admitted_reservation_bytes"] == 0    # ledger fully released


def test_nan_poison_tripped_by_always_on_guard():
    svc = DecompositionService(device_budget_bytes=BUDGET)
    with inject.active(FaultPlan(seed=0, rules=(
            FaultRule("factors.nan", nth=2),))):
        jid = svc.submit(SubmitDecomposition(tensor=_tensor(), rank=RANK,
                                             iters=5))
        svc.run()
    st = svc.status(jid)
    assert st.state == "failed"
    assert st.error_payload["type"] == "FactorPoisonError"
    assert "poisoned" in st.error_payload["message"]
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0


# ------------------------------------------------- reservation-leak (PR 8)
def test_admission_failure_releases_charged_bytes():
    """Regression: an exception between the ledger charge and a fully
    registered running job must release the charged bytes (audited by the
    sanitizer ledger check on every admission edge)."""
    set_sanitize(True)
    try:
        svc = DecompositionService(device_budget_bytes=BUDGET)
        boom = {"armed": True}

        def bomb(job, kind):
            if kind == "admitted" and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("observer exploded mid-admission")

        svc.scheduler.observers.append(bomb)
        jid = svc.submit(SubmitDecomposition(tensor=_tensor(), rank=RANK,
                                             iters=2))
        st = svc.status(jid)
        assert st.state == "failed"
        assert st.error_payload["where"] == "scheduler.admit"
        m = svc.service_metrics()
        assert m["admitted_reservation_bytes"] == 0      # no leaked charge
        # the budget is genuinely reusable: the next job admits and runs
        ok = svc.submit(SubmitDecomposition(tensor=_tensor(seed=1),
                                            rank=RANK, iters=2))
        svc.run()
        assert svc.status(ok).state == "done"
        assert svc.service_metrics()["admitted_reservation_bytes"] == 0
    finally:
        set_sanitize(None)


def test_planning_alloc_fault_fails_job_not_worker():
    """plan.alloc failures that survive every ladder rung quarantine the
    job; the ledger stays clean and later submissions are unaffected."""
    set_sanitize(True)
    try:
        svc = DecompositionService(device_budget_bytes=BUDGET)
        # fail the resident, streamed, and (absent) disk rungs: no
        # store_path, so after the streamed rung the failure surfaces
        rules = tuple(FaultRule("plan.alloc", nth=n) for n in (1, 2, 3))
        with inject.active(FaultPlan(seed=0, rules=rules)):
            jid = svc.submit(SubmitDecomposition(tensor=_tensor(),
                                                 rank=RANK, iters=2))
        st = svc.status(jid)
        assert st.state == "failed"
        assert st.error_payload["injected"] is True
        assert svc.service_metrics()["admitted_reservation_bytes"] == 0
        ok = svc.submit(SubmitDecomposition(tensor=_tensor(seed=1),
                                            rank=RANK, iters=2))
        svc.run()
        assert svc.status(ok).state == "done"
    finally:
        set_sanitize(None)


# ------------------------------------------------------ registry self-heal
def _corrupt(path):
    """Flip one byte inside the ``vals`` section (sections are page-
    aligned, so an arbitrary offset would likely hit dead padding)."""
    import json
    with open(path, "rb") as f:
        fixed = f.read(20)
        hlen = int(np.frombuffer(fixed[12:16], np.uint32)[0])
        sec = json.loads(f.read(hlen))["sections"]["vals"]
    off = sec["offset"] + sec["nbytes"] // 2
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1)
        f.seek(off)
        f.write(bytes([byte[0] ^ 0xFF]))


def test_registry_self_heals_corrupt_store(tmp_path):
    reg = TensorRegistry(store_dir=str(tmp_path))
    t = _tensor()
    handle = reg.register(t, build=BuildParams())
    ref_vals = np.array(handle.blco.values)
    reg.spill(handle.key)
    _corrupt(handle.store_path)
    healed = reg.load(handle.key)            # rebuilds from the live COO
    assert reg.rebuilds == 1
    assert not healed.quarantined
    np.testing.assert_array_equal(np.array(healed.blco.values), ref_vals)
    # the re-persisted file is intact: spill + reload round-trips
    reg.spill(handle.key)
    assert np.array_equal(np.array(reg.load(handle.key).blco.values),
                          ref_vals)


def test_registry_quarantines_without_source(tmp_path):
    reg = TensorRegistry(store_dir=str(tmp_path))
    t = _tensor()
    handle = reg.register(t, build=BuildParams())
    reg.spill(handle.key)
    _corrupt(handle.store_path)
    del t                                     # the COO is gone
    gc.collect()
    with pytest.raises(StoreCorruptionError):
        reg.load(handle.key)
    assert handle.quarantined
    assert "no source tensor" in handle.quarantine_reason
    assert reg.rebuilds == 0


def test_quarantined_handle_refuses_new_jobs(tmp_path):
    svc = DecompositionService(device_budget_bytes=BUDGET,
                               store_dir=str(tmp_path))
    t = _tensor()
    jid = svc.submit(SubmitDecomposition(tensor=t, rank=RANK, iters=1))
    svc.run()
    assert svc.status(jid).state == "done"
    handle = svc.scheduler.jobs[jid].handle
    handle.quarantined = True
    handle.quarantine_reason = "simulated unrebuildable corruption"
    j2 = svc.submit(SubmitDecomposition(tensor=t, rank=RANK, iters=1))
    st = svc.status(j2)
    assert st.state == "failed"
    assert "quarantined" in st.error_payload["message"]


# ------------------------------------------------------------ lint hygiene
def test_fault_site_hygiene_pass():
    from repro.analysis.linter import ParsedModule
    from repro.analysis.passes import FaultSiteHygienePass
    bad = ParsedModule("x/y.py", (
        "from repro.faults import inject as faults\n"
        "def f():\n"
        "    faults.maybe_fail('store.raed')\n"
        "    faults.fire('plan.alloc')\n"))
    findings = FaultSiteHygienePass().check(bad)
    assert len(findings) == 1
    assert "store.raed" in findings[0].message
    ok = ParsedModule("x/y.py", (
        "from repro.faults import inject as faults\n"
        "def f(site):\n"
        "    faults.maybe_fail('stream.h2d')\n"
        "    faults.fire(site)\n"))       # non-literal: runtime-validated
    assert FaultSiteHygienePass().check(ok) == []
