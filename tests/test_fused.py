"""Fused single-pallas_call MTTKRP vs the dense matricization oracle.

Satellite coverage (ISSUE 3): orders 3-5, both conflict resolutions
(``register`` -> segment variant, ``hierarchical`` -> stash variant on the
short-mode tensors), ragged nnz counts that exercise the reservation
padding slots, and both interpret and compiled configurations (compiled
runs only where a Pallas-capable backend exists; the CPU container
validates through the interpreter).
"""
import jax
import numpy as np
import pytest

from repro import core
from repro.core.launches import LaunchCache
from repro.kernels import (fused_cache_mttkrp, pallas_mttkrp,
                           pallas_mttkrp_phases)
from repro.kernels.fused import STASH_MAX_ROWS, _variant_for

# (dims, nnz, target_bits, max_nnz_per_block) — ragged nnz on purpose: none
# is a multiple of the 256-slot tile, so every launch ends in padding slots
CASES = [
    ((70, 40, 30), 1777, 12, 512),             # order 3
    ((13, 7, 29, 5), 499, 8, 64),              # order 4, forced blocking
    ((128, 4, 256, 8, 3), 801, 16, 128),       # order 5
]

COMPILED_OK = jax.default_backend() in ("tpu", "gpu")


def _factors(dims, rank=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((d, rank)).astype(np.float32) for d in dims]


def _rel_err(a, oracle):
    return np.max(np.abs(np.asarray(a, np.float64) - oracle)) / \
        (np.max(np.abs(oracle)) + 1e-30)


@pytest.mark.parametrize("interpret", [
    True,
    pytest.param(False, marks=pytest.mark.skipif(
        not COMPILED_OK,
        reason="compiled pallas_call needs a TPU/GPU backend")),
])
@pytest.mark.parametrize("resolution", ["register", "hierarchical"])
@pytest.mark.parametrize("dims,nnz,tb,mx", CASES)
def test_fused_matches_oracle_all_modes(dims, nnz, tb, mx, resolution,
                                        interpret):
    t = core.random_tensor(dims, nnz, seed=7, dist="powerlaw")
    b = core.build_blco(t, target_bits=tb, max_nnz_per_block=mx)
    factors = _factors(dims)
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        out = pallas_mttkrp(b, factors, mode, resolution=resolution,
                            interpret=interpret)
        assert _rel_err(out, oracle) < 5e-4, (mode, resolution)


def test_fused_exercises_both_variants():
    """The parametrized sweep hits the stash (hierarchical) variant on the
    short-mode cases and the segment variant on everything else; the mode
    -> variant mapping follows the §5.3 heuristic with the VMEM row bound."""
    assert _variant_for("hierarchical", STASH_MAX_ROWS) == "stash"
    assert _variant_for("hierarchical", STASH_MAX_ROWS + 1) == "segment"
    assert _variant_for("register", 4) == "segment"
    assert _variant_for("auto", 4) == "segment"        # resolved upstream
    # every CASES dims fits the stash bound, so the hierarchical sweep above
    # really ran the stash kernel on all modes
    assert all(d <= STASH_MAX_ROWS for dims, _, _, _ in CASES for d in dims)
    # and a long target mode falls back to the segment kernel + scatter
    t = core.random_tensor((600, 9, 8), 700, seed=11, dist="powerlaw")
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    factors = _factors(t.dims)
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    out = pallas_mttkrp(b, factors, 0, resolution="hierarchical")
    assert _rel_err(out, oracle) < 5e-4


@pytest.mark.parametrize("dims,nnz,tb,mx", CASES[:2])
def test_fused_single_dispatch_and_no_host_padding(dims, nnz, tb, mx):
    t = core.random_tensor(dims, nnz, seed=3, dist="powerlaw")
    b = core.build_blco(t, target_bits=tb, max_nnz_per_block=mx)
    factors = _factors(dims)
    cache = LaunchCache.from_blco(b)
    # warm the jit cache, then count: exactly ONE dispatch per call
    fused_cache_mttkrp(cache, factors, 0)
    c0 = core.dispatch_count()
    fused_cache_mttkrp(cache, factors, 0)
    assert core.dispatch_count() - c0 == 1
    # the three-phase reference records its three device phases
    c0 = core.dispatch_count()
    pallas_mttkrp_phases(b, factors, 0, cache=cache)
    assert core.dispatch_count() - c0 == 3
    cache.delete()


def test_fused_agrees_with_three_phase_reference():
    t = core.random_tensor((40, 25, 30), 1500, seed=5, dist="powerlaw")
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=512)
    factors = _factors(t.dims)
    for mode in range(t.order):
        fused = np.asarray(pallas_mttkrp(b, factors, mode), np.float64)
        phases = np.asarray(pallas_mttkrp_phases(b, factors, mode),
                            np.float64)
        np.testing.assert_allclose(fused, phases, rtol=1e-5, atol=1e-5)


def test_fused_padding_slots_are_exact():
    """Reservation padding contributes zero: growing the reservation (more
    pad slots per launch, different tile boundaries) leaves the result
    unchanged up to summation order."""
    t = core.random_tensor((30, 22, 14), 1003, seed=9, dist="powerlaw")
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    factors = _factors(t.dims)
    tight = LaunchCache.from_blco(b)
    loose = LaunchCache.from_blco(b, reservation_nnz=2 * tight.reservation)
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        a = fused_cache_mttkrp(tight, factors, mode)
        c = fused_cache_mttkrp(loose, factors, mode)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-5, atol=1e-5)
        assert _rel_err(c, oracle) < 5e-4, mode
    tight.delete()
    loose.delete()
