"""Pallas kernels vs ref.py oracles: shape/dtype sweeps + end-to-end."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import core
from repro.core import linearize as lin
from repro.kernels import pallas_mttkrp
from repro.kernels import ref as kref
from repro.kernels.blco_mttkrp import mttkrp_segments, mttkrp_stash
from repro.kernels.delinearize import delinearize


@pytest.mark.parametrize("t_total,tile", [(256, 256), (1024, 256), (512, 128)])
@pytest.mark.parametrize("r", [8, 32])
@pytest.mark.parametrize("n_gathered", [1, 2, 3])
def test_segment_kernel_sweep(t_total, tile, r, n_gathered):
    rng = np.random.default_rng(t_total + r)
    vals = jnp.asarray(rng.standard_normal(t_total).astype(np.float32))
    # runs of equal target (ALTO-sorted streams have runs, not sorted order)
    tgt = jnp.asarray(np.sort(rng.integers(0, 37, t_total)).astype(np.int32))
    g = tuple(jnp.asarray(rng.standard_normal((t_total, r)).astype(np.float32))
              for _ in range(n_gathered))
    st, ss = mttkrp_segments(vals, tgt, g, tile=tile)
    st_r, ss_r = kref.mttkrp_segments_ref(vals, tgt, g, tile=tile)
    np.testing.assert_array_equal(np.asarray(st), np.asarray(st_r))
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ss_r),
                               rtol=1e-5, atol=1e-5)
    # per-segment scatter equals direct scatter of all partials
    out = kref.scatter_segments_ref(st, ss, 37)
    ref = kref.mttkrp_stash_ref(vals, tgt, g, out_rows=37)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("out_rows", [8, 50, 512])
@pytest.mark.parametrize("dtype", [np.float32])
def test_stash_kernel_sweep(out_rows, dtype):
    rng = np.random.default_rng(out_rows)
    t_total, r = 512, 16
    vals = jnp.asarray(rng.standard_normal(t_total).astype(dtype))
    tgt = jnp.asarray(rng.integers(0, out_rows, t_total).astype(np.int32))
    g = (jnp.asarray(rng.standard_normal((t_total, r)).astype(dtype)),
         jnp.asarray(rng.standard_normal((t_total, r)).astype(dtype)))
    out = mttkrp_stash(vals, tgt, g, out_rows=out_rows, tile=256)
    ref = kref.mttkrp_stash_ref(vals, tgt, g, out_rows=out_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dims,target_bits", [
    ((13, 7, 29, 5), 8), ((64, 33, 17), 10), ((256, 256, 256), 64)])
def test_delinearize_kernel_vs_host(dims, target_bits):
    t = core.random_tensor(dims, 700, seed=5, dist="powerlaw")
    b = core.build_blco(t, target_bits=target_bits, max_nnz_per_block=256)
    bases_all = b.block_upper_bases()
    ids = b.element_block_ids()
    n = b.nnz
    pad = -n % 256
    hi = np.concatenate([b.idx_hi, np.zeros(pad, np.uint32)])
    lo = np.concatenate([b.idx_lo, np.zeros(pad, np.uint32)])
    bases = np.concatenate([bases_all[ids],
                            np.zeros((pad, b.order), np.int64)]).astype(np.int32)
    coords = delinearize(jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(bases),
                         field_bits=b.re.field_bits,
                         field_shifts=b.re.field_shift, tile=256)
    # compare against the original (ALTO-sorted) coordinates
    spec = lin.LinearSpec.make(t.dims)
    hi0, lo0 = lin.alto_encode(spec, t.indices)
    perm = lin.sort_by_alto(hi0, lo0)
    np.testing.assert_array_equal(np.asarray(coords)[:n], t.indices[perm])


@pytest.mark.parametrize("resolution", ["auto", "register", "hierarchical"])
def test_pallas_mttkrp_end_to_end(resolution):
    t = core.random_tensor((70, 40, 30, 9), 3000, seed=7, dist="powerlaw")
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=1024)
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 16)).astype(np.float32)
               for d in t.dims]
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        out = np.asarray(pallas_mttkrp(b, factors, mode,
                                       resolution=resolution), np.float64)
        rel = np.max(np.abs(out - oracle)) / (np.max(np.abs(oracle)) + 1e-30)
        assert rel < 5e-4, (mode, resolution, rel)


def test_pallas_matches_xla_path_bitwise_structure():
    """Same segments discovered by the kernel and the XLA reference path."""
    rng = np.random.default_rng(0)
    tgt = jnp.asarray(np.repeat(np.arange(10), 26)[:256].astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    g = (jnp.asarray(rng.standard_normal((256, 8)).astype(np.float32)),)
    st, ss = mttkrp_segments(vals, tgt, g, tile=256)
    n_segs = int((np.asarray(st) >= 0).sum())
    assert n_segs == len(np.unique(np.asarray(tgt)))
