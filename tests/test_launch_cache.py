"""Device-resident launch cache: single-dispatch execution, exactness,
byte accounting, and the engine's fenced in-memory timing.

Acceptance scenario (ISSUE 3): an in-memory BLCO MTTKRP issues exactly ONE
jitted dispatch per call — assertable via the dispatch counter — with zero
per-call host-side numpy padding, and matches both the dense oracle and the
legacy per-launch loop bit for bit.
"""
import numpy as np
import pytest

from repro import core
from repro.core.launches import (LaunchCache, default_reservation,
                                 launch_cache_bytes)
from repro.core.padding import (LANE, next_pow2, pad_bucket, pad_multiple,
                                pad_pow2)
from repro.engine import factor_bytes, in_memory_bytes, plan_for


def _tensor():
    return core.random_tensor((40, 25, 30), 2000, seed=1, dist="powerlaw")


def _factors(dims, rank=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((d, rank)).astype(np.float32) for d in dims]


def _rel_err(a, oracle):
    return np.max(np.abs(np.asarray(a, np.float64) - oracle)) / \
        (np.max(np.abs(oracle)) + 1e-30)


def test_padding_helpers_shared():
    """One home for the pow2/lane arithmetic (was three private copies)."""
    assert next_pow2(1) == 1 and next_pow2(3) == 4 and next_pow2(256) == 256
    assert pad_pow2(5) == LANE and pad_pow2(300) == 512
    assert pad_multiple(1) == LANE and pad_multiple(257) == 512
    assert pad_multiple(512) == 512
    # size-class buckets: LANE multiples, >= n, <= 25% waste above 1024,
    # and boundedly many distinct values (the cache-churn invariant)
    assert pad_bucket(1) == LANE and pad_bucket(256) == 256
    assert pad_bucket(2048) == 2048 and pad_bucket(2049) == 2560
    for n in (1, 255, 257, 1023, 5000, 1 << 20, (1 << 20) + 1):
        b = pad_bucket(n)
        assert b >= n and b % LANE == 0
        if n > 1024:
            assert b - n <= n // 4
    assert len({pad_bucket(n) for n in range(1, 1 << 16)}) <= \
        8 * 16 + 2                        # <= 8 classes per octave
    # monotone: a bigger launch never gets a smaller reservation
    vals = [pad_bucket(n) for n in range(1, 1 << 13)]
    assert vals == sorted(vals)


def test_single_dispatch_per_call_vs_per_launch_loop():
    t = _tensor()
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    assert len(b.launches) > 1            # the regime that matters
    factors = _factors(t.dims)

    c0 = core.dispatch_count()
    core.mttkrp_per_launch(b, factors, 0)
    assert core.dispatch_count() - c0 == len(b.launches)

    c0 = core.dispatch_count()
    out = core.mttkrp(b, factors, 0)
    assert core.dispatch_count() - c0 == 1          # ONE dispatch, L launches

    # the cache is built once and reattached calls stay single-dispatch
    cache = b._launch_cache
    c0 = core.dispatch_count()
    out2 = core.mttkrp(b, factors, 1)
    assert core.dispatch_count() - c0 == 1
    assert b._launch_cache is cache                  # no rebuild
    assert out.shape == (t.dims[0], 8) and out2.shape == (t.dims[1], 8)


def test_cache_matches_loop_bitwise_and_oracle():
    t = _tensor()
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    factors = _factors(t.dims)
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        for res in ("register", "hierarchical", "direct"):
            cached = core.mttkrp(b, factors, mode, resolution=res)
            loop = core.mttkrp_per_launch(b, factors, mode, resolution=res)
            # same launch order, same padding exactness -> bit identical
            np.testing.assert_array_equal(np.asarray(cached),
                                          np.asarray(loop), err_msg=res)
            assert _rel_err(cached, oracle) < 5e-4, (mode, res)


def test_in_memory_plan_single_dispatch_both_kernels():
    t = _tensor()
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    factors = _factors(t.dims)
    for kernel in ("xla", "pallas"):
        plan = plan_for(b, 1 << 40, rank=8, backend="in_memory",
                        kernel=kernel)
        for mode in range(t.order):
            c0 = core.dispatch_count()
            out = plan.mttkrp(factors, mode)
            assert core.dispatch_count() - c0 == 1, (kernel, mode)
            oracle = core.mttkrp_dense_oracle(t, factors, mode)
            assert _rel_err(out, oracle) < 5e-4, (kernel, mode)
        plan.close()


def test_in_memory_plan_records_fenced_timing():
    """Satellite: InMemoryPlan fills dispatch/device/launches EngineStats so
    in-memory vs streamed comparisons are apples-to-apples."""
    t = _tensor()
    b = core.build_blco(t, max_nnz_per_block=256)
    plan = plan_for(b, 1 << 40, rank=4, backend="in_memory")
    plan.mttkrp(_factors(t.dims, 4), 0)
    plan.mttkrp(_factors(t.dims, 4), 1)
    s = plan.stats()
    assert s.backend == "in_memory" and s.mttkrp_calls == 2
    assert s.launches == 2                 # one fused dispatch per call
    assert s.device_time_s >= s.dispatch_time_s > 0
    assert s.total_time_s >= s.device_time_s
    assert s.h2d_bytes == plan.device_bytes()        # the one upload
    plan.close()


def test_cache_bytes_accounting():
    t = _tensor()
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    cache = LaunchCache.from_blco(b)
    max_launch = max(l.nnz for l in b.launches)
    res = default_reservation(max_launch)
    assert cache.reservation == res
    assert cache.num_launches == len(b.launches)
    per_elem = 4 + 4 + b.values.dtype.itemsize + 4 * b.order
    want = len(b.launches) * res * per_elem
    assert cache.device_bytes() == want
    assert launch_cache_bytes(b) == want
    assert in_memory_bytes(b) == want      # engine admission sees the same
    plan = plan_for(b, 1 << 40, rank=8, backend="in_memory")
    assert plan.device_bytes() == want
    assert plan.close() == want
    cache.delete()
    assert cache.device_bytes() == 0
    with pytest.raises(RuntimeError, match="closed"):
        cache.mttkrp(_factors(t.dims), 0)


def test_cache_reservation_validation_and_flat_stream():
    t = _tensor()
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=256)
    max_launch = max(l.nnz for l in b.launches)
    with pytest.raises(ValueError, match="smaller than largest"):
        LaunchCache.from_blco(b, reservation_nnz=max_launch - 1)
    cache = LaunchCache.from_blco(b, reservation_nnz=pad_pow2(max_launch))
    hi, lo, vals, bases = cache.flat()
    assert hi.shape == (cache.num_launches * cache.reservation,)
    assert bases.shape == (hi.shape[0], b.order)
    cache.delete()


def test_ragged_explicit_reservation_rounds_to_lane():
    """ISSUE 4 satellite: an explicit non-LANE reservation_nnz is rounded
    up, so the actual footprint matches the launch_cache_bytes predictor
    and the fused Pallas tiler always sees a tile-divisible reservation."""
    t = _tensor()
    # a non-pow2 block budget gives launches whose max is NOT a LANE multiple
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=200)
    max_launch = max(l.nnz for l in b.launches)
    ragged = max_launch + 3                    # deliberately not a multiple
    assert ragged % LANE != 0
    cache = LaunchCache.from_blco(b, reservation_nnz=ragged)
    assert cache.reservation == pad_multiple(ragged)
    assert cache.reservation % LANE == 0
    # the default reservation equals the predictor even for ragged nnz
    default = LaunchCache.from_blco(b)
    assert max_launch % LANE != 0              # the ragged regime is real
    assert default.device_bytes() == launch_cache_bytes(b)
    factors = _factors(t.dims)
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    assert _rel_err(cache.mttkrp(factors, 0), oracle) < 5e-4
    cache.delete()
    default.delete()


def test_dtype_parity_xla_pallas_per_launch():
    """ISSUE 4 satellite: float64 tensor values against float32 factors
    accumulate in float64 on EVERY path (jnp.result_type), instead of the
    stacked accumulator silently downcasting to the factor dtype."""
    import jax
    from repro.kernels.fused import fused_cache_mttkrp

    t = _tensor()
    with jax.experimental.enable_x64():
        t64 = core.from_coo(np.asarray(t.indices),
                            np.asarray(t.values, np.float64), t.dims)
        b = core.build_blco(t64, target_bits=12, max_nnz_per_block=256)
        cache = LaunchCache.from_blco(b)
        factors = _factors(t.dims)             # float32 on purpose
        assert cache.vals.dtype == np.float64
        oracle = core.mttkrp_dense_oracle(t64, factors, 0)
        stacked = cache.mttkrp(factors, 0)
        loop = core.mttkrp_per_launch(b, factors, 0)
        fused = fused_cache_mttkrp(cache, factors, 0)
        for name, out in (("stacked", stacked), ("per_launch", loop),
                          ("pallas", fused)):
            assert out.dtype == np.float64, name
            assert _rel_err(out, oracle) < 1e-10, name
        cache.delete()


def test_zero_nnz_cache():
    t = core.from_coo(np.zeros((0, 3), np.int64), np.zeros((0,), np.float32),
                      (8, 6, 4))
    b = core.build_blco(t)
    assert launch_cache_bytes(b) == 0
    c0 = core.dispatch_count()
    out = core.mttkrp(b, _factors(t.dims, 5), 0)
    assert core.dispatch_count() == c0     # nothing to dispatch
    assert out.shape == (8, 5)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_streamed_kernel_validation():
    b = core.build_blco(_tensor(), max_nnz_per_block=256)
    with pytest.raises(ValueError, match="unknown kernel"):
        plan_for(b, 1 << 40, rank=8, backend="in_memory", kernel="cuda")
    with pytest.raises(ValueError, match="unknown kernel"):
        core.DeviceBLCO(b, kernel="cuda")
    # kernel= is validated consistently on every backend, not silently
    # ignored where it cannot apply
    with pytest.raises(ValueError, match="not supported on baseline"):
        plan_for(b, 1 << 40, rank=8, backend="coo", kernel="pallas")
    plan = plan_for(b, 1 << 40, rank=8, backend="streamed", kernel="pallas",
                    queues=2)
    factors = _factors(b.dims)
    t = _tensor()
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    c0 = core.dispatch_count()
    out = plan.mttkrp(factors, 0)
    # exactly one dispatch per streamed chunk (no double count on pallas)
    assert core.dispatch_count() - c0 == len(b.launches)
    assert _rel_err(out, oracle) < 5e-4
    plan.close()


def test_clear_launch_cache_releases_attached_copy():
    t = _tensor()
    b = core.build_blco(t, max_nnz_per_block=256)
    assert core.clear_launch_cache(b) == 0            # nothing attached yet
    factors = _factors(t.dims)
    core.mttkrp(b, factors, 0)
    cache = b._launch_cache
    held = cache.device_bytes()
    assert held > 0
    assert core.clear_launch_cache(b) == held
    assert cache.closed and b._launch_cache is None
    # a later call transparently rebuilds the cache
    oracle = core.mttkrp_dense_oracle(t, factors, 0)
    assert _rel_err(core.mttkrp(b, factors, 0), oracle) < 5e-4
