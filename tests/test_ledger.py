"""Bandwidth ledger: edge accounting, tenant attribution, the exact
conservation law against EngineStats (three regimes, zero relative
error), and the chaos-lane fault balance (retries counted once, giveups
never double-counted)."""
import threading

import numpy as np
import pytest

from conftest import hypothesis_or_stub
from repro import core, obs
from repro.engine import plan_for
from repro.faults import FaultPlan, FaultRule, inject
from repro.obs import ledger
from repro.store import DiskStreamedPlan, save_blco

given, settings, st = hypothesis_or_stub()


@pytest.fixture(autouse=True)
def _clean_ledger():
    """Every test starts and ends with the global ledger off and empty."""
    ledger.disable()
    ledger.clear()
    yield
    ledger.disable()
    ledger.clear()


def _factors(dims, rank=4, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)).astype(np.float32))
            for d in dims]


# ------------------------------------------------------------------ basics
def test_disabled_record_is_noop():
    ledger.record(ledger.HOST_DEVICE, 1024, 0.5, regime="streamed")
    ledger.enable()
    snap = ledger.snapshot()
    assert snap["edges"] == {} and snap["regimes"] == {}


def test_record_accumulates_edges_and_regimes():
    ledger.enable()
    ledger.record(ledger.DISK_HOST, 100, 0.5, regime="disk_streamed")
    ledger.record(ledger.DISK_HOST, 300, 1.5, regime="disk_streamed")
    ledger.record(ledger.HOST_DEVICE, 50, 0.0, regime="streamed", flops=7.0)
    snap = ledger.snapshot()
    dh = snap["edges"][ledger.DISK_HOST]
    assert dh["bytes"] == 400 and dh["seconds"] == 2.0 and dh["ops"] == 2
    assert dh["gb_per_s"] == pytest.approx(400 / 2.0 / 1e9)
    hd = snap["edges"][ledger.HOST_DEVICE]
    assert hd["seconds"] == 0.0 and hd["gb_per_s"] == 0.0  # no div-by-zero
    assert hd["flops"] == 7.0
    assert snap["regimes"]["disk_streamed"][ledger.DISK_HOST]["bytes"] == 400
    assert "streamed" in snap["regimes"]


def test_unknown_edge_rejected():
    ledger.enable()
    with pytest.raises(ValueError, match="unknown ledger edge"):
        ledger.record("host_gpu", 1, 0.0)


def test_enabled_context_manager_restores_state():
    assert not ledger.is_enabled()
    with ledger.enabled():
        assert ledger.is_enabled()
        with ledger.enabled():
            assert ledger.is_enabled()
        assert ledger.is_enabled()
    assert not ledger.is_enabled()


def test_record_is_thread_safe():
    ledger.enable()

    def work():
        for _ in range(1000):
            ledger.record(ledger.HOST_DEVICE, 1, 0.001, regime="streamed")

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = ledger.snapshot()
    assert snap["edges"][ledger.HOST_DEVICE]["bytes"] == 4000
    assert snap["edges"][ledger.HOST_DEVICE]["ops"] == 4000


# ------------------------------------------------------- tenant attribution
def test_job_scope_attributes_to_tenant_and_job():
    ledger.enable()
    with ledger.job_scope("acme", "job-1"):
        ledger.record(ledger.HOST_DEVICE, 100, 0.1, regime="streamed")
    with ledger.job_scope("umbrella", "job-2"):
        ledger.record(ledger.HOST_DEVICE, 200, 0.2, regime="streamed")
    ledger.record(ledger.HOST_DEVICE, 400, 0.4, regime="streamed")  # no scope
    snap = ledger.snapshot()
    assert snap["jobs"]["acme"]["job-1"][ledger.HOST_DEVICE]["bytes"] == 100
    assert snap["jobs"]["umbrella"]["job-2"][ledger.HOST_DEVICE]["bytes"] \
        == 200
    # tenants aggregate across jobs; unscoped traffic stays global-only
    assert snap["tenants"]["acme"][ledger.HOST_DEVICE]["bytes"] == 100
    assert snap["edges"][ledger.HOST_DEVICE]["bytes"] == 700


def test_job_scope_restores_previous_scope():
    ledger.enable()
    with ledger.job_scope("outer", "a"):
        with ledger.job_scope("inner", "b"):
            ledger.record(ledger.DISK_HOST, 1, 0.0, regime="r")
        ledger.record(ledger.DISK_HOST, 2, 0.0, regime="r")
    snap = ledger.snapshot()
    assert snap["jobs"]["inner"]["b"][ledger.DISK_HOST]["bytes"] == 1
    assert snap["jobs"]["outer"]["a"][ledger.DISK_HOST]["bytes"] == 2


def test_tenant_cardinality_bounded_with_overflow_bucket():
    ledger.enable()
    for n in range(ledger.MAX_TENANT_KEYS + 8):
        with ledger.job_scope(f"tenant-{n:03d}", "j"):
            ledger.record(ledger.HOST_DEVICE, 1, 0.0, regime="r")
    snap = ledger.snapshot()
    assert len(snap["tenants"]) == ledger.MAX_TENANT_KEYS + 1
    assert snap["tenants"][ledger.OVERFLOW_TENANT][
        ledger.HOST_DEVICE]["bytes"] == 8
    # nothing lost: per-tenant traffic sums to the edge total
    total = sum(acct[ledger.HOST_DEVICE]["bytes"]
                for acct in snap["tenants"].values())
    assert total == snap["edges"][ledger.HOST_DEVICE]["bytes"]


# ------------------------------------------------------------------- models
def test_hbm_model_and_flops_scale_linearly():
    one = ledger.hbm_model_bytes(1000, order=3, rank=8, value_itemsize=4)
    two = ledger.hbm_model_bytes(2000, order=3, rank=8, value_itemsize=4)
    assert two == 2 * one > 0
    assert ledger.mttkrp_flops(1000, order=3, rank=8) == 1000 * 8 * 3
    # the fused kernel never materializes decoded coords or Hadamard
    # intermediates, so its modeled traffic is strictly smaller
    assert ledger.hbm_model_bytes(1000, order=3, rank=8, value_itemsize=4,
                                  kernel="pallas") \
        < ledger.hbm_model_bytes(1000, order=3, rank=8, value_itemsize=4,
                                 kernel="xla_scan")


# ------------------------------------------- conservation (the BENCH_7 law)
def test_three_regime_conservation_is_exact(tmp_path):
    """Ledger accounts equal EngineStats counters with rel err exactly
    0.0 — same floats, recorded at the same sites — for the in-memory,
    host-streamed, and disk-streamed regimes."""
    t = core.random_tensor((30, 20, 25), 1500, seed=7)
    b = core.build_blco(t, max_nnz_per_block=256)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    factors = _factors(t.dims, rank=6)

    ledger.enable()
    mem = plan_for(b, 1 << 40, rank=6, backend="in_memory")
    host = plan_for(b, 1 << 40, rank=6, backend="streamed", queues=2)
    disk = DiskStreamedPlan(path, queues=2)
    try:
        for plan in (mem, host, disk):
            for mode in range(t.order):
                plan.mttkrp(factors, mode)
        verdict = ledger.verify_conservation(
            [("in_memory", mem.stats()), ("streamed", host.stats()),
             ("disk_streamed", disk.stats())])
    finally:
        mem.close(), host.close(), disk.close()
    assert verdict["max_rel_err"] == 0.0
    assert len(verdict["checks"]) == 15
    # and the accounts are live, not trivially zero == zero
    nonzero = [c for c in verdict["checks"] if c["ledger"] > 0]
    assert len(nonzero) >= 6


def test_conservation_catches_a_drop(tmp_path):
    """A byte that reaches EngineStats but not the ledger must show up
    as nonzero relative error — the check is falsifiable."""
    t = core.random_tensor((16, 16, 16), 400, seed=1)
    b = core.build_blco(t, max_nnz_per_block=128)
    ledger.enable()
    plan = plan_for(b, 1 << 40, rank=4, backend="streamed", queues=2)
    try:
        plan.mttkrp(_factors(t.dims), 0)
        plan.stats().h2d_bytes += 1          # simulate a missed record site
        verdict = ledger.verify_conservation([("streamed", plan.stats())])
    finally:
        plan.close()
    assert verdict["max_rel_err"] > 0.0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 1 << 20),
                          st.floats(1e-9, 10.0)), min_size=1, max_size=40))
def test_conservation_property_identical_float_sequence(events):
    """Replaying any (nbytes, seconds) sequence into both the ledger and
    a stats-shaped accumulator in the same order conserves exactly: same
    floats, same addition order, zero relative error."""
    ledger.clear()
    ledger.enable()
    stats = {"h2d_bytes": 0, "put_time_s": 0.0, "disk_bytes": 0,
             "disk_time_s": 0.0, "device_time_s": 0.0}
    for nbytes, secs in events:
        stats["h2d_bytes"] += nbytes
        stats["put_time_s"] += secs
        ledger.record(ledger.HOST_DEVICE, nbytes, secs, regime="prop")
    verdict = ledger.verify_conservation([("prop", stats)])
    assert verdict["max_rel_err"] == 0.0
    ledger.disable()
    ledger.clear()


# --------------------------------------------------------------- chaos lane
def test_fault_balance_transient_retry_counts_once(tmp_path):
    """A transient store.read fault is retried: the retry is counted in
    stats, the bytes are recorded once, and conservation still holds."""
    t = core.random_tensor((20, 20, 20), 800, seed=3)
    b = core.build_blco(t, max_nnz_per_block=128)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    ledger.enable()
    plan_ = FaultPlan(seed=0, rules=(
        FaultRule("store.read", kind="transient", nth=2, times=1),))
    with inject.active(plan_):
        plan = DiskStreamedPlan(path, queues=2)
        try:
            plan.mttkrp(_factors(t.dims), 0)
            s = plan.stats()
            assert s.retries == 1 and s.giveups == 0
            verdict = ledger.verify_conservation([("disk_streamed", s)])
        finally:
            plan.close()
    assert verdict["max_rel_err"] == 0.0


def test_fault_balance_giveup_never_double_counts(tmp_path):
    """Exhausting the retry budget surfaces the error BEFORE either the
    stats counters or the ledger record — the failed transfer's bytes
    appear in neither, so the accounts still balance exactly."""
    t = core.random_tensor((20, 20, 20), 800, seed=3)
    b = core.build_blco(t, max_nnz_per_block=128)
    path = str(tmp_path / "t.blco")
    save_blco(b, path)
    ledger.enable()
    plan_ = FaultPlan(seed=0, rules=(           # every read fails: giveup
        FaultRule("store.read", kind="transient", p=1.0),))
    with inject.active(plan_):
        plan = DiskStreamedPlan(path, queues=2)
        try:
            with pytest.raises(OSError):
                plan.mttkrp(_factors(t.dims), 0)
            s = plan.stats()
            assert s.giveups >= 1
            assert s.disk_bytes == 0            # nothing ever landed
            verdict = ledger.verify_conservation([("disk_streamed", s)])
        finally:
            plan.close()
    assert verdict["max_rel_err"] == 0.0
    snap = ledger.snapshot()
    assert snap["regimes"].get("disk_streamed", {}).get(
        ledger.DISK_HOST, {"bytes": 0})["bytes"] == 0


# ------------------------------------------------------------- JSON safety
def test_snapshot_json_safe():
    import json
    ledger.enable()
    with ledger.job_scope("acme", "j1"):
        ledger.record(ledger.DEVICE_HBM, 10, 0.1, regime="in_memory",
                      flops=5.0)
    json.dumps(ledger.snapshot())
    json.dumps(obs.roofline_report(peaks={"device_hbm": 100.0},
                                   peak_flops=1e9))
