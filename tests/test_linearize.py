"""Property tests for ALTO linearization + BLCO re-encoding/blocking."""
import numpy as np
import pytest

from conftest import hypothesis_or_stub

from repro.core import linearize as lin

given, settings, st = hypothesis_or_stub()
from repro.core import tensor as tz
from repro.core.blco import build_blco
from repro.core.u64 import join64, split64

dims_strategy = st.lists(st.integers(2, 300), min_size=2, max_size=5)


@settings(max_examples=30, deadline=None)
@given(dims=dims_strategy, seed=st.integers(0, 2**31 - 1))
def test_alto_roundtrip(dims, seed):
    spec = lin.LinearSpec.make(dims)
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.integers(0, d, 64) for d in dims], 1).astype(np.int64)
    hi, lo = lin.alto_encode(spec, idx)
    back = lin.alto_decode(spec, hi, lo)
    np.testing.assert_array_equal(back, idx)


@settings(max_examples=30, deadline=None)
@given(dims=dims_strategy, seed=st.integers(0, 2**31 - 1),
       target=st.sampled_from([8, 12, 16, 64]))
def test_reencode_roundtrip_with_blocking(dims, seed, target):
    spec = lin.LinearSpec.make(dims)
    re = lin.reencode_spec(spec, target)
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.integers(0, d, 64) for d in dims], 1).astype(np.int64)
    hi, lo = lin.alto_encode(spec, idx)
    keys = lin.block_key(spec, re, hi, lo)
    stored = lin.reencode(spec, re, idx)
    for key in np.unique(keys):
        sel = keys == key
        upper = lin.key_to_upper_coords(spec, re, int(key))
        back = lin.delinearize_host(re, stored[sel], upper)
        np.testing.assert_array_equal(back, idx[sel])


def test_alto_positions_cover_all_bits():
    for dims in [(5, 5), (1000, 3, 17), (2, 2, 2, 2, 900)]:
        spec = lin.LinearSpec.make(dims)
        flat = sorted(p for ps in spec.positions for p in ps)
        assert flat == list(range(spec.total_bits))
        for n, d in enumerate(dims):
            assert 2 ** spec.bits[n] >= d


def test_alto_ordering_is_morton_for_regular_dims():
    # equal mode lengths -> round-robin == Morton-Z interleave
    spec = lin.LinearSpec.make((4, 4, 4))
    assert spec.positions == ((0, 3), (1, 4), (2, 5))


@pytest.mark.parametrize("target_bits,max_nnz", [(6, 16), (10, 64), (64, 1 << 20)])
def test_blocking_invariants(target_bits, max_nnz):
    t = tz.random_tensor((37, 11, 53, 7), 3000, seed=0, dist="powerlaw")
    b = build_blco(t, target_bits=target_bits, max_nnz_per_block=max_nnz)
    # partition: blocks tile [0, nnz) exactly
    assert b.blocks[0].start == 0
    assert b.blocks[-1].end == b.nnz
    for prev, cur in zip(b.blocks, b.blocks[1:]):
        assert prev.end == cur.start
    # size budget
    assert all(blk.nnz <= max_nnz for blk in b.blocks)
    # in-block stored index fits target bits
    stored = join64(b.idx_hi, b.idx_lo)
    assert b.re.inblock_bits <= target_bits
    if b.re.inblock_bits < 64:
        assert int(stored.max()) < (1 << b.re.inblock_bits)
    # launches tile the block list exactly
    ids = [i for l in b.launches for i in l.block_ids]
    assert ids == list(range(len(b.blocks)))
    # every element delinearizes to its original coordinate set (as multiset)
    total = sum(blk.nnz for blk in b.blocks)
    assert total == t.nnz


def test_construction_stats_recorded():
    t = tz.random_tensor((64, 64, 64), 1000, seed=1)
    b = build_blco(t)
    for k in ("linearize", "sort", "block_keys", "reencode", "blocking",
              "batching"):
        assert k in b.construction_stats


def test_tns_roundtrip(tmp_path):
    t = tz.random_tensor((9, 8, 7), 50, seed=2, dtype=np.float64)
    p = tmp_path / "x.tns"
    with open(p, "w") as f:
        for row, v in zip(t.indices, t.values):
            f.write(" ".join(str(i + 1) for i in row) + f" {v}\n")
    t2 = tz.load_tns(str(p))
    assert t2.nnz == t.nnz
    np.testing.assert_allclose(t2.to_dense(), t.to_dense(), rtol=1e-12)
