"""Schema stability for the metrics snapshots.

External consumers (the Prometheus renderer, dashboards, the regression
gate) key off snapshot dictionaries.  These tests pin a golden key list
per snapshot: keys may be *added* in later PRs (append to the golden
list), but removing or renaming one fails here first, on purpose.
Every snapshot must also round-trip through ``json.dumps`` — no Inf/NaN,
no non-string dict keys, no dataclasses leaking through.
"""
import json

import pytest

from repro.core.streaming import EngineStats
from repro.obs.hist import Hist
from repro.service import JobMetrics, ServiceMetrics

# ----------------------------------------------------------------- goldens
# Grow-only: append new keys at the end; never delete or rename.
JOB_KEYS = {
    "iterations", "queue_wait_s", "run_time_s", "cache_hit", "backend",
    "released_bytes", "h2d_bytes", "disk_bytes", "mttkrp_calls", "launches",
    "put_time_s", "disk_time_s", "dispatch_time_s", "device_time_s",
    "hist",
    "retries", "giveups", "demotions",
}

SERVICE_KEYS = {
    "jobs_submitted", "jobs_admitted", "jobs_completed", "jobs_failed",
    "jobs_cancelled", "preemptions", "cancel_freed_bytes_total",
    "blco_cache_hits", "blco_cache_misses", "blco_disk_hits",
    "spills", "spill_bytes_total", "loads", "jobs_restored",
    "iterations_total", "iterations_per_sec",
    "h2d_bytes_total", "disk_bytes_total", "disk_time_s_total",
    "launches_total",
    "busy_time_s", "uptime_s",
    "queue_depth", "running_jobs", "host_budget_used_bytes",
    "tenant_iterations", "tenant_shares",
    "admitted_reservation_bytes", "peak_admitted_reservation_bytes",
    "hist",
    "store_rebuilds", "retries_total", "giveups_total", "demotions_total",
    "watchdog_restarts",
    "tenant_hist",
}

ENGINE_STATS_KEYS = {
    "backend", "mttkrp_calls", "h2d_bytes", "disk_bytes", "launches",
    "put_time_s", "disk_time_s", "dispatch_time_s", "device_time_s",
    "total_time_s", "hist",
    "retries", "giveups", "demotions",
}

HIST_KEYS = {"count", "sum", "min", "max", "buckets"}

ANALYSIS_KEYS = {
    "hot_paths_traced", "jaxpr_eqns_walked", "encodings_verified",
    "launches_analyzed",
    "findings_total", "findings_jaxpr_audit", "findings_cache_churn",
    "findings_encoding", "findings_conflicts",
    "runtime_jaxpr_audit_s", "runtime_cache_churn_s", "runtime_encoding_s",
    "runtime_conflicts_s", "runtime_total_s",
}

ENGINE_HIST_NAMES = {"dispatch_s", "put_chunk_s", "disk_read_s",
                     "launch_nnz"}
SERVICE_HIST_NAMES = ENGINE_HIST_NAMES | {"queue_wait_s", "quantum_s"}

TENANT_HIST_NAMES = {"queue_wait_s", "quantum_s"}

#: Prometheus series the dashboards scrape from ``render_prometheus``:
#: grow-only, same contract as the snapshot keys above.
PROM_SERIES = {
    "repro_trace_dropped_spans_total", "repro_trace_enabled",
    "repro_trace_buffered_spans", "repro_trace_capacity_spans",
    "repro_ledger_enabled", "repro_ledger_bytes_total",
    "repro_ledger_seconds_total", "repro_ledger_ops_total",
    "repro_ledger_gb_per_s",
}

LEDGER_EDGE_KEYS = {"bytes", "seconds", "ops", "flops", "gb_per_s"}


def test_job_metrics_snapshot_keys_only_grow():
    snap = JobMetrics().snapshot()
    missing = JOB_KEYS - set(snap)
    assert not missing, f"JobMetrics.snapshot() lost keys: {missing}"
    json.dumps(snap)
    assert set(snap["hist"]) >= ENGINE_HIST_NAMES
    for h in snap["hist"].values():
        assert set(h) >= HIST_KEYS


def test_service_metrics_snapshot_keys_only_grow():
    snap = ServiceMetrics().snapshot()
    missing = SERVICE_KEYS - set(snap)
    assert not missing, f"ServiceMetrics.snapshot() lost keys: {missing}"
    json.dumps(snap)
    assert set(snap["hist"]) >= SERVICE_HIST_NAMES
    for h in snap["hist"].values():
        assert set(h) >= HIST_KEYS


def test_engine_stats_snapshot_keys_only_grow():
    snap = EngineStats().snapshot()
    missing = ENGINE_STATS_KEYS - set(snap)
    assert not missing, f"EngineStats.snapshot() lost keys: {missing}"
    json.dumps(snap)


def test_snapshots_json_safe_with_data():
    m = ServiceMetrics()
    m.record_iteration("alice")
    m.record_iteration("bob")
    m.hist.queue_wait_s.record(0.01)
    m.hist.quantum_s.record(0.5)
    m.busy_time_s = 0.5
    text = json.dumps(m.snapshot())
    back = json.loads(text)
    assert back["tenant_iterations"] == {"alice": 1, "bob": 1}
    assert back["tenant_shares"]["alice"] == pytest.approx(0.5)
    # bucket keys are string-typed les, safe as JSON object keys
    assert all(isinstance(k, str)
               for k in back["hist"]["quantum_s"]["buckets"])


def test_tenant_hist_snapshot_shape():
    m = ServiceMetrics()
    m.hist.record_queue_wait("acme", 0.01)
    m.hist.record_quantum("acme", 0.5)
    snap = m.snapshot()
    assert set(snap["tenant_hist"]) == {"acme"}
    per = snap["tenant_hist"]["acme"]
    assert set(per) == TENANT_HIST_NAMES
    for h in per.values():
        assert set(h) >= HIST_KEYS
    json.dumps(snap)


def test_prometheus_series_only_grow():
    """Every golden Prometheus series renders (trace + ledger state and
    the labelled per-tenant histograms), with the ledger series labelled
    per edge."""
    from repro.obs import ledger
    from repro.obs.export import render_prometheus
    m = ServiceMetrics()
    m.hist.record_queue_wait("acme", 0.01)
    ledger.clear()
    ledger.enable()
    try:
        ledger.record(ledger.HOST_DEVICE, 1024, 0.5, regime="streamed")
        text = render_prometheus(m)
    finally:
        ledger.disable()
        ledger.clear()
    for series in PROM_SERIES:
        assert f"\n{series}" in text or text.startswith(series), \
            f"missing Prometheus series {series}"
    assert 'repro_ledger_bytes_total{edge="host_device"} 1024' in text
    assert 'repro_tenant_queue_wait_s_count{tenant="acme"} 1' in text


def test_ledger_snapshot_edge_keys_only_grow():
    from repro.obs import ledger
    ledger.clear()
    ledger.enable()
    try:
        ledger.record(ledger.DISK_HOST, 10, 0.1, regime="disk_streamed")
        snap = ledger.snapshot()
    finally:
        ledger.disable()
        ledger.clear()
    acct = snap["edges"]["disk_host"]
    missing = LEDGER_EDGE_KEYS - set(acct)
    assert not missing, f"ledger edge account lost keys: {missing}"
    json.dumps(snap)


def test_trace_verify_metrics_snapshot_keys_only_grow():
    from repro.analysis.trace.metrics import TraceVerifyMetrics
    snap = TraceVerifyMetrics().snapshot()
    missing = ANALYSIS_KEYS - set(snap)
    assert not missing, f"TraceVerifyMetrics.snapshot() lost keys: {missing}"
    json.dumps(snap)


def test_trace_verify_prometheus_render():
    """Every analysis golden key appears as a repro_analysis_* sample."""
    from repro.analysis.trace.metrics import TraceVerifyMetrics
    from repro.obs.export import render_prometheus_analysis

    class _F:                         # a Finding-shaped stub
        pass_id = "trace-encoding"

    m = TraceVerifyMetrics(hot_paths_traced=6, runtime_total_s=0.5)
    m.count_findings([_F(), _F()])
    text = render_prometheus_analysis(m)
    for key in ANALYSIS_KEYS:
        assert f"repro_analysis_{key} " in text
    assert "repro_analysis_findings_total 2" in text
    assert "repro_analysis_findings_encoding 2" in text
    assert "repro_analysis_hot_paths_traced 6" in text


def test_hist_snapshot_has_no_infinities():
    h = Hist()
    h.record(1e12)                       # lands in the +Inf bucket
    snap = h.snapshot()
    text = json.dumps(snap, allow_nan=False)   # raises on Inf/NaN
    assert "+Inf" in snap["buckets"]
    assert json.loads(text)["count"] == 1


def test_iterations_per_sec_uses_busy_time_not_wall_clock():
    m = ServiceMetrics()
    m.iterations_total = 10
    m.busy_time_s = 2.0
    assert m.iterations_per_sec() == pytest.approx(5.0)
    # idle time does not decay the rate: back-date construction far into
    # the past — a wall-clock denominator would crater the value
    m.started_s -= 3600.0
    assert m.iterations_per_sec() == pytest.approx(5.0)
    assert m.uptime_s >= 3600.0
    # and with no busy time, the rate is 0, not a division error
    assert ServiceMetrics().iterations_per_sec() == 0.0
