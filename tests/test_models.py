"""Per-arch smoke tests (reduced configs) + train/decode consistency."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model, param_count

KEY = jax.random.key(0)


def _batch(cfg, rng, b=2, s=32):
    batch = {}
    if cfg.input_mode == "embeddings":
        fd = cfg.frontend_dim or cfg.d_model
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, fd)).astype(np.float32))
    if cfg.input_mode == "tokens" or cfg.is_encdec:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    return batch


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke_forward_and_train_step(name):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    assert param_count(params) > 0
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits)))

    from repro.launch import steps
    from repro.optim import adamw
    opt_cfg = adamw.AdamWConfig(total_steps=10)
    step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke_decode_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    b = 2
    if cfg.is_encdec:
        cache = model.init_cache(b, 16, enc_len=8)
        from repro.models import encdec
        embeds = jnp.asarray(rng.standard_normal(
            (b, 8, cfg.frontend_dim)).astype(np.float32))
        cache = encdec.prefill_memory(params, cfg, cache, embeds)
        tok = jnp.zeros((b, 1), jnp.int32)
    elif cfg.input_mode == "embeddings":
        cache = model.init_cache(b, 16)
        fd = cfg.frontend_dim or cfg.d_model
        tok = jnp.asarray(rng.standard_normal((b, 1, fd)).astype(np.float32))
    else:
        cache = model.init_cache(b, 16)
        tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


CONSISTENCY = ["stablelm_12b", "h2o_danube_3_4b", "mamba2_370m",
               "zamba2_1_2b", "deepseek_v2_236b", "seamless_m4t_large_v2"]


@pytest.mark.parametrize("name", CONSISTENCY)
def test_train_decode_consistency(name):
    """Decode logits must reproduce teacher-forced forward logits."""
    cfg = dataclasses.replace(get_config(name).reduced(),
                              compute_dtype="float32", ssd_chunk=8,
                              capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    B, S = 2, 16
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.is_encdec:
        batch["embeds"] = jnp.asarray(rng.standard_normal(
            (B, 8, cfg.frontend_dim)).astype(np.float32))
    ref, _ = model.forward(params, batch)
    if cfg.is_encdec:
        cache = model.init_cache(B, S, enc_len=8, dtype=jnp.float32)
        from repro.models import encdec
        cache = encdec.prefill_memory(params, cfg, cache, batch["embeds"])
    else:
        cache = model.init_cache(B, S, dtype=jnp.float32)
    outs = []
    step = jax.jit(model.decode_step)
    for t in range(S):
        lg, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]),
                         jnp.int32(t))
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    rel = np.max(np.abs(dec - np.asarray(ref))) / \
        (np.max(np.abs(np.asarray(ref))) + 1e-9)
    assert rel < 2e-3, rel


def test_sliding_window_masks_history():
    """SWA: tokens beyond the window must not influence decode logits."""
    cfg = dataclasses.replace(get_config("h2o_danube_3_4b").reduced(),
                              compute_dtype="float32", sliding_window=4)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(2)
    S = 12
    t1 = rng.integers(0, cfg.vocab_size, (1, S))
    t2 = t1.copy()
    t2[0, 0:4] = (t2[0, 0:4] + 7) % cfg.vocab_size   # differ OUTSIDE window
    l1, _ = model.forward(params, {"tokens": jnp.asarray(t1)})
    l2, _ = model.forward(params, {"tokens": jnp.asarray(t2)})
    # last position attends to [S-4, S): identical inputs there
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_moe_routing_actually_sparse():
    """Zeroing one expert's weights must change only the tokens routed to it."""
    cfg = dataclasses.replace(get_config("dbrx_132b").reduced(),
                              compute_dtype="float32")
    from repro.models import moe as moe_mod
    from repro.models.modules import Rng
    p = moe_mod.moe_init(Rng(KEY), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)).astype(np.float32))
    out1, aux = moe_mod.moe_apply(p, cfg, x)
    assert np.isfinite(float(aux))
    p2 = jax.tree.map(lambda a: a, p)
    p2["wo"]["w"] = p["wo"]["w"].at[0].set(0.0)
    out2, _ = moe_mod.moe_apply(p2, cfg, x)
    changed = np.any(np.abs(np.asarray(out1 - out2)) > 1e-7, axis=-1)
    assert changed.sum() < x.shape[1]     # some tokens untouched by expert 0


def test_wsd_schedule_shape():
    from repro.optim import schedules
    import numpy as np
    lrs = [float(schedules.wsd(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(0, 101, 5)]
    assert lrs[0] < 0.1            # warmup start
    assert abs(lrs[5] - 1.0) < 1e-6   # plateau
    assert lrs[-1] < 0.05          # decayed
