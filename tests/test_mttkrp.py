"""Every format's MTTKRP vs the dense oracle, on every mode."""
import numpy as np
import pytest

from repro import core
from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

CASES = [
    # (dims, nnz, dist, target_bits, max_nnz)
    ((13, 7, 29, 5), 500, "powerlaw", 8, 64),      # forced blocking, 4-order
    ((40, 25, 30), 2000, "powerlaw", 12, 512),     # forced blocking, 3-order
    ((64, 33, 17), 1500, "uniform", 64, 1 << 20),  # single block path
    ((128, 4, 256, 8, 3), 800, "clustered", 16, 128),  # 5-order
    ((1000, 2, 5), 600, "powerlaw", 64, 1 << 20),  # long skewed mode
]


def _rel_err(a, oracle):
    return np.max(np.abs(np.asarray(a, np.float64) - oracle)) / \
        (np.max(np.abs(oracle)) + 1e-30)


@pytest.mark.parametrize("dims,nnz,dist,tb,mx", CASES)
def test_blco_all_modes_all_resolutions(dims, nnz, dist, tb, mx):
    t = core.random_tensor(dims, nnz, seed=1, dist=dist)
    b = core.build_blco(t, target_bits=tb, max_nnz_per_block=mx)
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 8)).astype(np.float32) for d in dims]
    for mode in range(len(dims)):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        for res in ("register", "hierarchical", "direct", "auto"):
            out = core.mttkrp(b, factors, mode, resolution=res)
            assert _rel_err(out, oracle) < 5e-4, (mode, res)


@pytest.mark.parametrize("dims,nnz,dist,tb,mx", CASES[:3])
def test_baselines_all_modes(dims, nnz, dist, tb, mx):
    t = core.random_tensor(dims, nnz, seed=2, dist=dist)
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 16)).astype(np.float32) for d in dims]
    coo = core.COOFormat.build(t)
    fcoo = core.FCOOFormat.build(t)
    csf = core.CSFFormat.build(t)
    for mode in range(len(dims)):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        assert _rel_err(core.coo_mttkrp(coo, factors, mode), oracle) < 5e-4
        assert _rel_err(core.fcoo_mttkrp(fcoo, factors, mode), oracle) < 5e-4
        assert _rel_err(core.csf_mttkrp(csf, factors, mode), oracle) < 5e-4
        # non-root CSF traversal (the mode-specific asymmetry the paper cites)
        other = (mode + 1) % len(dims)
        assert _rel_err(core.csf_mttkrp(csf, factors, mode, root=other),
                        oracle) < 5e-4


def test_mode_agnostic_single_copy():
    """The BLCO property the paper leads with: ONE tensor copy serves every
    mode (baseline F-COO/CSF need N copies)."""
    t = core.random_tensor((30, 40, 50), 2000, seed=3)
    b = core.build_blco(t)
    fcoo = core.FCOOFormat.build(t)
    csf = core.CSFFormat.build(t)
    blco_bytes = core.format_bytes(b)
    assert len(fcoo.per_mode_indices) == t.order          # N copies
    assert len(csf.trees) == t.order                      # N trees
    # BLCO's footprint now honestly counts its bases arrays (hi + lo + vals
    # + bases = 24 B/nnz at order 3), so the N-copy baselines are ~2.5x
    # (F-COO: 60 B/nnz) and ~2x (CSF: 48+ B/nnz) rather than 3x+
    assert fcoo.device_bytes() > 2.4 * blco_bytes
    assert csf.device_bytes() > 1.9 * blco_bytes


def test_heuristic_matches_paper_rule():
    assert core.choose_resolution(16) == "hierarchical"   # short mode
    assert core.choose_resolution(1 << 20) == "register"  # long mode


def test_choose_resolution_threshold_boundary():
    """The §5.3 heuristic switches exactly at the contention threshold."""
    from repro.core.mttkrp import CONTENTION_THRESHOLD
    assert core.choose_resolution(CONTENTION_THRESHOLD - 1) == "hierarchical"
    assert core.choose_resolution(CONTENTION_THRESHOLD) == "register"
    # a custom threshold re-keys the rule (different hardware)
    assert core.choose_resolution(100, threshold=50) == "register"
    assert core.choose_resolution(100, threshold=200) == "hierarchical"


def test_direct_resolution_matches_oracle_all_modes():
    """The "direct" (per-nnz scatter) path — previously untested — must
    agree with the oracle even under heavy duplicate-target contention."""
    rng = np.random.default_rng(7)
    n = 2048
    idx = np.stack([rng.integers(0, 8, n),          # heavy duplication
                    rng.integers(0, 50, n),
                    rng.integers(0, 31, n)], 1)
    t = core.from_coo(idx, rng.standard_normal(n).astype(np.float32),
                      (8, 50, 31))
    b = core.build_blco(t, target_bits=12, max_nnz_per_block=128)
    factors = [rng.standard_normal((d, 8)).astype(np.float32) for d in t.dims]
    for mode in range(t.order):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        out = core.mttkrp(b, factors, mode, resolution="direct")
        assert _rel_err(out, oracle) < 5e-4, mode


@given(dims=st.sampled_from([(13, 7, 29), (40, 25, 30), (64, 33, 17, 5)]),
       nnz=st.integers(min_value=1, max_value=700),
       seed=st.integers(min_value=0, max_value=31))
@settings(max_examples=12, deadline=None)
def test_launch_zero_padding_exact_all_resolutions(dims, nnz, seed):
    """Property: padding launches to the reservation size is EXACT for all
    three resolutions — pad slots delinearize to coordinate 0 with value 0,
    so padded and unpadded runs are bit-identical."""
    t = core.random_tensor(dims, nnz, seed=seed, dist="powerlaw")
    b = core.build_blco(t, target_bits=10, max_nnz_per_block=64)
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((d, 4)).astype(np.float32) for d in dims]
    for mode in (0, len(dims) - 1):
        for res in ("register", "hierarchical", "direct"):
            padded = core.mttkrp(b, factors, mode, resolution=res, pad=True)
            exact = core.mttkrp(b, factors, mode, resolution=res, pad=False)
            np.testing.assert_array_equal(np.asarray(padded),
                                          np.asarray(exact), err_msg=res)


def test_fp64_path():
    import jax
    if not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled in this session")


def test_empty_and_singleton_modes():
    t = core.random_tensor((1, 17, 9), 100, seed=4)
    b = core.build_blco(t)
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 4)).astype(np.float32) for d in t.dims]
    for mode in range(3):
        oracle = core.mttkrp_dense_oracle(t, factors, mode)
        assert _rel_err(core.mttkrp(b, factors, mode), oracle) < 5e-4
