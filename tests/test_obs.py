"""Observability layer: span tracer, log2 histograms, exporters, and the
end-to-end acceptance path (traced disk-streamed CP-ALS whose span sums
agree with the EngineStats the same timestamps fed)."""
import json
import math
import threading

import numpy as np
import pytest

from repro import core, obs
from repro.core.cp_als import cp_als
from repro.engine import plan_for
from repro.obs.hist import Hist, NBUCKETS, bucket_index, bucket_le


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with the global tracer off, empty, and
    back at the default ring-buffer capacity (enable() keeps the current
    capacity, so a capacity-shrinking test must not leak into the next)."""
    obs.enable(capacity=obs.trace.DEFAULT_CAPACITY)
    obs.disable()
    obs.clear()
    yield
    obs.enable(capacity=obs.trace.DEFAULT_CAPACITY)
    obs.disable()
    obs.clear()


def _factors(dims, rank=4, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((d, rank)).astype(np.float32))
            for d in dims]


# ------------------------------------------------------------------- hist
def test_bucket_index_boundaries():
    # exact powers of two land in the bucket whose le equals them
    for v in (0.25, 0.5, 1.0, 2.0, 1024.0):
        i = bucket_index(v)
        assert bucket_le(i) == v
    # just above a power of two spills into the next bucket
    assert bucket_index(1.0000001) == bucket_index(1.0) + 1
    # non-positive values land in the lowest bucket
    assert bucket_index(0.0) == 0
    assert bucket_index(-3.5) == 0
    # huge values clamp into the final +Inf bucket
    assert bucket_index(2.0 ** 40) == NBUCKETS - 1
    assert bucket_le(NBUCKETS - 1) == math.inf


def test_hist_record_merge_quantile():
    h = Hist()
    for v in (0.001, 0.002, 0.004, 0.008):
        h.record(v)
    assert h.count == 4
    assert h.sum == pytest.approx(0.015)
    assert h.min == 0.001 and h.max == 0.008
    assert h.mean == pytest.approx(0.015 / 4)
    assert h.quantile(1.0) == h.max
    assert h.quantile(0.25) <= h.quantile(0.75)
    other = Hist()
    other.record(1.0)
    h.merge(other)
    assert h.count == 5 and h.max == 1.0
    # cumulative buckets are monotone and end with +Inf at total count
    cum = h.cumulative()
    assert cum[-1][0] == math.inf and cum[-1][1] == 5
    assert all(c1 <= c2 for (_, c1), (_, c2) in zip(cum, cum[1:]))


def test_hist_snapshot_json_safe_when_empty():
    snap = Hist().snapshot()
    json.dumps(snap)                         # inf min/max would blow up here
    assert snap["count"] == 0 and snap["buckets"] == {}
    h = Hist()
    h.record(3.0)
    snap = h.snapshot()
    json.dumps(snap)
    assert sum(snap["buckets"].values()) == 1


# ------------------------------------------------------------------ tracer
def test_disabled_span_is_shared_noop_singleton():
    s1 = obs.span("a", "main")
    s2 = obs.span("b", "other", nnz=5)
    assert s1 is s2                          # zero allocation on the fast path
    with s1 as inner:
        assert inner is s1
    assert obs.spans() == []


def test_disabled_add_event_records_nothing():
    obs.add_event("x", "h2d", 0.0, 1.0, bytes=10)
    assert obs.spans() == []


def test_enabled_spans_record_nesting_and_attrs():
    obs.enable()
    with obs.span("outer", "scheduler", job=1) as outer:
        with obs.span("inner", "plan") as inner:
            inner.set(backend="streamed")
        obs.add_event("ev", "h2d", outer.start_s, outer.start_s + 0.5, n=3)
    got = obs.spans()
    names = {s.name: s for s in got}
    assert set(names) == {"outer", "inner", "ev"}
    assert names["inner"].parent == "outer"
    assert names["ev"].parent == "outer"     # add_event inherits the context
    assert names["outer"].parent is None
    assert names["inner"].attrs["backend"] == "streamed"
    assert names["ev"].duration_s == pytest.approx(0.5)
    assert names["outer"].end_s >= names["inner"].end_s


def test_ring_buffer_bounded_and_counts_drops():
    obs.enable(capacity=4)
    for i in range(10):
        with obs.span(f"s{i}", "main"):
            pass
    assert len(obs.spans()) == 4
    assert obs.TRACING.dropped == 6
    assert [s.name for s in obs.spans()] == ["s6", "s7", "s8", "s9"]
    drained = obs.drain()
    assert len(drained) == 4 and obs.spans() == []


def test_enabled_context_manager_restores_state():
    assert not obs.is_enabled()
    with obs.trace.enabled():
        assert obs.is_enabled()
        with obs.span("in", "main"):
            pass
    assert not obs.is_enabled()
    assert len(obs.spans()) == 1


def test_contextvar_parenting_is_per_thread():
    obs.enable()
    seen = []

    def worker():
        with obs.span("thread-span", "main") as s:
            seen.append(s.parent)

    with obs.span("main-span", "main"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    assert seen == [None]                    # no cross-thread parent leakage


# --------------------------------------------------------------- exporters
def test_chrome_trace_structure():
    obs.enable()
    with obs.span("a", "dispatch", nnz=7):
        pass
    obs.add_event("b", "h2d", obs.TRACING.epoch_s, obs.TRACING.epoch_s + 1e-3)
    doc = obs.chrome_trace()
    json.dumps(doc)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"a", "b"}
    track_names = {e["args"]["name"] for e in metas
                   if e["name"] == "thread_name"}
    assert {"dispatch", "h2d"} <= track_names
    # distinct tracks get distinct tids; events carry their attrs
    tids = {e["cat"]: e["tid"] for e in xs}
    assert tids["dispatch"] != tids["h2d"]
    a = next(e for e in xs if e["name"] == "a")
    assert a["args"]["nnz"] == 7
    assert doc["otherData"]["dropped_spans"] == 0


def test_track_totals_sums_durations():
    obs.enable()
    obs.add_event("x", "h2d", 0.0, 0.25)
    obs.add_event("y", "h2d", 1.0, 1.5)
    obs.add_event("z", "store", 0.0, 0.125)
    tot = obs.track_totals()
    assert tot["h2d"] == pytest.approx(0.75)
    assert tot["store"] == pytest.approx(0.125)


def test_render_prometheus_format():
    from repro.service import ServiceMetrics
    m = ServiceMetrics()
    m.iterations_total = 7
    m.busy_time_s = 2.0
    m.tenant_iterations = {"a": 4, "b": 3}
    m.hist.quantum_s.record(0.5)
    text = obs.render_prometheus(m)
    assert "# TYPE repro_iterations_total counter" in text
    assert "repro_iterations_total 7" in text
    assert 'repro_tenant_iterations_total{tenant="a"} 4' in text
    assert "# TYPE repro_quantum_s histogram" in text
    assert 'repro_quantum_s_bucket{le="+Inf"} 1' in text
    assert "repro_quantum_s_count 1" in text
    assert "repro_iterations_per_busy_sec 3.5" in text
    assert "# TYPE repro_queue_depth gauge" in text


# ------------------------------------------------- end-to-end acceptance
def test_traced_disk_streamed_als_spans_match_stats(tmp_path):
    """The ISSUE acceptance path: a disk-streamed CP-ALS run with tracing
    enabled produces a Perfetto-loadable trace with distinct store-read /
    H2D-put / device-dispatch spans whose per-track duration sums agree
    with the EngineStats histogram totals (exactly, by construction)."""
    t = core.random_tensor((30, 24, 18), 2000, seed=1)
    b = core.build_blco(t, max_nnz_per_block=256)
    obs.enable()
    plan = plan_for(b, 1 << 30, rank=4, backend="disk_streamed",
                    store_path=str(tmp_path / "t.blco"))
    cp_als(plan, t.dims, 4, iters=2,
           norm_x=float(np.linalg.norm(t.values.astype(np.float64))),
           tol=0.0, seed=0)
    st = plan.stats()
    plan.close()
    obs.disable()

    names = {s.name for s in obs.spans()}
    assert {"store.read", "h2d.put", "dispatch.launch", "device.fence",
            "plan.mttkrp"} <= names
    tot = obs.track_totals()
    for track, stat_total in (("store", st.disk_time_s),
                              ("h2d", st.put_time_s),
                              ("dispatch", st.dispatch_time_s),
                              ("device", st.device_time_s)):
        assert tot[track] == pytest.approx(stat_total, rel=0.10), track
    # histogram sums equal the scalar totals (same samples)
    assert st.hist.put_chunk_s.sum == pytest.approx(st.put_time_s)
    assert st.hist.disk_read_s.sum == pytest.approx(st.disk_time_s)
    assert st.hist.dispatch_s.sum == pytest.approx(st.dispatch_time_s)
    assert st.hist.launch_nnz.count == st.launches
    assert int(st.hist.launch_nnz.sum) == b.nnz * st.mttkrp_calls
    # and the export is valid Chrome trace JSON
    doc = obs.write_chrome_trace(str(tmp_path / "trace.json"))
    with open(tmp_path / "trace.json") as f:
        assert json.load(f) == doc


def test_tracing_disabled_records_nothing_on_hot_path():
    t = core.random_tensor((20, 16, 12), 800, seed=2)
    b = core.build_blco(t, max_nnz_per_block=128)
    plan = plan_for(b, 1 << 30, rank=3, backend="streamed")
    plan.mttkrp(_factors(t.dims, rank=3), 0)
    st = plan.stats()
    plan.close()
    assert obs.spans() == []                 # nothing recorded...
    assert st.hist.dispatch_s.count == st.launches   # ...hists still fill


def test_service_trace_and_metrics_endpoints():
    from repro.service import (GetMetrics, GetTrace, ServiceRuntime,
                               SubmitDecomposition)
    t = core.random_tensor((20, 15, 10), 600, seed=3)
    obs.enable()
    with ServiceRuntime(device_budget_bytes=256 << 20) as rt:
        job = rt.submit(SubmitDecomposition(tensor=t, rank=3, iters=2,
                                            tol=0.0, tenant="t0"))
        rt.wait(job, timeout=300)
        m = rt.get_metrics()
        prom = rt.get_metrics(GetMetrics(format="prometheus"))
        doc = rt.trace(GetTrace(drain=True))
    obs.disable()
    json.dumps(m)
    assert m["iterations_total"] == 2
    assert m["busy_time_s"] > 0
    assert m["iterations_per_sec"] == pytest.approx(2 / m["busy_time_s"])
    assert "repro_busy_time_s" in prom
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert "scheduler.quantum" in names
    # quantum spans parent the plan spans opened on the worker thread
    plan_spans = [e for e in doc["traceEvents"]
                  if e["ph"] == "X" and e["name"] == "plan.mttkrp"]
    assert plan_spans
    assert all(e["args"]["parent"] == "scheduler.quantum"
               for e in plan_spans)
    assert obs.spans() == []                 # drain=True emptied the buffer
    with pytest.raises(ValueError):
        rt.service.get_metrics(GetMetrics(format="xml"))
