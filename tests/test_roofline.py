"""Roofline attribution, per-tenant SLO evaluation, the background
telemetry exporter, and their service endpoints (GetRoofline/GetSLO)."""
import json
import math
import os
import time

import numpy as np
import pytest

from repro import core, obs
from repro.obs import ledger, roofline, slo
from repro.obs.hist import Hist, ServiceHists, MAX_TENANT_LABELS, \
    OVERFLOW_LABEL


@pytest.fixture(autouse=True)
def _clean_ledger():
    ledger.disable()
    ledger.clear()
    yield
    ledger.disable()
    ledger.clear()


# ---------------------------------------------------------------- roofline
def test_arithmetic_intensity_and_classification():
    assert roofline.arithmetic_intensity(100.0, 50.0) == 2.0
    assert roofline.arithmetic_intensity(100.0, 0.0) == 0.0
    # machine balance = peak_flops / peak_bytes_per_s; below it the
    # kernel is starved for bytes, above it for flops
    kw = {"peak_flops": 1e12, "peak_hbm_gb_per_s": 100.0}  # balance = 10
    assert roofline.classify(1.0, **kw) == "memory_bound"
    assert roofline.classify(100.0, **kw) == "compute_bound"
    assert roofline.classify(1.0, peak_flops=None,
                             peak_hbm_gb_per_s=None) == "unknown"


def test_roofline_report_from_ledger():
    ledger.enable()
    ledger.record(ledger.HOST_DEVICE, 10 * 10**9, 10.0, regime="streamed")
    ledger.record(ledger.DEVICE_HBM, 40 * 10**9, 10.0, regime="streamed",
                  flops=4 * 10**9)
    rep = obs.roofline_report(
        peaks={"host_device": 10.0, "device_hbm": 8.0},
        peak_flops=1e12)
    reg = rep["regimes"]["streamed"]
    hd = reg["edges"]["host_device"]
    assert hd["gb_per_s"] == pytest.approx(1.0)
    assert hd["achieved_fraction"] == pytest.approx(0.1)
    hbm = reg["edges"]["device_hbm"]
    assert hbm["achieved_fraction"] == pytest.approx(4.0 / 8.0)
    assert reg["saturated_edge"] == "device_hbm"      # closest to its peak
    assert reg["arithmetic_intensity"] == pytest.approx(0.1)
    assert reg["bound"] == "memory_bound"
    json.dumps(rep)


def test_roofline_report_empty_ledger_is_json_safe():
    rep = obs.roofline_report()
    assert rep["regimes"] == {}
    json.dumps(rep)


# --------------------------------------------------------------------- SLO
def _hist_with(values):
    h = Hist()
    for v in values:
        h.record(v)
    return h


def test_fraction_le_is_conservative():
    h = _hist_with([0.1] * 90 + [10.0] * 10)
    # 0.1 lands in the bucket with le=0.125 <= 0.2: all 90 count as good
    assert slo.fraction_le(h, 0.2) == pytest.approx(0.9)
    # min above threshold: conservatively zero good
    assert slo.fraction_le(h, 0.05) == 0.0
    # max below threshold: everything is good, regardless of buckets
    assert slo.fraction_le(_hist_with([0.5]), 100.0) == 1.0
    # empty hist: vacuously met
    assert slo.fraction_le(Hist(), 1.0) == 1.0


def test_evaluate_and_burn_rate():
    target = slo.SLO("wait", "queue_wait_s", threshold_s=0.2, target=0.95)
    h = _hist_with([0.1] * 90 + [10.0] * 10)
    v = slo.evaluate(target, h)
    assert v["samples"] == 100
    assert v["good_fraction"] == pytest.approx(0.9)
    assert not v["met"]
    # burning 10%/period against a 5% error budget = 2x burn
    assert v["burn_rate"] == pytest.approx(0.1 / 0.05)
    json.dumps(v)


def test_slo_report_global_and_per_tenant():
    hists = ServiceHists()
    for _ in range(20):
        hists.record_queue_wait("acme", 0.01)
        hists.record_quantum("acme", 0.01)
    for _ in range(20):
        hists.record_queue_wait("umbrella", 30.0)
        hists.record_quantum("umbrella", 30.0)
    rep = slo.slo_report(hists)
    assert set(rep["global"]) == {s.name for s in slo.DEFAULT_SLOS}
    assert rep["tenants"]["acme"]["queue_wait_under_1s"]["met"]
    assert not rep["tenants"]["umbrella"]["queue_wait_under_1s"]["met"]
    json.dumps(rep)


# ------------------------------------------------------------ tenant hists
def test_tenant_hists_rollup_is_lossless():
    hists = ServiceHists()
    for n in range(5):
        hists.record_queue_wait(f"t{n}", float(n + 1))
    snap = hists.tenant_snapshot()
    assert set(snap) == {f"t{n}" for n in range(5)}
    # the global hist is the exact rollup: same count, same sum
    assert hists.queue_wait_s.count == 5
    assert hists.queue_wait_s.sum == pytest.approx(sum(range(1, 6)))
    per_tenant = sum(s["queue_wait_s"]["count"] for s in snap.values())
    assert per_tenant == hists.queue_wait_s.count


def test_tenant_hists_cardinality_bounded():
    hists = ServiceHists()
    for n in range(MAX_TENANT_LABELS + 10):
        hists.record_quantum(f"tenant-{n:03d}", 0.5)
    snap = hists.tenant_snapshot()
    assert len(snap) == MAX_TENANT_LABELS + 1
    assert snap[OVERFLOW_LABEL]["quantum_s"]["count"] == 10
    # rollup stays lossless through the overflow bucket
    total = sum(s["quantum_s"]["count"] for s in snap.values())
    assert total == hists.quantum_s.count == MAX_TENANT_LABELS + 10


# ---------------------------------------------------------------- exporter
class _Target:
    """Minimal exporter target: metrics + SLO surface of the runtime."""

    def __init__(self):
        from repro.service.metrics import ServiceMetrics
        self.metrics = ServiceMetrics()
        self.metrics.jobs_completed = 1
        self.metrics.hist.record_queue_wait("acme", 0.01)

    def service_metrics(self):
        return self.metrics.snapshot()

    def get_slo(self, req=None):
        return slo.slo_report(self.metrics.hist)


def test_exporter_writes_jsonl_and_prom(tmp_path):
    jsonl = str(tmp_path / "telemetry.jsonl")
    prom = str(tmp_path / "telemetry.prom")
    target = _Target()
    exp = slo.TelemetryExporter(target, interval_s=0.05,
                                jsonl_path=jsonl, prom_path=prom)
    with exp:
        deadline = time.time() + 5.0
        while exp.counters()["exports"] < 2 and time.time() < deadline:
            time.sleep(0.02)
    counters = exp.counters()
    assert counters["exports"] >= 2 and counters["failures"] == 0
    assert not exp.running
    with open(jsonl) as f:
        records = [json.loads(line) for line in f]
    assert len(records) == counters["exports"]
    for rec in records:
        assert rec["metrics"]["jobs_completed"] == 1
        assert "slo" in rec and "ledger" in rec and "ts" in rec
    # the prom textfile is a complete, atomic snapshot
    text = open(prom).read()
    assert "repro_ledger_enabled" in text
    assert text.endswith("\n")


def test_exporter_counts_failures_and_survives(tmp_path):
    class _Broken(_Target):
        def service_metrics(self):
            raise RuntimeError("boom")

    exp = slo.TelemetryExporter(_Broken(), interval_s=0.05,
                                jsonl_path=str(tmp_path / "t.jsonl"))
    exp.start()
    deadline = time.time() + 5.0
    while exp.counters()["failures"] < 2 and time.time() < deadline:
        time.sleep(0.02)
    assert exp.running                     # a failed export never kills it
    exp.stop()
    assert exp.counters()["failures"] >= 2
    assert exp.counters()["exports"] == 0


def test_exporter_disabled_paths_are_noops():
    target = _Target()
    exp = slo.TelemetryExporter(target, interval_s=60.0)  # no sinks
    exp.start()
    assert exp.export_once()               # still builds the record
    exp.stop(final_export=False)
    assert not exp.running


# --------------------------------------------------------- service surface
def test_service_roofline_and_slo_endpoints():
    from repro.service import (GetRoofline, GetSLO, ServiceRuntime,
                               SubmitDecomposition)
    t = core.random_tensor((20, 15, 10), 600, seed=3)
    ledger.enable()
    with ServiceRuntime(device_budget_bytes=256 << 20) as rt:
        job = rt.submit(SubmitDecomposition(tensor=t, rank=3, iters=2,
                                            tol=0.0, tenant="acme"))
        rt.wait(job, timeout=300)
        roof = rt.get_roofline(GetRoofline(
            peaks={"host_device": 100.0, "device_hbm": 100.0},
            peak_flops=1e12))
        slo_rep = rt.get_slo(GetSLO())
        m = rt.get_metrics()
    json.dumps(roof)
    json.dumps(slo_rep)
    # the submitted job's transfers landed in the ledger under its tenant
    snap = ledger.snapshot()
    assert "acme" in snap["tenants"]
    assert snap["edges"]["host_device"]["bytes"] > 0
    assert "in_memory" in roof["regimes"]
    assert roof["regimes"]["in_memory"]["bound"] in (
        "memory_bound", "compute_bound")
    # per-tenant SLO + tenant_hist metrics surface
    assert "acme" in slo_rep["tenants"]
    assert all(s["met"] in (True, False)
               for s in slo_rep["global"].values())
    assert m["tenant_hist"]["acme"]["quantum_s"]["count"] >= 1


def test_prometheus_exposition_tenant_trace_ledger_series():
    from repro.service import (GetMetrics, ServiceRuntime,
                               SubmitDecomposition)
    t = core.random_tensor((16, 12, 10), 400, seed=4)
    ledger.enable()
    obs.enable()
    try:
        with ServiceRuntime(device_budget_bytes=256 << 20) as rt:
            job = rt.submit(SubmitDecomposition(tensor=t, rank=3, iters=1,
                                                tol=0.0, tenant="acme"))
            rt.wait(job, timeout=300)
            prom = rt.get_metrics(GetMetrics(format="prometheus"))
    finally:
        obs.disable()
    assert 'repro_tenant_queue_wait_s_count{tenant="acme"}' in prom
    assert 'repro_tenant_quantum_s_bucket{tenant="acme"' in prom
    assert "repro_trace_dropped_spans_total" in prom
    assert "repro_trace_enabled 1" in prom
    assert "repro_ledger_enabled 1" in prom
    assert 'repro_ledger_bytes_total{edge="host_device"}' in prom
    assert 'repro_ledger_gb_per_s{edge="host_device"}' in prom
