"""Async service runtime: cancellation, streaming status, weighted shares.

Acceptance scenarios (ISSUE 4):
* a 3-tenant run with weights (2, 1, 1) yields an iteration trace within
  10% of the 2:1:1 share (here: exactly 2:1:1 — stride scheduling is
  deterministic);
* ``cancel()`` frees measured pooled bytes mid-run (asserted via
  ``ServiceEngine.pooled_bytes()``) and a waiting job is admitted
  immediately, for a queued job, a running job, and the last sharer of a
  pooled resident copy;
* the runtime streams per-iteration ``JobEvent`` snapshots to both
  blocking and asyncio subscribers while jobs run on the worker thread.
"""
import asyncio
import time

import numpy as np
import pytest

from repro import core
from repro.service import (BuildParams, CancelJob, DecompositionService,
                           ServiceRuntime, SetWeight, SubmitDecomposition,
                           TensorRegistry)
from repro.engine import factor_bytes

BUILD = BuildParams(max_nnz_per_block=256)


def _t1(seed=6):
    return core.random_tensor((30, 22, 14), 1500, seed=seed, dist="powerlaw")


def _req(t, *, seed=0, iters=4, tenant="default", weight=1.0, rank=4):
    return SubmitDecomposition(tensor=t, rank=rank, iters=iters, seed=seed,
                               tol=0.0, tenant=tenant, weight=weight,
                               build=BUILD)


# --------------------------------------------------------- weighted shares
def test_weighted_fair_share_2_1_1():
    """The ISSUE acceptance: weights (2, 1, 1) -> iteration shares within
    10% of (1/2, 1/4, 1/4) over the window where all tenants are active."""
    t = _t1()
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    # tenant A gets twice the sweeps, so 2x the iterations finish together
    a = svc.submit(_req(t, seed=0, iters=8, tenant="A", weight=2.0))
    b = svc.submit(_req(t, seed=1, iters=4, tenant="B", weight=1.0))
    c = svc.submit(_req(t, seed=2, iters=4, tenant="C", weight=1.0))
    svc.run()
    m = svc.service_metrics()
    assert m["tenant_iterations"] == {"A": 8, "B": 4, "C": 4}
    for tenant, expected in (("A", 0.5), ("B", 0.25), ("C", 0.25)):
        assert abs(m["tenant_shares"][tenant] - expected) <= 0.1 * expected
    # all tenants stay interleaved: every 4-quantum window is 2xA, 1xB, 1xC
    trace = svc.scheduler.trace
    assert len(trace) == 16
    for w in range(4):
        window = trace[4 * w:4 * w + 4]
        assert window.count(a) == 2 and window.count(b) == 1 \
            and window.count(c) == 1
    assert all(svc.status(j).state == "done" for j in (a, b, c))


def test_equal_weights_reproduce_round_robin():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    ids = [svc.submit(_req(_t1(), seed=s, iters=3)) for s in range(3)]
    svc.run()
    assert svc.scheduler.trace == ids * 3


def test_set_weight_preempts_between_sweeps_keeping_state():
    """Demoting a heavy tenant takes effect at the next quantum and never
    resets its CPState (fits keep accumulating from where they were)."""
    t = _t1()
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    heavy = svc.submit(_req(t, seed=0, iters=50, tenant="heavy", weight=4.0))
    light = svc.submit(_req(t, seed=1, iters=50, tenant="light", weight=1.0))
    for _ in range(10):
        svc.step()
    head = svc.scheduler.trace[:10]
    assert head.count(heavy) == 8 and head.count(light) == 2   # 4:1
    fits_before = list(svc.scheduler.jobs[heavy].cp.fits)

    svc.set_weight(SetWeight(weight=1.0, tenant="heavy"))       # demote
    assert svc.service_metrics()["preemptions"] == 1
    for _ in range(10):
        svc.step()
    tail = svc.scheduler.trace[10:20]
    # equal weights from the demotion on: the 4:1 window becomes 1:1
    assert tail.count(light) == tail.count(heavy) == 5
    # CPState survived the demotion: the old trajectory is a prefix
    fits_after = svc.scheduler.jobs[heavy].cp.fits
    assert fits_after[:len(fits_before)] == fits_before
    assert len(fits_after) > len(fits_before)

    with pytest.raises(ValueError, match="must be > 0"):
        svc.set_weight(SetWeight(weight=0.0, job_id=heavy))
    with pytest.raises(ValueError, match="exactly one of"):
        svc.set_weight(SetWeight(weight=2.0))
    # a tenant whose jobs already finished is a no-op, not an error (the
    # caller cannot win that race against the async runtime's worker)
    svc.run()
    update = svc.set_weight(SetWeight(weight=3.0, tenant="heavy"))
    assert update.job_ids == ()


def test_weight_validation_at_submit():
    svc = DecompositionService(device_budget_bytes=64 << 20)
    with pytest.raises(ValueError, match="weight must be > 0"):
        svc.submit(_req(_t1(), weight=-1.0))


# ------------------------------------------------------------ cancellation
def test_cancel_queued_job():
    """Cancelling a queued job unblocks FIFO admission behind it."""
    t = _t1()
    probe = TensorRegistry()
    h = probe.register(t, build=BUILD)
    fb = factor_bytes(h.dims, 4, np.float32)
    budget = h.in_memory_bytes + fb               # exactly one job fits
    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    j0 = svc.submit(_req(t, seed=0, iters=2))
    j1 = svc.submit(_req(t, seed=1, iters=2))
    j2 = svc.submit(_req(t, seed=2, iters=2))
    assert [svc.status(j).state for j in (j0, j1, j2)] == \
        ["running", "queued", "queued"]
    res = svc.cancel(CancelJob(job_id=j1))
    assert res.cancelled and res.state == "cancelled"
    assert res.freed_bytes == 0                   # held nothing yet
    assert svc.scheduler.pending == [j2]          # j2 moved up behind j0
    # queue_wait of a never-admitted job freezes at cancellation
    frozen = svc.status(j1).queue_wait_s
    time.sleep(0.02)
    assert svc.status(j1).queue_wait_s == frozen
    svc.run()
    assert svc.status(j1).state == "cancelled"
    assert svc.status(j0).state == svc.status(j2).state == "done"
    m = svc.service_metrics()
    assert m["jobs_cancelled"] == 1 and m["jobs_completed"] == 2
    assert not svc.cancel(j1).cancelled           # idempotent on final jobs


def test_cancel_running_job_frees_bytes_and_admits_waiter():
    """The ISSUE acceptance: cancel mid-run frees the measured pooled bytes
    (ServiceEngine.pooled_bytes()) and the waiting job is admitted in the
    same call."""
    t = _t1()
    probe = TensorRegistry()
    h = probe.register(t, build=BUILD)
    fb = factor_bytes(h.dims, 4, np.float32)
    budget = h.in_memory_bytes + fb
    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    j0 = svc.submit(_req(t, seed=0, iters=50))
    j1 = svc.submit(_req(t, seed=1, iters=2))
    for _ in range(3):                            # j0 makes real progress
        svc.step()
    assert svc.status(j0).state == "running"
    assert svc.status(j1).state == "queued"
    held = svc.engine.pooled_bytes()
    assert held == h.in_memory_bytes
    res = svc.cancel(j0)
    assert res.cancelled and res.freed_bytes == h.in_memory_bytes + fb
    # j0 was the only sharer: its pooled copy was measurably released,
    # and the waiter was admitted immediately against the freed budget
    assert svc.status(j1).state == "running"
    assert svc.engine.pooled_bytes() == held      # j1 re-pooled the copy
    assert svc.service_metrics()["admitted_reservation_bytes"] == budget
    assert svc.service_metrics()["cancel_freed_bytes_total"] == \
        res.freed_bytes
    # the cancelled job keeps its partial CPState for inspection
    assert svc.scheduler.jobs[j0].cp.iteration == 3
    svc.run()
    assert svc.status(j1).state == "done"
    assert svc.engine.pooled_bytes() == 0
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0


def test_cancel_last_sharer_releases_pooled_resident_copy():
    t = _t1()
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    j0 = svc.submit(_req(t, seed=0, iters=50))
    j1 = svc.submit(_req(t, seed=1, iters=50))
    assert svc.engine.resident_count == 1         # one shared copy
    pooled = svc.engine.pooled_bytes()
    fb = factor_bytes(t.dims, 4, np.float32)
    svc.step()
    # first sharer leaves: the copy stays for the second sharer
    assert svc.cancel(j0).freed_bytes == fb       # only its working set
    assert svc.engine.resident_count == 1
    assert svc.engine.pooled_bytes() == pooled
    # LAST sharer leaves: pooled bytes measurably return to zero
    assert svc.cancel(j1).freed_bytes == pooled + fb
    assert svc.engine.resident_count == 0
    assert svc.engine.pooled_bytes() == 0
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0
    assert svc.scheduler.jobs[j0].handle.pins == 0
    assert not svc.step()                         # nothing left to run


# ----------------------------------------------------------- async runtime
def test_runtime_runs_jobs_and_matches_sync_service():
    t = _t1()
    sync = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    sj = sync.submit(_req(t, seed=3, iters=4))
    ref = sync.run()[sj]

    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        j = rt.submit(_req(t, seed=3, iters=4))
        status = rt.wait(j, timeout=120)
        assert status.state == "done" and status.iteration == 4
        got = rt.result(j)
        assert rt.drain(timeout=10)
    assert got.result.fits == ref.result.fits
    for a, b in zip(got.result.factors, ref.result.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_runtime_streaming_status_feed():
    """Every sweep publishes a JobEvent carrying the fit trajectory."""
    t = _t1()
    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        feed = rt.subscribe()                     # subscribe BEFORE submit
        j = rt.submit(_req(t, seed=0, iters=4, tenant="streaming"))
        events = []
        for ev in feed:
            if ev.job_id == j:
                events.append(ev)
            if ev.job_id == j and ev.terminal:
                rt.unsubscribe(feed)
        kinds = [ev.kind for ev in events]
        assert kinds[0] == "queued" and kinds[1] == "admitted"
        assert kinds.count("iteration") == 4 and kinds[-1] == "done"
        iters = [ev for ev in events if ev.kind == "iteration"]
        # fit trajectories grow one sweep at a time, monotonically complete
        assert [len(ev.fits) for ev in iters] == [1, 2, 3, 4]
        assert iters[-1].fits[:3] == iters[2].fits
        assert all(ev.tenant == "streaming" for ev in events)
        assert events[-1].metrics["iterations"] == 4
        seqs = [ev.seq for ev in events]
        assert seqs == sorted(seqs)


def test_runtime_async_stream_and_result():
    t = _t1()

    async def drive(rt):
        # prime the all-jobs stream so its feed subscribes BEFORE submit:
        # every lifecycle event of the job is then observed, race-free
        agen = rt.stream(None)
        first = asyncio.ensure_future(anext(agen))
        await asyncio.sleep(0)                    # generator reaches get()
        j = rt.submit(_req(t, seed=1, iters=3, tenant="aio"))
        kinds = []
        ev = await first
        while True:
            if ev.job_id == j:
                kinds.append(ev.kind)
                if ev.terminal:
                    break
            ev = await anext(agen)
        await agen.aclose()
        result = await rt.result_async(j, timeout=120)
        return kinds, result

    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        kinds, result = asyncio.run(drive(rt))
    assert kinds[:2] == ["queued", "admitted"]
    assert kinds.count("iteration") == 3 and kinds[-1] == "done"
    assert result.metrics["iterations"] == 3


def test_runtime_cancel_mid_run_frees_pooled_bytes():
    t = _t1()
    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        j = rt.submit(_req(t, seed=0, iters=10_000, tenant="victim"))
        feed = rt.subscribe(j)
        assert feed.get(timeout=60).job_id == j   # it is really running
        res = rt.cancel(CancelJob(job_id=j))
        assert res.cancelled and res.freed_bytes > 0
        assert rt.status(j).state == "cancelled"
        assert rt.service.engine.pooled_bytes() == 0
        assert rt.service_metrics()["admitted_reservation_bytes"] == 0
        assert rt.drain(timeout=10)


def test_runtime_wait_on_finished_job_and_subscribe_after_terminal():
    t = _t1()
    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        j = rt.submit(_req(t, seed=0, iters=2))
        rt.wait(j, timeout=120)
        # both of these must return instantly instead of hanging
        assert rt.wait(j, timeout=1).state == "done"
        assert list(rt.subscribe(j)) == []
        with pytest.raises(ValueError, match="unknown job id"):
            rt.wait(j + 99)


def test_runtime_weighted_tenants_end_to_end():
    """3 concurrent tenants with weights (2, 1, 1) through the threaded
    runtime: shares land within 10% of 2:1:1 (same stride math, now
    driven by the worker thread)."""
    t = _t1()
    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        rt.submit(_req(t, seed=0, iters=8, tenant="A", weight=2.0))
        rt.submit(_req(t, seed=1, iters=4, tenant="B", weight=1.0))
        rt.submit(_req(t, seed=2, iters=4, tenant="C", weight=1.0))
        assert rt.drain(timeout=240)
        m = rt.service_metrics()
    assert m["tenant_iterations"] == {"A": 8, "B": 4, "C": 4}
    for tenant, expected in (("A", 0.5), ("B", 0.25), ("C", 0.25)):
        assert abs(m["tenant_shares"][tenant] - expected) <= 0.1 * expected


def test_runtime_subscribe_unknown_job_raises():
    with ServiceRuntime(device_budget_bytes=64 << 20) as rt:
        with pytest.raises(ValueError, match="unknown job id"):
            rt.subscribe(42)


def test_runtime_worker_failure_surfaces_instead_of_hanging():
    """An exception escaping the scheduling quantum (here: a broken
    observer) must not silently kill the worker thread — drain() and
    submit() raise instead of blocking forever."""
    t = _t1()
    with ServiceRuntime(device_budget_bytes=64 << 20, queues=2) as rt:
        def bomb(job, kind):
            if kind == "iteration":
                raise RuntimeError("observer boom")
        rt.scheduler.observers.append(bomb)
        rt.submit(_req(t, seed=0, iters=5))
        with pytest.raises(RuntimeError, match="worker failed"):
            rt.drain(timeout=60)
        with pytest.raises(RuntimeError, match="worker failed"):
            rt.submit(_req(t, seed=1, iters=1))


def test_runtime_stop_is_idempotent_and_restart_rejected():
    rt = ServiceRuntime(device_budget_bytes=64 << 20).start()
    with pytest.raises(RuntimeError, match="already started"):
        rt.start()
    rt.stop()
    rt.stop()                                     # safe no-op
