"""Multi-tenant decomposition service: registry cache, admission, fair share.

The acceptance scenario: >=3 concurrent jobs on >=2 distinct tensors run
through the scheduler with (a) a BLCO cache hit on the repeated tensor,
(b) admitted plan bytes never exceeding the budget, (c) per-job CP factors
matching a solo engine run on the same seeds.  Admission is by *measured*
``plan.device_bytes()``: small tensors get the device-resident fast path,
larger ones stream through pooled reservations, under one shared budget.
"""
import numpy as np
import pytest

from repro import core
from repro.engine import factor_bytes, in_memory_bytes, plan_for
from repro.service import (BuildParams, DecompositionService, MTTKRPQuery,
                           SubmitDecomposition, TensorRegistry)

BUILD = BuildParams(max_nnz_per_block=256)      # force many launches

def _t1(seed=6):
    return core.random_tensor((30, 22, 14), 1500, seed=seed, dist="powerlaw")


def _t2():
    return core.random_tensor((40, 25, 30), 2000, seed=3, dist="powerlaw")


def _norm(t):
    return float(np.linalg.norm(t.values))


def test_acceptance_three_jobs_two_tensors():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=3)
    t1, t2, t1_again = _t1(), _t2(), _t1()
    assert t1_again is not t1                     # distinct objects, same content
    j1 = svc.submit(SubmitDecomposition(tensor=t1, rank=6, iters=5, seed=7,
                                        build=BUILD))
    j2 = svc.submit(SubmitDecomposition(tensor=t2, rank=8, iters=5, seed=1,
                                        build=BUILD))
    j3 = svc.submit(SubmitDecomposition(tensor=t1_again, rank=6, iters=5,
                                        seed=7, build=BUILD))
    results = svc.run()
    assert set(results) == {j1, j2, j3}
    m = svc.service_metrics()
    # (a) BLCO cache hit on the repeated tensor
    assert m["blco_cache_hits"] == 1 and m["blco_cache_misses"] == 2
    assert svc.status(j3).cache_hit and not svc.status(j1).cache_hit
    # (b) admitted plan bytes never exceeded the budget; a 64 MiB budget
    # gives every tenant the device-resident fast path
    assert 0 < m["peak_admitted_reservation_bytes"] <= 64 << 20
    assert m["admitted_reservation_bytes"] == 0   # all released at the end
    assert all(svc.status(j).backend == "in_memory" for j in (j1, j2, j3))
    # (c) per-job factors match a solo engine run on the same seeds
    for jid, t, rank, seed in ((j1, t1, 6, 7), (j2, t2, 8, 1)):
        b = core.build_blco(t, max_nnz_per_block=256)
        plan = plan_for(b, 64 << 20, rank=rank, backend="in_memory")
        ref = core.cp_als(plan, t.dims, rank, norm_x=_norm(t), iters=5,
                          seed=seed)
        plan.close()
        got = results[jid].result
        np.testing.assert_allclose(got.fits, ref.fits, rtol=1e-5, atol=1e-6)
        for a, b_ in zip(got.factors, ref.factors):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-5)
    # identical submissions produce identical factors (shared BLCO copy)
    for a, b_ in zip(results[j1].result.factors, results[j3].result.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b_))


def test_round_robin_iteration_fair_share():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    ids = [svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=4,
                                          seed=s, tol=0.0, build=BUILD))
           for s in range(3)]
    svc.run()
    trace = svc.scheduler.trace
    assert len(trace) == 12                       # 3 jobs x 4 iterations
    # every scheduling cycle advances each active job exactly once
    for cycle in range(4):
        assert trace[cycle * 3:(cycle + 1) * 3] == ids


def test_fast_path_and_streaming_share_one_budget():
    """The ISSUE acceptance: under ONE budget the engine runs the small
    tensor device-resident and streams the large one, and admission charges
    exactly the measured plan bytes."""
    t_small, t_big = _t1(), _t2()
    probe = TensorRegistry()
    h_small = probe.register(t_small, build=BUILD)
    h_big = probe.register(t_big, build=BUILD)
    # budget: small's residency + big's reservation + both factor sets
    budget = h_small.in_memory_bytes \
        + factor_bytes(t_small.dims, 4, np.float32) \
        + h_big.spec.bytes_in_flight(2) \
        + factor_bytes(t_big.dims, 4, np.float32)
    assert budget - h_small.in_memory_bytes < h_big.in_memory_bytes

    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    js = svc.submit(SubmitDecomposition(tensor=t_small, rank=4, iters=3,
                                        seed=0, build=BUILD))
    jb = svc.submit(SubmitDecomposition(tensor=t_big, rank=4, iters=3,
                                        seed=0, build=BUILD))
    assert svc.status(js).backend == "in_memory"      # fast path
    assert svc.status(jb).backend == "streamed"       # too big -> streams
    # measured admission: the resident copy + the pooled reservation + each
    # job's (never pooled) factor working set
    m = svc.service_metrics()
    assert m["admitted_reservation_bytes"] == \
        h_small.in_memory_bytes + factor_bytes(t_small.dims, 4, np.float32) \
        + h_big.spec.bytes_in_flight(2) \
        + factor_bytes(t_big.dims, 4, np.float32)
    svc.run()
    m = svc.service_metrics()
    assert svc.status(js).state == "done" and svc.status(jb).state == "done"
    assert m["peak_admitted_reservation_bytes"] <= budget
    assert m["admitted_reservation_bytes"] == 0
    # launches count compute dispatches: the resident job issues exactly
    # ONE fused dispatch per MTTKRP call (the launch-cache scan), while the
    # streamed job pays one dispatch per reservation chunk per call
    rs, rb = svc.result(js).metrics, svc.result(jb).metrics
    assert rs["backend"] == "in_memory" and \
        rs["launches"] == rs["mttkrp_calls"] > 0
    assert rb["backend"] == "streamed" and \
        rb["launches"] > rb["mttkrp_calls"] > 0
    # both still match a solo engine run on the same seeds
    b = core.build_blco(t_big, max_nnz_per_block=256)
    solo = plan_for(b, h_big.spec.bytes_in_flight(2)
                    + factor_bytes(t_big.dims, 4, np.float32),
                    rank=4, queues=2)
    assert solo.backend == "streamed"
    ref = core.cp_als(solo, t_big.dims, 4, norm_x=_norm(t_big), iters=3,
                      seed=0)
    solo.close()
    np.testing.assert_allclose(svc.result(jb).result.fits, ref.fits,
                               rtol=1e-5, atol=1e-6)


def test_admission_control_respects_budget():
    # the budget fits the small tensor's regime but not the big one's ->
    # the second job must queue until the first completes and releases
    t1, t2 = _t1(), _t2()
    probe = TensorRegistry()
    small = probe.register(t1, build=BUILD).spec.bytes_in_flight(2)
    big = probe.register(
        t2, build=BuildParams(max_nnz_per_block=512)).spec.bytes_in_flight(2)
    assert small < big
    budget = big + factor_bytes(t2.dims, 4, np.float32)
    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    j1 = svc.submit(SubmitDecomposition(tensor=t1, rank=4, iters=3, seed=0,
                                        build=BUILD))
    j2 = svc.submit(SubmitDecomposition(
        tensor=t2, rank=4, iters=3, seed=0,
        build=BuildParams(max_nnz_per_block=512)))
    assert svc.status(j1).state == "running"
    assert svc.status(j2).state == "queued"       # over budget: must wait
    assert svc.status(j2).queue_wait_s >= 0.0
    svc.run()
    m = svc.service_metrics()
    assert svc.status(j1).state == "done" and svc.status(j2).state == "done"
    assert m["peak_admitted_reservation_bytes"] <= budget


def test_tenants_share_pooled_state():
    """Plans over one pool entry charge the budget once, whichever pool.

    Same-content tensors under a big budget share ONE device-resident copy;
    under a tight budget, same-shape tensors share ONE reservation.  Each
    job's factor working set is charged per job on top of the pooled entry
    (it is private to the job, never shared)."""
    # residency pooling: 3 tenants, one DeviceBLCO copy, charged once
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    for s in range(3):                            # same tensor content 3x
        svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=2, seed=s,
                                       build=BUILD))
    assert svc.engine.resident_count == 1         # one pooled resident copy
    assert svc.engine.pool_size == 0              # nothing streams
    one = svc.scheduler.jobs[0].handle.in_memory_bytes
    fb = factor_bytes(svc.scheduler.jobs[0].handle.dims, 4, np.float32)
    assert svc.service_metrics()["admitted_reservation_bytes"] == one + 3 * fb
    svc.run()
    assert svc.service_metrics()["peak_admitted_reservation_bytes"] == \
        one + 3 * fb
    assert svc.engine.resident_count == 0         # released at the end

    # reservation pooling: budget below residency -> all three stream
    # through one pooled shape, charged once (+ one working set per job)
    probe = TensorRegistry()
    h = probe.register(_t1(), build=BUILD)
    res_bytes = h.spec.bytes_in_flight(2)
    budget = res_bytes + 3 * fb + 1024
    assert budget < h.in_memory_bytes + fb        # residency can't fit
    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    for s in range(3):
        svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=2, seed=s,
                                       build=BUILD))
    assert svc.engine.pool_size == 1              # one pooled shape
    assert svc.engine.resident_count == 0
    assert svc.service_metrics()["admitted_reservation_bytes"] == \
        res_bytes + 3 * fb
    svc.run()
    assert svc.service_metrics()["peak_admitted_reservation_bytes"] == \
        res_bytes + 3 * fb


def test_admission_charges_working_set_no_overcommit():
    """ISSUE 4 satellite: K admitted same-tensor jobs hold exactly
    K * factor_bytes + ONE pooled tensor copy.

    The old ``try_plan`` checked the factor working set at admission but
    never charged it to the ledger, so every later same-tensor job passed a
    check that assumed ``working`` was free — the budget could be
    overcommitted by N x factor_bytes.  This test fails on that code: all
    three jobs were admitted against a budget sized for two."""
    t = _t1()
    probe = TensorRegistry()
    h = probe.register(t, build=BUILD)
    fb = factor_bytes(h.dims, 4, np.float32)
    budget = h.in_memory_bytes + 2 * fb       # one copy + TWO working sets
    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    ids = [svc.submit(SubmitDecomposition(tensor=t, rank=4, iters=2, seed=s,
                                          tol=0.0, build=BUILD))
           for s in range(3)]
    states = [svc.status(j).state for j in ids]
    assert states == ["running", "running", "queued"]
    m = svc.service_metrics()
    assert m["admitted_reservation_bytes"] == h.in_memory_bytes + 2 * fb
    assert m["admitted_reservation_bytes"] <= budget  # ledger == reality
    svc.run()
    assert all(svc.status(j).state == "done" for j in ids)
    assert svc.service_metrics()["peak_admitted_reservation_bytes"] <= budget
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0


def test_pool_join_branch_checks_working_set():
    """ISSUE 4 satellite: the resident pool-join branch (resident cost 0)
    must still check AND charge the joiner's working set — the old code
    admitted any sharer of a pooled copy unconditionally."""
    t = _t1()
    probe = TensorRegistry()
    h = probe.register(t, build=BUILD)
    fb = factor_bytes(h.dims, 4, np.float32)
    budget = h.in_memory_bytes + fb           # exactly ONE job fits
    svc = DecompositionService(device_budget_bytes=budget, queues=2)
    j0 = svc.submit(SubmitDecomposition(tensor=t, rank=4, iters=2, seed=0,
                                        tol=0.0, build=BUILD))
    j1 = svc.submit(SubmitDecomposition(tensor=t, rank=4, iters=2, seed=1,
                                        tol=0.0, build=BUILD))
    assert svc.status(j0).state == "running"
    assert svc.status(j1).state == "queued"   # joining is NOT free
    assert svc.service_metrics()["admitted_reservation_bytes"] == budget
    svc.run()
    assert svc.status(j1).state == "done"     # admitted once j0 released
    assert svc.service_metrics()["peak_admitted_reservation_bytes"] <= budget


def test_evict_pinned_handle_raises():
    """ISSUE 4 satellite: eviction of a handle whose chunks live plans
    still reference raises instead of corrupting the running jobs."""
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    j0 = svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=2,
                                        seed=0, tol=0.0, build=BUILD))
    j1 = svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=2,
                                        seed=1, tol=0.0, build=BUILD))
    key = svc.scheduler.jobs[j0].handle.key
    assert svc.scheduler.jobs[j0].handle.pins == 2    # both live plans
    with pytest.raises(RuntimeError, match="pinned by 2 live plan"):
        svc.registry.evict(key)
    assert svc.registry.get(key) is not None          # still cached intact
    svc.run()
    assert svc.scheduler.jobs[j0].handle.pins == 0    # plans closed
    assert svc.registry.evict(key)                    # now safe
    assert svc.registry.get(key) is None


def test_oversized_job_rejected_at_submit():
    svc = DecompositionService(device_budget_bytes=1024, queues=4)
    with pytest.raises(ValueError, match="can never be admitted"):
        svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, build=BUILD))
    # regression: a tiny reservation does NOT sneak a huge factor working
    # set past admission — rank-R factor bytes count in every regime
    t = _t1()
    probe = TensorRegistry()
    h = probe.register(t, build=BUILD)
    budget = h.spec.bytes_in_flight(4) + h.in_memory_bytes
    assert factor_bytes(t.dims, 4096, np.float32) > budget
    svc = DecompositionService(device_budget_bytes=budget, queues=4)
    with pytest.raises(ValueError, match="can never be admitted"):
        svc.submit(SubmitDecomposition(tensor=t, rank=4096, build=BUILD))


def test_unknown_job_id_raises_value_error():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    with pytest.raises(ValueError, match="no jobs submitted yet"):
        svc.status(0)
    j = svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=1,
                                       build=BUILD))
    svc.run()
    assert svc.status(j).state == "done"
    with pytest.raises(ValueError, match=r"unknown job id 7; known ids: 0..0"):
        svc.status(7)
    with pytest.raises(ValueError, match="unknown job id"):
        svc.result(j + 1)


def test_registry_fingerprint_semantics():
    reg = TensorRegistry()
    h1 = reg.register(_t1(), build=BUILD)
    h2 = reg.register(_t1(), build=BUILD)         # same content -> hit
    assert h1 is h2 and reg.hits == 1 and reg.misses == 1
    h3 = reg.register(_t1(), build=BuildParams(max_nnz_per_block=512))
    assert h3 is not h1 and reg.misses == 2       # build params change -> miss
    t_other = _t1(seed=7)
    h4 = reg.register(t_other, build=BUILD)       # different content -> miss
    assert h4 is not h1 and reg.misses == 3
    assert len(reg) == 3 and reg.host_bytes() > 0
    assert reg.evict(h3.key) and len(reg) == 2


def test_mttkrp_query_matches_in_memory():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=3)
    t = _t1()
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 8)).astype(np.float32) for d in t.dims]
    b = core.build_blco(t, max_nnz_per_block=256)
    for mode in range(t.order):
        got = svc.mttkrp(MTTKRPQuery(tensor=t, factors=factors, mode=mode,
                                     build=BUILD))
        ref = core.mttkrp(b, factors, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # all three queries + any later job reuse one cached BLCO build
    assert svc.registry.misses == 1 and svc.registry.hits == 2
    # query plans are closed: nothing left admitted or pooled
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0
    assert svc.engine.resident_count == 0 and svc.engine.pool_size == 0


def test_failed_job_isolated_and_plan_released():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    good = svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=3,
                                          seed=0, build=BUILD))
    bad = svc.submit(SubmitDecomposition(tensor=_t2(), rank=4, iters=3,
                                         seed=0, build=BUILD))
    svc.scheduler.jobs[bad].mttkrp_fn = \
        lambda f, m: (_ for _ in ()).throw(RuntimeError("boom"))
    svc.run()
    assert svc.status(bad).state == "failed"
    assert "boom" in svc.status(bad).error
    assert svc.status(good).state == "done"       # unaffected tenant
    m = svc.service_metrics()
    assert m["admitted_reservation_bytes"] == 0   # plans closed on retire
    assert m["jobs_failed"] == 1 and m["jobs_completed"] == 1
    assert svc.engine.resident_count == 0 and svc.engine.pool_size == 0


def test_mttkrp_query_obeys_budget():
    """One-shot queries charge the same admission budget as jobs."""
    t = _t1()
    factors = [np.zeros((d, 4), np.float32) for d in t.dims]
    svc = DecompositionService(device_budget_bytes=1024, queues=4)
    with pytest.raises(ValueError, match="does not fit the device budget"):
        svc.mttkrp(MTTKRPQuery(tensor=t, factors=factors, mode=0, build=BUILD))
    assert svc.engine.pool_size == 0              # nothing leaked
    assert svc.engine.resident_count == 0
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0
    with pytest.raises(ValueError, match="out of range"):
        DecompositionService().mttkrp(
            MTTKRPQuery(tensor=t, factors=factors, mode=7, build=BUILD))


def test_resumable_stepper_matches_one_shot():
    """cp_als == a loop of cp_als_step over CPState (the scheduler contract)."""
    t = _t1()
    b = core.build_blco(t)
    fn = lambda f, m: core.mttkrp(b, f, m)        # noqa: E731
    ref = core.cp_als(fn, t.dims, 5, norm_x=_norm(t), iters=6, seed=2)
    state = core.cp_als_init(t.dims, 5, norm_x=_norm(t), seed=2)
    for _ in range(6):
        core.cp_als_step(fn, state)
        if state.converged:
            break
    assert state.fits == ref.fits
    for a, b_ in zip(state.factors, ref.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b_))
