"""Multi-tenant decomposition service: registry cache, admission, fair share.

The acceptance scenario: >=3 concurrent jobs on >=2 distinct tensors run
through the scheduler with (a) a BLCO cache hit on the repeated tensor,
(b) admitted reservation bytes never exceeding the budget, (c) per-job CP
factors matching a sequential cp_als run on the same seeds.
"""
import numpy as np
import pytest

from repro import core
from repro.service import (BuildParams, DecompositionService, MTTKRPQuery,
                           SubmitDecomposition, TensorRegistry)

BUILD = BuildParams(max_nnz_per_block=256)      # force many launches


def _t1(seed=6):
    return core.random_tensor((30, 22, 14), 1500, seed=seed, dist="powerlaw")


def _t2():
    return core.random_tensor((40, 25, 30), 2000, seed=3, dist="powerlaw")


def _norm(t):
    return float(np.linalg.norm(t.values))


def test_acceptance_three_jobs_two_tensors():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=3)
    t1, t2, t1_again = _t1(), _t2(), _t1()
    assert t1_again is not t1                     # distinct objects, same content
    j1 = svc.submit(SubmitDecomposition(tensor=t1, rank=6, iters=5, seed=7,
                                        build=BUILD))
    j2 = svc.submit(SubmitDecomposition(tensor=t2, rank=8, iters=5, seed=1,
                                        build=BUILD))
    j3 = svc.submit(SubmitDecomposition(tensor=t1_again, rank=6, iters=5,
                                        seed=7, build=BUILD))
    results = svc.run()
    assert set(results) == {j1, j2, j3}
    m = svc.service_metrics()
    # (a) BLCO cache hit on the repeated tensor
    assert m["blco_cache_hits"] == 1 and m["blco_cache_misses"] == 2
    assert svc.status(j3).cache_hit and not svc.status(j1).cache_hit
    # (b) admitted reservation bytes never exceeded the budget
    assert 0 < m["peak_admitted_reservation_bytes"] <= 64 << 20
    assert m["admitted_reservation_bytes"] == 0   # all released at the end
    # (c) per-job factors match a sequential cp_als on the same seeds
    for jid, t, rank, seed in ((j1, t1, 6, 7), (j2, t2, 8, 1)):
        b = core.build_blco(t, max_nnz_per_block=256)
        ex = core.OOMExecutor(b, queues=3)
        ref = core.cp_als(lambda f, m_: ex.mttkrp(f, m_), t.dims, rank,
                          norm_x=_norm(t), iters=5, seed=seed)
        got = results[jid].result
        np.testing.assert_allclose(got.fits, ref.fits, rtol=1e-5, atol=1e-6)
        for a, b_ in zip(got.factors, ref.factors):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-5)
    # identical submissions produce identical factors (shared BLCO copy)
    for a, b_ in zip(results[j1].result.factors, results[j3].result.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b_))


def test_round_robin_iteration_fair_share():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    ids = [svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=4,
                                          seed=s, tol=0.0, build=BUILD))
           for s in range(3)]
    svc.run()
    trace = svc.scheduler.trace
    assert len(trace) == 12                       # 3 jobs x 4 iterations
    # every scheduling cycle advances each active job exactly once
    for cycle in range(4):
        assert trace[cycle * 3:(cycle + 1) * 3] == ids


def test_admission_control_respects_budget():
    # two distinct reservation shapes (256- vs 512-slot); the budget fits
    # either alone but not both -> the second must queue until the first
    # job completes and releases its reservation
    t1, t2 = _t1(), _t2()
    probe = TensorRegistry()
    small = probe.register(t1, build=BUILD).spec.bytes_in_flight(2)
    big = probe.register(
        t2, build=BuildParams(max_nnz_per_block=512)).spec.bytes_in_flight(2)
    assert small < big
    svc = DecompositionService(device_budget_bytes=big, queues=2)
    j1 = svc.submit(SubmitDecomposition(tensor=t1, rank=4, iters=3, seed=0,
                                        build=BUILD))
    j2 = svc.submit(SubmitDecomposition(
        tensor=t2, rank=4, iters=3, seed=0,
        build=BuildParams(max_nnz_per_block=512)))
    assert svc.status(j1).state == "running"
    assert svc.status(j2).state == "queued"       # over budget: must wait
    assert svc.status(j2).queue_wait_s >= 0.0
    svc.run()
    m = svc.service_metrics()
    assert svc.status(j1).state == "done" and svc.status(j2).state == "done"
    assert m["peak_admitted_reservation_bytes"] <= big


def test_same_shape_tenants_share_one_reservation():
    """Jobs padding to one ReservationSpec charge the budget once (pooling)."""
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    for s in range(3):                            # same tensor content 3x
        svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=2, seed=s,
                                       build=BUILD))
    assert svc.executor.pool_size == 1            # one pooled shape
    one = svc.scheduler.jobs[0].handle.spec.bytes_in_flight(2)
    assert svc.service_metrics()["admitted_reservation_bytes"] == one
    svc.run()
    assert svc.service_metrics()["peak_admitted_reservation_bytes"] == one


def test_oversized_job_rejected_at_submit():
    svc = DecompositionService(device_budget_bytes=1024, queues=4)
    with pytest.raises(ValueError, match="can never be admitted"):
        svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, build=BUILD))


def test_registry_fingerprint_semantics():
    reg = TensorRegistry()
    h1 = reg.register(_t1(), build=BUILD)
    h2 = reg.register(_t1(), build=BUILD)         # same content -> hit
    assert h1 is h2 and reg.hits == 1 and reg.misses == 1
    h3 = reg.register(_t1(), build=BuildParams(max_nnz_per_block=512))
    assert h3 is not h1 and reg.misses == 2       # build params change -> miss
    t_other = _t1(seed=7)
    h4 = reg.register(t_other, build=BUILD)       # different content -> miss
    assert h4 is not h1 and reg.misses == 3
    assert len(reg) == 3 and reg.host_bytes() > 0
    assert reg.evict(h3.key) and len(reg) == 2


def test_mttkrp_query_matches_in_memory():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=3)
    t = _t1()
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((d, 8)).astype(np.float32) for d in t.dims]
    b = core.build_blco(t, max_nnz_per_block=256)
    for mode in range(t.order):
        got = svc.mttkrp(MTTKRPQuery(tensor=t, factors=factors, mode=mode,
                                     build=BUILD))
        ref = core.mttkrp(b, factors, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # all three queries + any later job reuse one cached BLCO build
    assert svc.registry.misses == 1 and svc.registry.hits == 2


def test_failed_job_isolated_and_reservation_released():
    svc = DecompositionService(device_budget_bytes=64 << 20, queues=2)
    good = svc.submit(SubmitDecomposition(tensor=_t1(), rank=4, iters=3,
                                          seed=0, build=BUILD))
    bad = svc.submit(SubmitDecomposition(tensor=_t2(), rank=4, iters=3,
                                         seed=0, build=BUILD))
    svc.scheduler.jobs[bad].mttkrp_fn = \
        lambda f, m: (_ for _ in ()).throw(RuntimeError("boom"))
    svc.run()
    assert svc.status(bad).state == "failed"
    assert "boom" in svc.status(bad).error
    assert svc.status(good).state == "done"       # unaffected tenant
    m = svc.service_metrics()
    assert m["admitted_reservation_bytes"] == 0
    assert m["jobs_failed"] == 1 and m["jobs_completed"] == 1


def test_mttkrp_query_obeys_budget():
    """One-shot queries charge the same admission budget as jobs."""
    t = _t1()
    factors = [np.zeros((d, 4), np.float32) for d in t.dims]
    svc = DecompositionService(device_budget_bytes=1024, queues=4)
    with pytest.raises(ValueError, match="does not fit the device budget"):
        svc.mttkrp(MTTKRPQuery(tensor=t, factors=factors, mode=0, build=BUILD))
    assert svc.executor.pool_size == 0            # nothing leaked
    assert svc.service_metrics()["admitted_reservation_bytes"] == 0
    with pytest.raises(ValueError, match="out of range"):
        DecompositionService().mttkrp(
            MTTKRPQuery(tensor=t, factors=factors, mode=7, build=BUILD))


def test_resumable_stepper_matches_one_shot():
    """cp_als == a loop of cp_als_step over CPState (the scheduler contract)."""
    t = _t1()
    b = core.build_blco(t)
    fn = lambda f, m: core.mttkrp(b, f, m)        # noqa: E731
    ref = core.cp_als(fn, t.dims, 5, norm_x=_norm(t), iters=6, seed=2)
    state = core.cp_als_init(t.dims, 5, norm_x=_norm(t), seed=2)
    for _ in range(6):
        core.cp_als_step(fn, state)
        if state.converged:
            break
    assert state.fits == ref.fits
    for a, b_ in zip(state.factors, ref.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b_))
