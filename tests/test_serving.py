"""Batched serving loop: generation determinism + prefill/decode agreement."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Server, ServeConfig


@pytest.mark.parametrize("name", ["minicpm_2b", "mamba2_370m"])
def test_greedy_generation_deterministic(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    srv = Server(cfg, ServeConfig(max_len=48), params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    a = srv.generate(prompts, 6)
    b = srv.generate(prompts, 6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 6)
    assert (a >= 0).all() and (a < cfg.padded_vocab).all()


def test_batch_independence():
    """Each batch row's continuation depends only on its own prompt."""
    cfg = get_config("minicpm_2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    srv = Server(cfg, ServeConfig(max_len=32), params)
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    both = srv.generate(p, 4)
    solo0 = srv.generate(p[0:1], 4)
    np.testing.assert_array_equal(both[0], solo0[0])
