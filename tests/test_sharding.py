"""Sharding rules: every param of every FULL config gets a divisible spec
(shape-only — no allocation, no mesh devices needed)."""
import numpy as np
import jax
import pytest

from repro.configs import ASSIGNED, get_config
from repro.dist import sharding as shd
from repro.models import build_model

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


class FakeMesh:
    """Shape-only stand-in (NamedSharding needs devices; specs don't)."""
    def __init__(self, names):
        self.axis_names = names
        self.shape = {n: AXIS_SIZES[n] for n in names}


@pytest.mark.parametrize("name", ASSIGNED)
@pytest.mark.parametrize("axes", [("data", "model"), ("pod", "data", "model")])
def test_param_specs_divide(name, axes):
    cfg = get_config(name)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    mesh = FakeMesh(axes)
    f = shd.fsdp_axes(mesh)
    f = f if len(f) > 1 else f[0]
    n_sharded = 0
    for path, leaf in shd.tree_paths(params).items():
        spec = shd.param_spec(path, leaf.shape, f)
        assert len(spec) <= len(leaf.shape), (path, spec)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= AXIS_SIZES[a]
            assert dim % size == 0, (name, path, leaf.shape, spec)
            n_sharded += 1
    # the big params must actually be sharded (ZeRO/TP coverage)
    assert n_sharded > 0


@pytest.mark.parametrize("name", ["qwen2_5_14b", "deepseek_v2_236b",
                                  "dbrx_132b"])
def test_big_params_not_replicated(name):
    """No parameter >= 8 MiB may end up fully replicated."""
    cfg = get_config(name)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    mesh = FakeMesh(("data", "model"))
    for path, leaf in shd.tree_paths(params).items():
        if int(np.prod(leaf.shape)) * 4 < (8 << 20):
            continue
        spec = shd.param_spec(path, leaf.shape, ("data",))
        assert any(ax is not None for ax in tuple(spec)), (path, leaf.shape)


def test_stacked_params_not_sharded_on_layer_dim():
    spec = shd.param_spec("dense_layers/attn/wq/w", (40, 5120, 4096),
                          ("data",))
    assert tuple(spec)[0] is None
    spec = shd.param_spec("group_layers/mamba/in_proj/w", (6, 6, 2048, 8384),
                          ("data",))
    assert tuple(spec)[0] is None and tuple(spec)[1] is None
