"""Service persistence: snapshot mid-run, restore after a simulated
process restart, exact CP-ALS resumption, disk-streamed re-admission."""
import os

import numpy as np
import pytest

from repro import core
from repro.service import (BuildParams, DecompositionService, ServiceRuntime,
                           SubmitDecomposition)
from repro.store import StoreError, restore_service, snapshot_service

BUILD = BuildParams(max_nnz_per_block=1 << 10)
BUDGET = 64 << 20


def _tensor(seed=0):
    return core.paper_like("uber-like", seed=seed)


def _submit(svc, t, *, iters=6, seed=1, tenant="acme", weight=2.0, rank=8):
    return svc.submit(SubmitDecomposition(
        tensor=t, rank=rank, iters=iters, tol=0.0, seed=seed, build=BUILD,
        tenant=tenant, weight=weight))


def test_snapshot_requires_store_dir(tmp_path):
    svc = DecompositionService(device_budget_bytes=BUDGET)
    _submit(svc, _tensor())
    with pytest.raises(StoreError, match="store_dir"):
        svc.snapshot(str(tmp_path / "snap"))


def test_snapshot_restore_resumes_exactly(tmp_path):
    """Acceptance: kill the service mid-decomposition, restore from the
    persisted store, and the resumed fit trajectory equals the
    uninterrupted one exactly — without rebuilding any BLCO."""
    store = str(tmp_path / "store")
    snap = str(tmp_path / "snap")
    t = _tensor()

    ref = DecompositionService(device_budget_bytes=BUDGET, store_dir=store)
    ref_job = _submit(ref, t)
    ref.run()
    ref_fits = ref.result(ref_job).result.fits

    svc = DecompositionService(device_budget_bytes=BUDGET, store_dir=store)
    jid = _submit(svc, t)
    for _ in range(3):
        svc.step()
    manifest = svc.snapshot(snap)
    assert [j["job_id"] for j in manifest["jobs"]] == [jid]
    assert manifest["jobs"][0]["iteration"] == 3
    key = manifest["jobs"][0]["tensor_key"]
    assert os.path.exists(manifest["tensors"][key]["file"])
    del svc                                   # simulated process death

    svc2 = DecompositionService.restore(snap, device_budget_bytes=BUDGET,
                                        store_dir=store)
    st = svc2.status(jid)                     # original id survives
    assert st.state == "running" and st.iteration == 3
    assert st.tenant == "acme" and st.weight == 2.0
    assert svc2.registry.misses == 0          # adopted from store, no rebuild
    assert st.backend == "disk_streamed"      # stub handle streams from disk
    svc2.run()
    fits = svc2.result(jid).result.fits
    assert fits == ref_fits                   # numerically identical resume
    m = svc2.service_metrics()
    assert m["jobs_restored"] == 1
    assert m["disk_bytes_total"] > 0          # store->host traffic rolled up


def test_snapshot_keeps_terminal_jobs_and_queued(tmp_path):
    """DONE jobs persist as finished records: a restarted service keeps
    serving their status()/result() while never re-admitting them."""
    store = str(tmp_path / "store")
    snap = str(tmp_path / "snap")
    svc = DecompositionService(device_budget_bytes=BUDGET, store_dir=store,
                               max_active=1)
    done = _submit(svc, _tensor(), iters=1)
    while svc.status(done).state == "running":
        svc.step()
    running = _submit(svc, _tensor(), iters=5, seed=2)
    queued = _submit(svc, _tensor(seed=1), iters=5, seed=3)
    svc.step()
    assert svc.status(done).state == "done"
    assert svc.status(running).state == "running"
    assert svc.status(queued).state == "queued"
    done_factors = np.asarray(svc.result(done).result.factors[0])
    manifest = svc.snapshot(snap)
    snap_ids = {j["job_id"] for j in manifest["jobs"]}
    assert snap_ids == {done, running, queued}

    svc2 = DecompositionService.restore(snap, device_budget_bytes=BUDGET,
                                        store_dir=store)
    assert set(svc2.scheduler.jobs) == {done, running, queued}
    # the terminal record restores finished — status/result served, never
    # re-admitted (it is in no queue), factors bit-identical
    assert svc2.status(done).state == "done"
    assert done not in svc2.scheduler.pending
    assert done not in svc2.scheduler.active
    assert np.array_equal(
        np.asarray(svc2.result(done).result.factors[0]), done_factors)
    # a queued job was never admitted: it restores without a CPState and
    # initializes from its seed on admission
    svc2.run()
    assert svc2.status(running).state == "done"
    assert svc2.status(queued).state == "done"
    # new submissions continue past the restored ids
    new = _submit(svc2, _tensor(seed=2), iters=1)
    assert new > max(snap_ids)


def test_restore_missing_manifest_raises(tmp_path):
    svc = DecompositionService(device_budget_bytes=BUDGET)
    with pytest.raises(StoreError, match="manifest"):
        restore_service(str(tmp_path / "nope"), svc)


def test_runtime_snapshot_restore_mid_flight(tmp_path):
    """Satellite 6's machinery: ServiceRuntime.snapshot() at a quantum
    boundary, runtime restart, job resumes and completes."""
    store = str(tmp_path / "store")
    snap = str(tmp_path / "snap")
    t = _tensor()
    with ServiceRuntime(device_budget_bytes=BUDGET, store_dir=store) as rt:
        jid = rt.submit(SubmitDecomposition(
            tensor=t, rank=8, iters=50, tol=0.0, seed=1, build=BUILD,
            tenant="acme"))
        feed = rt.subscribe(jid)
        while True:                      # wait until real progress was made
            ev = feed.get(timeout=120)
            assert ev is not None
            if ev.kind == "iteration" and ev.iteration >= 2:
                prefix = list(ev.fits)   # trajectory the first process saw
                break
        rt.unsubscribe(feed)
        manifest = rt.snapshot(snap)
    # context exit stopped the runtime mid-decomposition ("kill")
    [rec] = manifest["jobs"]
    assert rec["state"] == "running" and rec["iteration"] >= 2

    rt2 = ServiceRuntime.restore(snap, device_budget_bytes=BUDGET,
                                 store_dir=store)
    with rt2:
        status = rt2.wait(jid, timeout=600)
    assert status.state == "done"
    assert status.iteration == 50
    assert rt2.service.registry.misses == 0   # no BLCO rebuild after restart
    fits = rt2.result(jid).result.fits
    assert len(fits) == 50
    # the checkpointed prefix is exactly what the first process computed
    # (the worker may have swept past the observed event before snapshot)
    k = min(len(prefix), rec["iteration"])
    assert k >= 2 and fits[:k] == prefix[:k]


def test_snapshot_is_nonintrusive(tmp_path):
    """Snapshotting persists tensors but never drops host copies or
    perturbs the running decomposition."""
    store = str(tmp_path / "store")
    snap = str(tmp_path / "snap")
    t = _tensor()
    ref = DecompositionService(device_budget_bytes=BUDGET, store_dir=store)
    rj = _submit(ref, t)
    ref.run()
    ref_fits = ref.result(rj).result.fits

    svc = DecompositionService(device_budget_bytes=BUDGET, store_dir=store)
    jid = _submit(svc, t)
    svc.step()
    handle = svc.scheduler.jobs[jid].handle
    was_resident = handle.resident
    svc.snapshot(snap)
    assert handle.resident == was_resident    # persist() keeps host copies
    svc.run()
    assert svc.result(jid).result.fits == ref_fits
